"""Bit-accurate configuration for the velocity-factor tanh datapath.

This module is the *specification*: the Pallas kernel
(`velocity_tanh.py`), the pure-jnp/numpy oracle (`ref.py`) and the rust
golden model (`rust/src/tanh/`) all implement exactly the semantics
defined here, bit for bit.

Paper mapping (Chandra, "A Novel Method for Scalable VLSI Implementation
of Hyperbolic Tangent Function"):

  * velocity factor  f(a) = (1 - tanh a) / (1 + tanh a) = e^(-2a)   (eq. 9)
  * tanh a           = (1 - f) / (1 + f)                            (eq. 10)
  * f(a + b)         = f(a) * f(b)                                  (eq. 6)
  * per-bit product  f(N * 2^-frac) = prod_k f(2^(k-frac))^(b_k)    (eq. 7)
  * grouped LUTs store the product for each bit-combination          (Table I)
  * bit-shuffled addressing mixes place values across groups         (IV.B.3)
  * (1+f)/2 in (0.5, 1) feeds a Newton-Raphson reciprocal            (eq. 11)
  * numerator 1-f via 2's complement or the cheaper 1's complement   (IV.B.4)
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Tuple

SUB_ONES = "ones"
SUB_TWOS = "twos"


@dataclass(frozen=True)
class TanhConfig:
    """Static parameters of one hardware instance of the tanh unit.

    Fixed-point formats:
      input  : signed s{in_int}.{in_frac}, width 1 + in_int + in_frac bits
      output : signed s.{out_frac},        width 1 + out_frac bits
      LUTs   : u0.{lut_bits} velocity factors (always in (0,1], eq. 9)
      NR path: u·.{mult_bits} (multiplier fractional precision)
    """

    in_int: int = 3
    in_frac: int = 12
    out_frac: int = 15
    lut_bits: int = 18
    mult_bits: int = 16
    lut_group: int = 4
    shuffle: bool = True
    nr_stages: int = 3  # 0 => reference float divider (Table II row 0)
    subtractor: str = SUB_TWOS

    def __post_init__(self) -> None:
        if self.in_int < 0 or self.in_frac < 1 or self.out_frac < 1:
            raise ValueError(f"invalid format: {self}")
        if self.lut_bits < self.mult_bits - 1:
            raise ValueError("lut_bits must be >= mult_bits - 1 "
                             "(d = (1+f)/2 is floor-truncated from the LUT domain)")
        if self.lut_group < 1:
            raise ValueError("lut_group must be >= 1")
        if self.nr_stages not in (0, 1, 2, 3, 4):
            raise ValueError("nr_stages must be in {0..4}")
        if self.subtractor not in (SUB_ONES, SUB_TWOS):
            raise ValueError("subtractor must be 'ones' or 'twos'")

    # ---- derived geometry -------------------------------------------------

    @property
    def mag_bits(self) -> int:
        """Magnitude bits of the input (sign stripped)."""
        return self.in_int + self.in_frac

    @property
    def in_width(self) -> int:
        return 1 + self.mag_bits

    @property
    def out_width(self) -> int:
        return 1 + self.out_frac

    @property
    def out_max(self) -> int:
        """Largest representable output word: 1 - 2^-out_frac."""
        return (1 << self.out_frac) - 1

    @property
    def num_groups(self) -> int:
        return (self.mag_bits + self.lut_group - 1) // self.lut_group

    @property
    def sat_threshold(self) -> int:
        """Smallest input magnitude word that saturates the output.

        Beyond atanh(1 - 2^-out_frac) the true tanh differs from 1.0 by
        less than the output lsb (paper §IV): emit out_max directly.
        """
        dom = math.atanh(1.0 - 2.0 ** (-self.out_frac))
        return math.ceil(dom * (1 << self.in_frac))

    # ---- LUT construction -------------------------------------------------

    def group_positions(self) -> List[List[int]]:
        """Bit positions (lsb=0) addressed by each LUT group.

        shuffle=True deals the sorted positions round-robin so every group
        mixes small and large place values (paper IV.B.3: LUT0 addressed by
        {x15, x8, x7, x0} instead of {x3..x0}); shuffle=False packs them
        consecutively (the "accentuated" precision-loss layout the paper
        warns about).
        """
        n, g = self.mag_bits, self.num_groups
        if self.shuffle:
            groups = [[p for p in range(j, n, g)] for j in range(g)]
        else:
            groups = [list(range(j * self.lut_group,
                                 min((j + 1) * self.lut_group, n)))
                      for j in range(g)]
        return groups

    def lut_tables(self) -> List[List[int]]:
        """Velocity-factor LUT contents, one table per group.

        entry[mask] = round(2^L * prod_{j: mask_j=1} e^(-2 * 2^(p_j - in_frac)))

        The product over the group's set bits is evaluated exactly (in
        float) and rounded once — that is what a ROM stores (Table I).
        A full-scale f == 1.0 (mask == 0) is stored as 2^L and relies on
        the table width being lut_bits+1; hardware implements the 0-mask
        bypass as "no multiply", which is numerically identical.
        """
        one = 1 << self.lut_bits
        tables: List[List[int]] = []
        for positions in self.group_positions():
            size = 1 << len(positions)
            table = []
            for mask in range(size):
                a = 0.0
                for j, p in enumerate(positions):
                    if (mask >> j) & 1:
                        a += 2.0 ** (p - self.in_frac)
                val = int(round(one * math.exp(-2.0 * a)))
                table.append(min(val, one))
            tables.append(table)
        return tables

    # ---- Newton-Raphson constants ------------------------------------

    @property
    def nr_seed_const(self) -> int:
        """Seed constant for the linear NR seed x0 = 2.75 - 2d.

        Kornerup & Muller's optimum is 48/17 - 32/17*d (x0 = 2.9142 - 2d
        after scaling). Hardware instead uses 2.75 = 0b10.11 — a constant
        with two set bits, so the whole seed is one 3-input add. The seed's
        relative error is then largest near d = 0.5 (where tanh is large
        and the error actually shows at the output) and squares per NR
        stage: NR2 lands at ~2.6e-4 and NR3 at the multiplier-quantization
        floor ~5e-5 — the exact NR2 vs NR3 profile of the paper's Table II.
        """
        return 11 << (self.mult_bits - 2)  # 2.75 * 2^M

    def describe(self) -> str:
        return (f"s{self.in_int}.{self.in_frac}->s.{self.out_frac} "
                f"L={self.lut_bits} M={self.mult_bits} g={self.lut_group} "
                f"{'shuf' if self.shuffle else 'seq'} nr={self.nr_stages} "
                f"{self.subtractor}")


# The paper's two headline operating points.
CFG_16BIT = TanhConfig()  # s3.12 -> s.15 (Tables II, III)
CFG_8BIT = TanhConfig(in_int=3, in_frac=5, out_frac=7,
                      lut_bits=10, mult_bits=9, lut_group=3)  # Table IV
