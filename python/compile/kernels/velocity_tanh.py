"""Layer-1 Pallas kernels: velocity-factor tanh on fixed-point words.

The compute hot-spot of the paper's accelerator: tanh over a batch of
signed fixed-point words, computed exactly as the hardware datapath does
(grouped velocity-factor LUTs -> product chain -> 1/2's-complement
subtract -> Newton-Raphson reciprocal -> recompose), vectorized over the
batch dimension.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the ASIC's per-bit LUT
product tree becomes a gather + compile-time-unrolled multiplicative
reduction over `num_groups` tiny broadcast tables (VPU work); the MXU is
engaged by the fused `matmul -> quantize -> vf-tanh` kernel used by the
L2 model. BlockSpec tiles the batch so one VMEM block holds a tile of
activations plus the (~256 B, grid-broadcast) tables.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowering produces plain
HLO that the rust runtime loads byte-identically.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import SUB_ONES, TanhConfig

jax.config.update("jax_enable_x64", True)


def _round_mul(a, b, frac: int):
    """u·.frac x u·.frac -> u·.frac with round-to-nearest, in int64."""
    return (a * b + (1 << (frac - 1))) >> frac


def lut_operands(cfg: TanhConfig):
    """The grouped velocity-factor tables as jnp arrays (kernel operands).

    Pallas kernels may not capture array constants, so every kernel takes
    these as explicit (grid-broadcast) inputs.
    """
    return tuple(jnp.asarray(t, dtype=jnp.int64) for t in cfg.lut_tables())


def vf_tanh_words(x, cfg: TanhConfig, tables):
    """Core datapath on a jnp int array of input words -> output words.

    Pure jnp int64 ops; used inside the Pallas kernels below and reusable
    from plain jax code. Matches ``ref.tanh_vf_reference`` bit-for-bit.
    """
    x = x.astype(jnp.int64)
    sign = x < 0
    n = jnp.abs(x)

    one_l = 1 << cfg.lut_bits

    # Grouped LUT product chain (eq. 7 / Table I).
    f = None
    for positions, table in zip(cfg.group_positions(), tables):
        addr = jnp.zeros_like(n)
        for j, p in enumerate(positions):
            addr = addr | (((n >> p) & 1) << j)
        entry = jnp.take(table, addr)
        f = entry if f is None else _round_mul(f, entry, cfg.lut_bits)

    # Output stage: num = 1 - f (2's or 1's complement), den = 1 + f.
    if cfg.subtractor == SUB_ONES:
        num = (one_l - 1) - f
    else:
        num = one_l - f
    den = one_l + f

    if cfg.nr_stages == 0:
        # Reference float divider + fixed-point conversion (Table II row 0).
        q = num.astype(jnp.float64) / den.astype(jnp.float64)
        t = jnp.rint(q * (1 << cfg.out_frac)).astype(jnp.int64)
    else:
        # d = (1+f)/2 truncated to M fractional bits; in [0.5, 1) (eq. 11).
        d = den >> (cfg.lut_bits + 1 - cfg.mult_bits)
        m = cfg.mult_bits
        two = 2 << m
        xr = cfg.nr_seed_const - (d << 1)
        for _ in range(cfg.nr_stages):
            t0 = _round_mul(d, xr, m)
            xr = _round_mul(xr, two - t0, m)
        shift = cfg.lut_bits + cfg.mult_bits + 1 - cfg.out_frac
        t = (num * xr + (1 << (shift - 1))) >> shift

    t = jnp.clip(t, 0, cfg.out_max)
    t = jnp.where(n >= cfg.sat_threshold, cfg.out_max, t)
    return jnp.where(sign, -t, t).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------


def _tanh_kernel(x_ref, *rest, cfg: TanhConfig):
    *table_refs, o_ref = rest
    tables = [t[...] for t in table_refs]
    o_ref[...] = vf_tanh_words(x_ref[...], cfg, tables)


@partial(jax.jit, static_argnames=("cfg", "tile"))
def tanh_vf(x, cfg: TanhConfig = TanhConfig(), tile: int = 256):
    """Batched tanh on int32 words via a Pallas kernel.

    ``x``: int32[N] fixed-point words (s{in_int}.{in_frac}); N must be a
    multiple of ``tile``. Returns int32[N] output words (s.{out_frac}).
    """
    n = x.shape[0]
    if n % tile:
        raise ValueError(f"batch {n} not a multiple of tile {tile}")
    tables = lut_operands(cfg)
    table_specs = [
        pl.BlockSpec(t.shape, lambda i: (0,)) for t in tables
    ]
    return pl.pallas_call(
        partial(_tanh_kernel, cfg=cfg),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))] + table_specs,
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        interpret=True,
    )(x, *tables)


def quantize_f32(x, frac_bits: int, width: int):
    """Round-to-nearest f32 -> signed word, saturating (accelerator ADC)."""
    lo = -(1 << (width - 1))
    hi = (1 << (width - 1)) - 1
    w = jnp.rint(x.astype(jnp.float64) * (1 << frac_bits))
    return jnp.clip(w, lo, hi).astype(jnp.int64)


def _fused_dense_kernel(x_ref, w_ref, b_ref, *rest, cfg: TanhConfig,
                        pre_shift: int):
    """MXU path: f32 matmul tile, then the int datapath on the result.

    pre_shift=1 halves the pre-activation before quantization, which turns
    the unit into a sigmoid: sigma(z) = (1 + tanh(z/2)) / 2.
    """
    *table_refs, o_ref = rest
    tables = [t[...] for t in table_refs]
    z = x_ref[...] @ w_ref[...] + b_ref[...]
    z = z / (1 << pre_shift)
    words = quantize_f32(z, cfg.in_frac, cfg.in_width)
    t = vf_tanh_words(words, cfg, tables).astype(jnp.float32)
    y = t / (1 << cfg.out_frac)
    if pre_shift:
        y = (1.0 + y) * 0.5
    o_ref[...] = y.astype(jnp.float32)


@partial(jax.jit, static_argnames=("cfg", "sigmoid"))
def fused_dense_vf_tanh(x, w, b, cfg: TanhConfig = TanhConfig(),
                        sigmoid: bool = False):
    """y = act(x @ w + b) with the activation through the VF datapath.

    x: f32[B, I], w: f32[I, O], b: f32[O] -> f32[B, O]. Single-block
    pallas_call (model tiles are small); the activation is bit-exact with
    the hardware unit, so accelerator-level accuracy studies are faithful.
    """
    bdim, odim = x.shape[0], w.shape[1]
    tables = lut_operands(cfg)
    return pl.pallas_call(
        partial(_fused_dense_kernel, cfg=cfg, pre_shift=1 if sigmoid else 0),
        out_shape=jax.ShapeDtypeStruct((bdim, odim), jnp.float32),
        interpret=True,
    )(x, w, b, *tables)


def _act_kernel(x_ref, *rest, cfg: TanhConfig, sigmoid: bool):
    *table_refs, o_ref = rest
    tables = [t[...] for t in table_refs]
    z = x_ref[...]
    if sigmoid:
        z = z * 0.5
    words = quantize_f32(z, cfg.in_frac, cfg.in_width)
    t = vf_tanh_words(words, cfg, tables).astype(jnp.float32)
    y = t / (1 << cfg.out_frac)
    if sigmoid:
        y = (1.0 + y) * 0.5
    o_ref[...] = y.astype(jnp.float32)


@partial(jax.jit, static_argnames=("cfg", "sigmoid"))
def act_vf(x, cfg: TanhConfig = TanhConfig(), sigmoid: bool = False):
    """Elementwise activation on an f32 array through the VF datapath."""
    shape = x.shape
    flat = x.reshape(-1)
    tables = lut_operands(cfg)
    y = pl.pallas_call(
        partial(_act_kernel, cfg=cfg, sigmoid=sigmoid),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat, *tables)
    return y.reshape(shape)
