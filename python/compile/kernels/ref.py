"""Pure-numpy/jnp oracles for the velocity-factor tanh kernel.

Two references:

  * ``tanh_float_quantized`` — the *mathematical* oracle: float64 tanh of the
    dequantized input, rounded to the output format. The paper's Table II
    "Max Error" is measured against this.
  * ``tanh_vf_reference``   — the *bit-accurate* oracle: a straight-line
    numpy int64 transcription of the datapath spec in ``config.py``. The
    Pallas kernel (and the rust golden model) must match this value
    exactly, word for word.
"""

from __future__ import annotations

import math

import numpy as np

from .config import SUB_ONES, TanhConfig


def quantize(x: np.ndarray, frac_bits: int, width: int) -> np.ndarray:
    """Round float to a signed fixed-point word, saturating."""
    lo = -(1 << (width - 1))
    hi = (1 << (width - 1)) - 1
    w = np.rint(np.asarray(x, dtype=np.float64) * (1 << frac_bits))
    return np.clip(w, lo, hi).astype(np.int64)


def dequantize(w: np.ndarray, frac_bits: int) -> np.ndarray:
    return np.asarray(w, dtype=np.float64) / (1 << frac_bits)


def tanh_float_quantized(x_word: np.ndarray, cfg: TanhConfig) -> np.ndarray:
    """Mathematical oracle: float tanh -> output fixed-point word."""
    x = dequantize(x_word, cfg.in_frac)
    y = np.tanh(x)
    return quantize(y, cfg.out_frac, cfg.out_width)


def _round_mul(a: np.ndarray, b: np.ndarray, frac: int) -> np.ndarray:
    """Fixed-point multiply with round-to-nearest (+half then truncate).

    Both operands and the result carry ``frac`` fractional bits.
    """
    prod = a.astype(np.int64) * b.astype(np.int64)
    return (prod + (1 << (frac - 1))) >> frac


def newton_raphson_recip(d: np.ndarray, cfg: TanhConfig) -> np.ndarray:
    """Reciprocal of d in [0.5, 1] (u1.M word) via NR, returning u1.M.

    x0 = 2.9142 - 2d, then nr_stages of x <- x * (2 - d*x), every product
    rounded to M fractional bits (the paper's fixed multiplier precision).
    """
    m = cfg.mult_bits
    two = np.int64(2 << m)
    x = np.int64(cfg.nr_seed_const) - (d.astype(np.int64) << 1)
    for _ in range(cfg.nr_stages):
        t = _round_mul(d, x, m)
        x = _round_mul(x, two - t, m)
    return x


def tanh_vf_reference(x_word: np.ndarray, cfg: TanhConfig) -> np.ndarray:
    """Bit-accurate datapath reference. Input/output are int64 words."""
    x = np.asarray(x_word, dtype=np.int64)
    sign = x < 0
    n = np.abs(x)

    one_l = np.int64(1 << cfg.lut_bits)

    # LUT product chain (eq. 7 with grouped LUTs, Table I).
    groups = cfg.group_positions()
    tables = [np.asarray(t, dtype=np.int64) for t in cfg.lut_tables()]
    f = None
    for positions, table in zip(groups, tables):
        addr = np.zeros_like(n)
        for j, p in enumerate(positions):
            addr |= ((n >> p) & 1) << j
        entry = table[addr]
        f = entry if f is None else _round_mul(f, entry, cfg.lut_bits)

    # Output stage: num = 1 - f, den = 1 + f (bit concat), d = den/2.
    if cfg.subtractor == SUB_ONES:
        num = (one_l - 1) - f
    else:
        num = one_l - f
    den = one_l + f

    if cfg.nr_stages == 0:
        # Reference float divider + fixed-point conversion (Table II row 0).
        t = np.rint((num.astype(np.float64) / den.astype(np.float64))
                    * (1 << cfg.out_frac)).astype(np.int64)
    else:
        # d = (1+f)/2 truncated to M fractional bits (single right shift +
        # lsb drop — eq. 11 makes this land in [0.5, 1)).
        d = den >> (cfg.lut_bits + 1 - cfg.mult_bits)
        recip = newton_raphson_recip(d, cfg)
        # tanh = num * recip / 2, rounded to the output format.
        shift = cfg.lut_bits + cfg.mult_bits + 1 - cfg.out_frac
        t = (num * recip + (1 << (shift - 1))) >> shift

    t = np.minimum(t, cfg.out_max)
    t = np.maximum(t, 0)

    # Saturation region (inputs beyond the representable-error domain).
    t = np.where(n >= cfg.sat_threshold, np.int64(cfg.out_max), t)
    return np.where(sign, -t, t)


def max_error(cfg: TanhConfig, x_words: np.ndarray | None = None) -> dict:
    """Error statistics of the datapath vs true tanh (Table II metric)."""
    if x_words is None:
        half = 1 << cfg.mag_bits
        x_words = np.arange(-half, half, dtype=np.int64)
    got = tanh_vf_reference(x_words, cfg)
    y_true = np.tanh(dequantize(x_words, cfg.in_frac))
    err = np.abs(dequantize(got, cfg.out_frac) - y_true)
    i = int(np.argmax(err))
    return {
        "max_error": float(err[i]),
        "mean_error": float(err.mean()),
        "rms_error": float(math.sqrt(float((err ** 2).mean()))),
        "argmax_word": int(x_words[i]),
        "lsb": 2.0 ** (-cfg.out_frac),
        "max_error_lsb": float(err[i] * (1 << cfg.out_frac)),
    }
