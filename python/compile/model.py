"""Layer-2 JAX model: the accelerator workloads the paper motivates.

The paper (§I) motivates the tanh unit with RNN/LSTM accelerators — tanh
for cell/candidate activations, sigmoid (= shifted/scaled tanh) for the
gates. This module defines the forward graphs that the rust coordinator
serves through PJRT:

  * ``tanh_batch``   — the raw activation unit over a batch of words.
  * ``mlp_forward``  — 3-layer MLP, hidden activations through the VF unit.
  * ``lstm_cell``    — a single LSTM step, all five nonlinearities through
    the VF unit (sigmoid via sigma(z) = (1 + tanh(z/2))/2, the same
    datapath with a 1-bit pre-shift — "free" in hardware).
  * ``lstm_seq``     — ``lax.scan`` of the cell over a fixed sequence
    (scan, not unroll: one compiled step body regardless of T).

Everything here is build-time only; ``aot.py`` lowers each entry point to
HLO text in ``artifacts/``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.config import TanhConfig
from .kernels.velocity_tanh import act_vf, fused_dense_vf_tanh, tanh_vf

jax.config.update("jax_enable_x64", True)


def tanh_batch(x, cfg: TanhConfig = TanhConfig(), tile: int = 256):
    """Raw activation service: int32 words in, int32 words out."""
    return tanh_vf(x, cfg, tile=tile)


class MlpParams(NamedTuple):
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array
    w3: jax.Array
    b3: jax.Array


def mlp_forward(x, p: MlpParams, cfg: TanhConfig = TanhConfig()):
    """3-layer MLP; hidden layers fused matmul+VF-tanh, linear head."""
    h1 = fused_dense_vf_tanh(x, p.w1, p.b1, cfg)
    h2 = fused_dense_vf_tanh(h1, p.w2, p.b2, cfg)
    return h2 @ p.w3 + p.b3


class LstmParams(NamedTuple):
    wx: jax.Array  # [I, 4H] input kernel,  gate order (i, f, g, o)
    wh: jax.Array  # [H, 4H] recurrent kernel
    b: jax.Array   # [4H]


def lstm_cell(x, h, c, p: LstmParams, cfg: TanhConfig = TanhConfig()):
    """One LSTM step with every nonlinearity through the VF datapath."""
    hidden = h.shape[-1]
    z = x @ p.wx + h @ p.wh + p.b
    zi, zf, zg, zo = (z[..., k * hidden:(k + 1) * hidden] for k in range(4))
    i = act_vf(zi, cfg, sigmoid=True)
    f = act_vf(zf, cfg, sigmoid=True)
    g = act_vf(zg, cfg)
    o = act_vf(zo, cfg, sigmoid=True)
    c_new = f * c + i * g
    h_new = o * act_vf(c_new, cfg)
    return h_new, c_new


def lstm_seq(xs, h0, c0, p: LstmParams, cfg: TanhConfig = TanhConfig()):
    """Scan the cell over xs: f32[T, B, I] -> (h_T, c_T, hs[T, B, H])."""

    def step(carry, x):
        h, c = carry
        h, c = lstm_cell(x, h, c, p, cfg)
        return (h, c), h

    (h, c), hs = jax.lax.scan(step, (h0, c0), xs)
    return h, c, hs


# ---------------------------------------------------------------------------
# Canonical serving shapes (the rust coordinator pads requests to these).
# ---------------------------------------------------------------------------

TANH_BATCH = 1024
MLP_BATCH, MLP_IN, MLP_H1, MLP_H2, MLP_OUT = 32, 64, 64, 32, 10
LSTM_BATCH, LSTM_IN, LSTM_HIDDEN, LSTM_T = 16, 32, 64, 8


def mlp_param_spec():
    f32 = jnp.float32
    return MlpParams(
        w1=jax.ShapeDtypeStruct((MLP_IN, MLP_H1), f32),
        b1=jax.ShapeDtypeStruct((MLP_H1,), f32),
        w2=jax.ShapeDtypeStruct((MLP_H1, MLP_H2), f32),
        b2=jax.ShapeDtypeStruct((MLP_H2,), f32),
        w3=jax.ShapeDtypeStruct((MLP_H2, MLP_OUT), f32),
        b3=jax.ShapeDtypeStruct((MLP_OUT,), f32),
    )


def lstm_param_spec():
    f32 = jnp.float32
    return LstmParams(
        wx=jax.ShapeDtypeStruct((LSTM_IN, 4 * LSTM_HIDDEN), f32),
        wh=jax.ShapeDtypeStruct((LSTM_HIDDEN, 4 * LSTM_HIDDEN), f32),
        b=jax.ShapeDtypeStruct((4 * LSTM_HIDDEN,), f32),
    )
