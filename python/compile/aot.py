"""AOT compile path: lower every L2 entry point to HLO text artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. HLO *text* (not ``.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

Also emits:
  * ``manifest.json``       — entry points, parameter shapes/dtypes, so the
    rust runtime can validate its buffers before dispatch.
  * ``golden_vectors.json`` — bit-exact input/output vectors from the numpy
    oracle, replayed by rust integration tests against (a) the native
    golden model, (b) the RTL simulator, and (c) the PJRT executable.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.config import CFG_8BIT, CFG_16BIT, TanhConfig
from .kernels.ref import max_error, tanh_vf_reference
from . import model as M

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides any
    # constant with more than 8 elements as `{...}`, which the xla 0.5.1
    # text parser accepts silently and fills with garbage — the velocity
    # factor LUTs (16 entries) would be destroyed.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": str(dtype)}


def lower_tanh(cfg: TanhConfig, batch: int):
    fn = lambda x: (M.tanh_batch(x, cfg, tile=min(256, batch)),)
    lowered = jax.jit(fn).lower(_spec((batch,), jnp.int32))
    return to_hlo_text(lowered), {
        "inputs": [_io_entry("x", (batch,), "s32")],
        "outputs": [_io_entry("y", (batch,), "s32")],
        "config": dataclasses.asdict(cfg),
    }


def lower_mlp(cfg: TanhConfig):
    p = M.mlp_param_spec()
    fn = lambda x, *params: (M.mlp_forward(x, M.MlpParams(*params), cfg),)
    x = _spec((M.MLP_BATCH, M.MLP_IN), jnp.float32)
    lowered = jax.jit(fn).lower(x, *p)
    ins = [_io_entry("x", x.shape, "f32")] + [
        _io_entry(n, s.shape, "f32") for n, s in zip(p._fields, p)
    ]
    return to_hlo_text(lowered), {
        "inputs": ins,
        "outputs": [_io_entry("logits", (M.MLP_BATCH, M.MLP_OUT), "f32")],
        "config": dataclasses.asdict(cfg),
    }


def lower_lstm_cell(cfg: TanhConfig):
    p = M.lstm_param_spec()
    fn = lambda x, h, c, wx, wh, b: M.lstm_cell(
        x, h, c, M.LstmParams(wx, wh, b), cfg)
    shapes = {
        "x": (M.LSTM_BATCH, M.LSTM_IN),
        "h": (M.LSTM_BATCH, M.LSTM_HIDDEN),
        "c": (M.LSTM_BATCH, M.LSTM_HIDDEN),
        "wx": p.wx.shape, "wh": p.wh.shape, "b": p.b.shape,
    }
    lowered = jax.jit(fn).lower(
        *[_spec(s, jnp.float32) for s in shapes.values()])
    return to_hlo_text(lowered), {
        "inputs": [_io_entry(n, s, "f32") for n, s in shapes.items()],
        "outputs": [
            _io_entry("h_new", (M.LSTM_BATCH, M.LSTM_HIDDEN), "f32"),
            _io_entry("c_new", (M.LSTM_BATCH, M.LSTM_HIDDEN), "f32"),
        ],
        "config": dataclasses.asdict(cfg),
    }


def lower_lstm_seq(cfg: TanhConfig):
    p = M.lstm_param_spec()

    def fn(xs, h0, c0, wx, wh, b):
        h, c, hs = M.lstm_seq(xs, h0, c0, M.LstmParams(wx, wh, b), cfg)
        return h, c, hs

    shapes = {
        "xs": (M.LSTM_T, M.LSTM_BATCH, M.LSTM_IN),
        "h0": (M.LSTM_BATCH, M.LSTM_HIDDEN),
        "c0": (M.LSTM_BATCH, M.LSTM_HIDDEN),
        "wx": p.wx.shape, "wh": p.wh.shape, "b": p.b.shape,
    }
    lowered = jax.jit(fn).lower(
        *[_spec(s, jnp.float32) for s in shapes.values()])
    return to_hlo_text(lowered), {
        "inputs": [_io_entry(n, s, "f32") for n, s in shapes.items()],
        "outputs": [
            _io_entry("h", (M.LSTM_BATCH, M.LSTM_HIDDEN), "f32"),
            _io_entry("c", (M.LSTM_BATCH, M.LSTM_HIDDEN), "f32"),
            _io_entry("hs", (M.LSTM_T, M.LSTM_BATCH, M.LSTM_HIDDEN), "f32"),
        ],
        "config": dataclasses.asdict(cfg),
    }


# ---------------------------------------------------------------------------
# Golden vectors
# ---------------------------------------------------------------------------


def tanh_edge_words(cfg: TanhConfig, n: int, seed: int = 1234) -> np.ndarray:
    """Edge cases + deterministic random words, padded to n."""
    half = 1 << cfg.mag_bits
    edges = [0, 1, -1, 2, -2, half - 1, -half, -(half - 1),
             cfg.sat_threshold, cfg.sat_threshold - 1, cfg.sat_threshold + 1,
             -cfg.sat_threshold, -cfg.sat_threshold + 1]
    edges += [1 << k for k in range(cfg.mag_bits)]
    edges += [-(1 << k) for k in range(cfg.mag_bits)]
    edges += [(1 << k) - 1 for k in range(1, cfg.mag_bits)]
    rng = np.random.default_rng(seed)
    rand = rng.integers(-half, half, size=max(0, n - len(edges)))
    out = np.concatenate([np.asarray(edges, dtype=np.int64), rand])[:n]
    return out.astype(np.int64)


def golden(cfg: TanhConfig, n: int) -> dict:
    x = tanh_edge_words(cfg, n)
    y = tanh_vf_reference(x, cfg)
    stats = max_error(cfg)
    return {
        "config": dataclasses.asdict(cfg),
        "inputs": x.tolist(),
        "outputs": y.tolist(),
        "exhaustive_max_error": stats["max_error"],
        "exhaustive_max_error_lsb": stats["max_error_lsb"],
    }


def golden_mlp(cfg: TanhConfig) -> dict:
    rng = np.random.default_rng(7)
    x = rng.normal(size=(M.MLP_BATCH, M.MLP_IN)).astype(np.float32)
    p = M.MlpParams(
        w1=(rng.normal(size=(M.MLP_IN, M.MLP_H1)) * 0.3).astype(np.float32),
        b1=(rng.normal(size=(M.MLP_H1,)) * 0.1).astype(np.float32),
        w2=(rng.normal(size=(M.MLP_H1, M.MLP_H2)) * 0.3).astype(np.float32),
        b2=(rng.normal(size=(M.MLP_H2,)) * 0.1).astype(np.float32),
        w3=(rng.normal(size=(M.MLP_H2, M.MLP_OUT)) * 0.3).astype(np.float32),
        b3=(rng.normal(size=(M.MLP_OUT,)) * 0.1).astype(np.float32),
    )
    logits = np.asarray(M.mlp_forward(jnp.asarray(x), p, cfg))
    return {
        "x": x.ravel().tolist(),
        "params": {n: np.asarray(v).ravel().tolist()
                   for n, v in zip(p._fields, p)},
        "logits": logits.ravel().tolist(),
    }


def golden_lstm(cfg: TanhConfig) -> dict:
    rng = np.random.default_rng(11)
    x = rng.normal(size=(M.LSTM_BATCH, M.LSTM_IN)).astype(np.float32)
    h = (rng.normal(size=(M.LSTM_BATCH, M.LSTM_HIDDEN)) * 0.5).astype(np.float32)
    c = (rng.normal(size=(M.LSTM_BATCH, M.LSTM_HIDDEN)) * 0.5).astype(np.float32)
    p = M.LstmParams(
        wx=(rng.normal(size=(M.LSTM_IN, 4 * M.LSTM_HIDDEN)) * 0.2).astype(np.float32),
        wh=(rng.normal(size=(M.LSTM_HIDDEN, 4 * M.LSTM_HIDDEN)) * 0.2).astype(np.float32),
        b=(rng.normal(size=(4 * M.LSTM_HIDDEN,)) * 0.1).astype(np.float32),
    )
    hn, cn = M.lstm_cell(jnp.asarray(x), jnp.asarray(h), jnp.asarray(c), p, cfg)
    return {
        "x": x.ravel().tolist(), "h": h.ravel().tolist(),
        "c": c.ravel().tolist(),
        "params": {n: np.asarray(v).ravel().tolist()
                   for n, v in zip(p._fields, p)},
        "h_new": np.asarray(hn).ravel().tolist(),
        "c_new": np.asarray(cn).ravel().tolist(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory (or a single .hlo.txt path, "
                         "in which case its parent is used)")
    args = ap.parse_args()
    out_dir = args.out
    if out_dir.endswith(".txt"):
        out_dir = os.path.dirname(out_dir) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "entries": {}}

    jobs = {
        "tanh_s3_12": lambda: lower_tanh(CFG_16BIT, M.TANH_BATCH),
        "tanh_s3_5": lambda: lower_tanh(CFG_8BIT, M.TANH_BATCH),
        "mlp_b32": lambda: lower_mlp(CFG_16BIT),
        "lstm_cell_b16": lambda: lower_lstm_cell(CFG_16BIT),
        "lstm_seq_b16": lambda: lower_lstm_seq(CFG_16BIT),
    }
    for name, job in jobs.items():
        text, meta = job()
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        meta["file"] = f"{name}.hlo.txt"
        manifest["entries"][name] = meta
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)

    vectors = {
        "tanh_s3_12": golden(CFG_16BIT, M.TANH_BATCH),
        "tanh_s3_5": golden(CFG_8BIT, M.TANH_BATCH),
        "tanh_s3_12_nr2_ones": golden(
            dataclasses.replace(CFG_16BIT, nr_stages=2, subtractor="ones"),
            M.TANH_BATCH),
        "mlp_b32": golden_mlp(CFG_16BIT),
        "lstm_cell_b16": golden_lstm(CFG_16BIT),
    }
    gv = os.path.join(out_dir, "golden_vectors.json")
    with open(gv, "w") as fh:
        json.dump(vectors, fh)
    print(f"wrote {gv}")

    # Compatibility with the Makefile's sentinel target.
    sentinel = os.path.join(out_dir, "model.hlo.txt")
    if not os.path.exists(sentinel):
        with open(os.path.join(out_dir, "tanh_s3_12.hlo.txt")) as src, \
                open(sentinel, "w") as dst:
            dst.write(src.read())


if __name__ == "__main__":
    main()
