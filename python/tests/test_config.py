"""Unit tests for the datapath configuration / LUT construction."""

import dataclasses
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.config import CFG_8BIT, CFG_16BIT, TanhConfig


class TestGeometry:
    def test_canonical_16bit(self):
        cfg = CFG_16BIT
        assert cfg.mag_bits == 15
        assert cfg.in_width == 16
        assert cfg.out_width == 16
        assert cfg.out_max == (1 << 15) - 1
        assert cfg.num_groups == 4

    def test_canonical_8bit(self):
        cfg = CFG_8BIT
        assert cfg.mag_bits == 8
        assert cfg.in_width == 9
        assert cfg.out_width == 8
        assert cfg.num_groups == 3

    def test_sat_threshold_matches_paper_domain(self):
        # Paper §IV: domain for s.15 output is ±5.55, for s.7 is ±2.77.
        assert CFG_16BIT.sat_threshold / (1 << 12) == pytest.approx(5.55, abs=0.01)
        assert CFG_8BIT.sat_threshold / (1 << 5) == pytest.approx(2.78, abs=0.03)

    def test_invalid_configs_rejected(self):
        with pytest.raises(ValueError):
            TanhConfig(in_frac=0)
        with pytest.raises(ValueError):
            TanhConfig(lut_bits=10, mult_bits=16)
        with pytest.raises(ValueError):
            TanhConfig(nr_stages=7)
        with pytest.raises(ValueError):
            TanhConfig(subtractor="threes")
        with pytest.raises(ValueError):
            TanhConfig(lut_group=0)


class TestGroupPositions:
    def test_shuffle_partitions_all_bits(self):
        for cfg in (CFG_16BIT, CFG_8BIT):
            flat = sorted(p for g in cfg.group_positions() for p in g)
            assert flat == list(range(cfg.mag_bits))

    def test_sequential_partitions_all_bits(self):
        cfg = dataclasses.replace(CFG_16BIT, shuffle=False)
        flat = sorted(p for g in cfg.group_positions() for p in g)
        assert flat == list(range(cfg.mag_bits))
        # consecutive packing
        assert cfg.group_positions()[0] == [0, 1, 2, 3]

    def test_shuffle_mixes_magnitudes(self):
        # Every group must contain at least one "low" and one "high" bit
        # (the paper's IV.B.3 precision argument).
        cfg = CFG_16BIT
        for g in cfg.group_positions():
            assert min(g) < cfg.mag_bits // 2
            assert max(g) >= cfg.mag_bits // 2

    @given(st.integers(1, 6), st.integers(4, 16), st.booleans())
    @settings(max_examples=50, deadline=None)
    def test_partition_property(self, group, mag, shuffle):
        cfg = TanhConfig(in_int=3, in_frac=mag - 3, out_frac=15,
                         lut_group=group, shuffle=shuffle)
        flat = sorted(p for g in cfg.group_positions() for p in g)
        assert flat == list(range(cfg.mag_bits))
        assert all(len(g) <= group for g in cfg.group_positions())


class TestLutTables:
    def test_entry_zero_is_one(self):
        # mask 0 => f = 1.0 (no angle contribution).
        for t in CFG_16BIT.lut_tables():
            assert t[0] == 1 << CFG_16BIT.lut_bits

    def test_entries_monotone_decreasing_in_angle(self):
        # Larger angle => smaller velocity factor (f = e^-2a).
        cfg = CFG_16BIT
        for positions, table in zip(cfg.group_positions(), cfg.lut_tables()):
            angles = []
            for mask in range(len(table)):
                a = sum(2.0 ** (p - cfg.in_frac)
                        for j, p in enumerate(positions) if (mask >> j) & 1)
                angles.append(a)
            order = np.argsort(angles)
            vals = np.asarray(table)[order]
            assert (np.diff(vals) <= 0).all()

    def test_entries_match_exp_identity(self):
        cfg = CFG_16BIT
        one = 1 << cfg.lut_bits
        for positions, table in zip(cfg.group_positions(), cfg.lut_tables()):
            for mask in (1, 3, len(table) - 1):
                a = sum(2.0 ** (p - cfg.in_frac)
                        for j, p in enumerate(positions) if (mask >> j) & 1)
                assert table[mask] == round(one * math.exp(-2 * a))

    def test_table_sizes(self):
        sizes = [len(t) for t in CFG_16BIT.lut_tables()]
        assert sizes == [16, 16, 16, 8]  # 15 bits in groups of 4

    def test_multi_bit_entry_is_product_table1(self):
        # Paper Table I: entry(11) = vf(lsb) * vf(msb) up to rounding.
        cfg = dataclasses.replace(CFG_16BIT, lut_group=2, shuffle=False)
        for positions, table in zip(cfg.group_positions(), cfg.lut_tables()):
            if len(positions) < 2:
                continue
            one = 1 << cfg.lut_bits
            approx = table[1] * table[2] / one
            assert abs(table[3] - approx) <= 2

    def test_nr_seed_const(self):
        assert CFG_16BIT.nr_seed_const == int(2.75 * 2 ** 16)
        assert CFG_8BIT.nr_seed_const == int(2.75 * 2 ** 9)
