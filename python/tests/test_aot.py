"""AOT-path regression tests — the cross-layer gotchas, pinned.

The expensive one discovered during bring-up: the default HLO text
printer elides constants with more than 8 elements as `{...}`, and the
rust side's xla_extension 0.5.1 text parser *silently accepts* that and
fills the tensor with garbage. The velocity-factor LUTs are 16-entry
constants, so the whole datapath broke while every python-side test
passed. These tests make that failure mode impossible to reintroduce.
"""

import dataclasses
import re

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.aot import lower_tanh, to_hlo_text, tanh_edge_words
from compile.kernels.config import CFG_8BIT, CFG_16BIT
from compile.kernels.ref import tanh_vf_reference


class TestHloTextIntegrity:
    def test_no_elided_constants(self):
        """`{...}` in HLO text means a constant was dropped — fatal."""
        for cfg, batch in [(CFG_16BIT, 256), (CFG_8BIT, 256)]:
            text, _ = lower_tanh(cfg, batch)
            assert "constant({...})" not in text, (
                "HLO printer elided a large constant; "
                "as_hlo_text(print_large_constants=True) regressed"
            )

    def test_lut_constants_present_verbatim(self):
        """Every LUT table entry must appear in the HLO text."""
        text, _ = lower_tanh(CFG_16BIT, 256)
        for table in CFG_16BIT.lut_tables():
            # Spot-check distinctive (non-trivial) entries.
            for v in [table[1], table[-1]]:
                if v in (0, 1):
                    continue
                assert re.search(rf"\b{v}\b", text), f"LUT entry {v} missing"

    def test_entry_computation_present(self):
        text, meta = lower_tanh(CFG_16BIT, 512)
        assert "ENTRY" in text
        assert meta["inputs"][0]["shape"] == [512]
        assert meta["outputs"][0]["dtype"] == "s32"

    def test_roundtrip_is_deterministic(self):
        a, _ = lower_tanh(CFG_16BIT, 128)
        b, _ = lower_tanh(CFG_16BIT, 128)
        assert a == b


class TestGoldenVectors:
    def test_edge_words_cover_boundaries(self):
        cfg = CFG_16BIT
        xs = tanh_edge_words(cfg, 1024)
        assert len(xs) == 1024
        for must in [0, 1, -1, (1 << 15) - 1, -(1 << 15),
                     cfg.sat_threshold, cfg.sat_threshold - 1]:
            assert must in xs, f"edge word {must} missing"
        # All words must fit the input format.
        assert (xs >= -(1 << 15)).all() and (xs < (1 << 15)).all()

    def test_edge_words_deterministic(self):
        a = tanh_edge_words(CFG_16BIT, 512)
        b = tanh_edge_words(CFG_16BIT, 512)
        np.testing.assert_array_equal(a, b)

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_reference_defined_on_all_words(self, seed):
        # The oracle must be total over the input domain for any config
        # flavour used in golden vectors.
        rng = np.random.default_rng(seed)
        cfg = dataclasses.replace(
            CFG_16BIT,
            nr_stages=int(rng.integers(0, 4)),
            subtractor=["ones", "twos"][int(rng.integers(0, 2))],
        )
        x = rng.integers(-(1 << 15), 1 << 15, size=64)
        y = tanh_vf_reference(x, cfg)
        assert (np.abs(y) <= cfg.out_max).all()


class TestManifestSchema:
    def test_manifest_fields(self):
        import json
        import os
        path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        man = json.load(open(path))
        for name, entry in man["entries"].items():
            assert entry["file"].endswith(".hlo.txt"), name
            for io in entry["inputs"] + entry["outputs"]:
                assert set(io) == {"name", "shape", "dtype"}
                assert io["dtype"] in ("f32", "s32")
                assert all(isinstance(d, int) and d > 0 for d in io["shape"])

    def test_artifact_files_have_full_constants(self):
        import os
        art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        if not os.path.isdir(art):
            pytest.skip("artifacts not built")
        for f in os.listdir(art):
            if f.endswith(".hlo.txt"):
                text = open(os.path.join(art, f)).read()
                assert "constant({...})" not in text, f
