"""Pallas kernel vs oracle: the core L1 correctness signal.

Bit-exactness against the numpy datapath reference, accuracy against the
float oracle (Table II bands), and hypothesis sweeps over shapes, dtypes
and datapath configurations.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.config import CFG_8BIT, CFG_16BIT, TanhConfig
from compile.kernels.velocity_tanh import (act_vf, fused_dense_vf_tanh,
                                           tanh_vf)

RNG = np.random.default_rng(42)


def words(cfg, n):
    half = 1 << cfg.mag_bits
    return RNG.integers(-half, half, size=n).astype(np.int32)


class TestBitExactness:
    @pytest.mark.parametrize("nr", [0, 1, 2, 3])
    @pytest.mark.parametrize("sub", ["ones", "twos"])
    def test_16bit_matches_reference(self, nr, sub):
        cfg = dataclasses.replace(CFG_16BIT, nr_stages=nr, subtractor=sub)
        x = words(cfg, 1024)
        got = np.asarray(tanh_vf(jnp.asarray(x), cfg))
        want = ref.tanh_vf_reference(x, cfg)
        np.testing.assert_array_equal(got, want)

    def test_8bit_exhaustive(self):
        cfg = CFG_8BIT
        half = 1 << cfg.mag_bits
        x = np.arange(-half, half, dtype=np.int32)
        got = np.asarray(tanh_vf(jnp.asarray(x), cfg, tile=128))
        want = ref.tanh_vf_reference(x, cfg)
        np.testing.assert_array_equal(got, want)

    def test_tile_independence(self):
        cfg = CFG_16BIT
        x = words(cfg, 1024)
        a = np.asarray(tanh_vf(jnp.asarray(x), cfg, tile=128))
        b = np.asarray(tanh_vf(jnp.asarray(x), cfg, tile=512))
        np.testing.assert_array_equal(a, b)

    def test_bad_batch_rejected(self):
        with pytest.raises(ValueError):
            tanh_vf(jnp.zeros((1000,), jnp.int32), CFG_16BIT, tile=256)

    @given(st.integers(1, 3), st.booleans(), st.integers(2, 5),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_config_sweep_matches_reference(self, nr, shuffle, group, seed):
        cfg = TanhConfig(nr_stages=nr, shuffle=shuffle, lut_group=group)
        rng = np.random.default_rng(seed)
        x = rng.integers(-(1 << 15), 1 << 15, size=256).astype(np.int32)
        got = np.asarray(tanh_vf(jnp.asarray(x), cfg))
        want = ref.tanh_vf_reference(x, cfg)
        np.testing.assert_array_equal(got, want)

    @given(st.sampled_from([(3, 5, 7, 10, 9, 3), (3, 12, 15, 18, 16, 4),
                            (2, 9, 11, 14, 12, 3), (4, 10, 14, 17, 15, 4)]))
    @settings(max_examples=8, deadline=None)
    def test_precision_scaling(self, fmt):
        ii, if_, of, lb, mb, g = fmt
        cfg = TanhConfig(in_int=ii, in_frac=if_, out_frac=of,
                         lut_bits=lb, mult_bits=mb, lut_group=g)
        x = words(cfg, 256)
        got = np.asarray(tanh_vf(jnp.asarray(x), cfg))
        want = ref.tanh_vf_reference(x, cfg)
        np.testing.assert_array_equal(got, want)


class TestMathematicalProperties:
    def test_odd_symmetry(self):
        cfg = CFG_16BIT
        x = words(cfg, 512)
        x = x[x != -(1 << 15)]  # negation overflows for the min word
        pos = np.asarray(tanh_vf(jnp.asarray(np.abs(x).astype(np.int32)),
                                 cfg, tile=1))
        neg = np.asarray(tanh_vf(jnp.asarray((-np.abs(x)).astype(np.int32)),
                                 cfg, tile=1))
        np.testing.assert_array_equal(pos, -neg)

    def test_zero_maps_to_zero(self):
        got = np.asarray(tanh_vf(jnp.zeros((256,), jnp.int32), CFG_16BIT))
        assert (got == 0).all()

    def test_saturation_region(self):
        cfg = CFG_16BIT
        x = np.full(256, cfg.sat_threshold + 5, dtype=np.int32)
        got = np.asarray(tanh_vf(jnp.asarray(x), cfg))
        assert (got == cfg.out_max).all()

    def test_monotone_nondecreasing(self):
        cfg = CFG_16BIT
        x = np.sort(words(cfg, 1024))
        got = np.asarray(tanh_vf(jnp.asarray(np.ascontiguousarray(x)), cfg))
        # Datapath is not strictly monotone at lsb level, but violations
        # must stay within 2 output lsb (quantization noise only).
        assert (np.diff(got) >= -2).all()

    def test_output_range(self):
        cfg = CFG_16BIT
        half = 1 << cfg.mag_bits
        x = RNG.integers(-half, half, size=4096).astype(np.int32)
        got = np.asarray(tanh_vf(jnp.asarray(x), cfg))
        assert (np.abs(got) <= cfg.out_max).all()


class TestAccuracy:
    def test_table2_band_nr3(self):
        cfg = dataclasses.replace(CFG_16BIT, nr_stages=3)
        stats = ref.max_error(cfg)
        # Paper Table II: 4.44e-5. Same band: < 2.5 lsb.
        assert stats["max_error"] < 7.7e-5

    def test_table2_band_nr2_worse(self):
        e2 = ref.max_error(dataclasses.replace(CFG_16BIT, nr_stages=2))
        e3 = ref.max_error(dataclasses.replace(CFG_16BIT, nr_stages=3))
        # Paper: 2.56e-4 vs 4.44e-5 — NR2 is several x worse.
        assert e2["max_error"] > 2.5 * e3["max_error"]
        assert 1e-4 < e2["max_error"] < 6e-4

    def test_ones_vs_twos_marginal(self):
        e1 = ref.max_error(dataclasses.replace(
            CFG_16BIT, nr_stages=3, subtractor="ones"))
        e2 = ref.max_error(dataclasses.replace(
            CFG_16BIT, nr_stages=3, subtractor="twos"))
        assert abs(e1["max_error"] - e2["max_error"]) < 5e-5

    def test_8bit_error_within_lsb(self):
        stats = ref.max_error(CFG_8BIT)
        assert stats["max_error"] <= stats["lsb"] * 1.01

    def test_kernel_accuracy_vs_float(self):
        cfg = CFG_16BIT
        x = words(cfg, 4096)
        got = np.asarray(tanh_vf(jnp.asarray(x), cfg))
        want = np.tanh(x.astype(np.float64) / (1 << cfg.in_frac))
        err = np.abs(got / (1 << cfg.out_frac) - want)
        assert err.max() < 7.7e-5


class TestFusedKernels:
    @given(st.integers(1, 8), st.integers(1, 24), st.integers(1, 12),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_fused_dense_close_to_float(self, b, i, o, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(b, i)).astype(np.float32)
        w = (rng.normal(size=(i, o)) * 0.4).astype(np.float32)
        bias = (rng.normal(size=(o,)) * 0.1).astype(np.float32)
        y = np.asarray(fused_dense_vf_tanh(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias)))
        want = np.tanh(x @ w + bias)
        # input quantization (2^-13) + datapath error + output lsb
        assert np.abs(y - want).max() < 3e-4

    def test_sigmoid_identity(self):
        rng = np.random.default_rng(3)
        x = rng.normal(size=(4, 8)).astype(np.float32)
        w = np.eye(8, dtype=np.float32)
        b = np.zeros(8, dtype=np.float32)
        y = np.asarray(fused_dense_vf_tanh(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), sigmoid=True))
        want = 1.0 / (1.0 + np.exp(-x))
        assert np.abs(y - want).max() < 3e-4

    def test_act_vf_shapes(self):
        for shape in [(16,), (4, 8), (2, 3, 5)]:
            x = RNG.normal(size=shape).astype(np.float32)
            y = np.asarray(act_vf(jnp.asarray(x)))
            assert y.shape == shape
            assert np.abs(y - np.tanh(x)).max() < 3e-4

    def test_act_vf_saturates(self):
        x = np.asarray([100.0, -100.0], dtype=np.float32)
        y = np.asarray(act_vf(jnp.asarray(x)))
        lsb = 2.0 ** -15
        np.testing.assert_allclose(y, [1 - lsb, -(1 - lsb)], atol=1e-9)
