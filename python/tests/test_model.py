"""L2 model tests: shapes, numerics vs float references, scan semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.config import CFG_16BIT

RNG = np.random.default_rng(5)


def mk_mlp_params(scale=0.3):
    return M.MlpParams(
        w1=jnp.asarray(RNG.normal(size=(M.MLP_IN, M.MLP_H1)) * scale, jnp.float32),
        b1=jnp.asarray(RNG.normal(size=(M.MLP_H1,)) * 0.1, jnp.float32),
        w2=jnp.asarray(RNG.normal(size=(M.MLP_H1, M.MLP_H2)) * scale, jnp.float32),
        b2=jnp.asarray(RNG.normal(size=(M.MLP_H2,)) * 0.1, jnp.float32),
        w3=jnp.asarray(RNG.normal(size=(M.MLP_H2, M.MLP_OUT)) * scale, jnp.float32),
        b3=jnp.asarray(RNG.normal(size=(M.MLP_OUT,)) * 0.1, jnp.float32),
    )


def mk_lstm_params(scale=0.2):
    return M.LstmParams(
        wx=jnp.asarray(RNG.normal(size=(M.LSTM_IN, 4 * M.LSTM_HIDDEN)) * scale,
                       jnp.float32),
        wh=jnp.asarray(RNG.normal(size=(M.LSTM_HIDDEN, 4 * M.LSTM_HIDDEN)) * scale,
                       jnp.float32),
        b=jnp.asarray(RNG.normal(size=(4 * M.LSTM_HIDDEN,)) * 0.1, jnp.float32),
    )


def float_lstm_cell(x, h, c, p):
    hidden = h.shape[-1]
    z = x @ np.asarray(p.wx) + h @ np.asarray(p.wh) + np.asarray(p.b)
    zi, zf, zg, zo = (z[..., k * hidden:(k + 1) * hidden] for k in range(4))
    sig = lambda v: 1.0 / (1.0 + np.exp(-v))
    c_new = sig(zf) * c + sig(zi) * np.tanh(zg)
    h_new = sig(zo) * np.tanh(c_new)
    return h_new, c_new


class TestMlp:
    def test_shapes(self):
        x = jnp.asarray(RNG.normal(size=(M.MLP_BATCH, M.MLP_IN)), jnp.float32)
        y = M.mlp_forward(x, mk_mlp_params())
        assert y.shape == (M.MLP_BATCH, M.MLP_OUT)

    def test_close_to_float_mlp(self):
        x = RNG.normal(size=(M.MLP_BATCH, M.MLP_IN)).astype(np.float32)
        p = mk_mlp_params()
        y = np.asarray(M.mlp_forward(jnp.asarray(x), p))
        h1 = np.tanh(x @ np.asarray(p.w1) + np.asarray(p.b1))
        h2 = np.tanh(h1 @ np.asarray(p.w2) + np.asarray(p.b2))
        want = h2 @ np.asarray(p.w3) + np.asarray(p.b3)
        # activation error compounds over two hidden layers but stays small
        assert np.abs(y - want).max() < 5e-3

    def test_hidden_activations_bounded(self):
        # The VF unit can never emit |y| >= 1.
        x = jnp.asarray(RNG.normal(size=(4, M.MLP_IN)) * 50, jnp.float32)
        p = mk_mlp_params(scale=5.0)
        from compile.kernels.velocity_tanh import fused_dense_vf_tanh
        h1 = np.asarray(fused_dense_vf_tanh(x, p.w1, p.b1, CFG_16BIT))
        assert (np.abs(h1) < 1.0).all()


class TestLstm:
    def test_cell_shapes(self):
        x = jnp.asarray(RNG.normal(size=(M.LSTM_BATCH, M.LSTM_IN)), jnp.float32)
        h = jnp.zeros((M.LSTM_BATCH, M.LSTM_HIDDEN), jnp.float32)
        c = jnp.zeros((M.LSTM_BATCH, M.LSTM_HIDDEN), jnp.float32)
        hn, cn = M.lstm_cell(x, h, c, mk_lstm_params())
        assert hn.shape == (M.LSTM_BATCH, M.LSTM_HIDDEN)
        assert cn.shape == (M.LSTM_BATCH, M.LSTM_HIDDEN)

    def test_cell_close_to_float(self):
        x = RNG.normal(size=(M.LSTM_BATCH, M.LSTM_IN)).astype(np.float32)
        h = (RNG.normal(size=(M.LSTM_BATCH, M.LSTM_HIDDEN)) * 0.5).astype(np.float32)
        c = (RNG.normal(size=(M.LSTM_BATCH, M.LSTM_HIDDEN)) * 0.5).astype(np.float32)
        p = mk_lstm_params()
        hn, cn = M.lstm_cell(jnp.asarray(x), jnp.asarray(h), jnp.asarray(c), p)
        hf, cf = float_lstm_cell(x, h, c, p)
        assert np.abs(np.asarray(hn) - hf).max() < 2e-3
        assert np.abs(np.asarray(cn) - cf).max() < 2e-3

    def test_seq_matches_repeated_cell(self):
        T = 4
        xs = (RNG.normal(size=(T, M.LSTM_BATCH, M.LSTM_IN))).astype(np.float32)
        h = np.zeros((M.LSTM_BATCH, M.LSTM_HIDDEN), np.float32)
        c = np.zeros((M.LSTM_BATCH, M.LSTM_HIDDEN), np.float32)
        p = mk_lstm_params()
        hs_, cs_ = jnp.asarray(h), jnp.asarray(c)
        outs = []
        for t in range(T):
            hs_, cs_ = M.lstm_cell(jnp.asarray(xs[t]), hs_, cs_, p)
            outs.append(np.asarray(hs_))
        hT, cT, hs = M.lstm_seq(jnp.asarray(xs), jnp.asarray(h), jnp.asarray(c), p)
        np.testing.assert_allclose(np.asarray(hT), outs[-1], rtol=0, atol=1e-6)
        np.testing.assert_allclose(np.asarray(hs)[-1], outs[-1], atol=1e-6)

    def test_gate_saturation_keeps_state_bounded(self):
        # Huge inputs: sigmoid gates pin to ~{0,1}, tanh to ±(1-lsb);
        # state stays bounded (hardware never overflows).
        x = jnp.asarray(np.full((M.LSTM_BATCH, M.LSTM_IN), 100.0), jnp.float32)
        h = jnp.zeros((M.LSTM_BATCH, M.LSTM_HIDDEN), jnp.float32)
        c = jnp.asarray(np.full((M.LSTM_BATCH, M.LSTM_HIDDEN), 0.9), jnp.float32)
        hn, cn = M.lstm_cell(x, h, c, mk_lstm_params(scale=1.0))
        assert np.isfinite(np.asarray(hn)).all()
        assert (np.abs(np.asarray(cn)) < 2.0).all()
        assert (np.abs(np.asarray(hn)) < 1.0).all()


class TestAotLowering:
    def test_tanh_lowering_roundtrip(self):
        from compile.aot import lower_tanh
        text, meta = lower_tanh(CFG_16BIT, 256)
        assert "ENTRY" in text
        assert meta["inputs"][0]["shape"] == [256]

    def test_manifest_entries_complete(self):
        import json
        import os
        path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        man = json.load(open(path))
        assert set(man["entries"]) >= {
            "tanh_s3_12", "tanh_s3_5", "mlp_b32", "lstm_cell_b16",
            "lstm_seq_b16"}
        for e in man["entries"].values():
            assert os.path.exists(os.path.join(os.path.dirname(path), e["file"]))
