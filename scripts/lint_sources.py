#!/usr/bin/env python3
"""Dependency-free source lints for the request-serving tier.

Rules (scoped to ``rust/src/server/``, the code on the request path):

  unwrap     ``.unwrap()`` / ``.expect(`` outside ``#[cfg(test)]``
             modules. A panic on the serving path kills a worker and
             drops every in-flight connection it owned; fallible paths
             must surface errors to the connection state machine
             instead. (Test modules may unwrap freely.)
  systemtime ``SystemTime::now()`` outside the ``Clock`` /
             ``now_millis``-style seams. Direct wall-clock reads in
             request handling break the deterministic simulator
             (``server/sim.rs``) — inject time through the existing
             seam instead.

Existing debt is pinned, not ignored: ``scripts/lint_allowlist.txt``
holds per-file budgets (``<path> <rule> <max-count>``). A new violation
over budget fails CI; paying debt down prints a reminder to ratchet
the budget so it cannot regress.

Usage:  python3 scripts/lint_sources.py [--repo-root DIR]
Exits non-zero with one line per violation.
"""

import argparse
import os
import re
import sys

SERVER_DIR = os.path.join("rust", "src", "server")
ALLOWLIST = os.path.join("scripts", "lint_allowlist.txt")

UNWRAP_RE = re.compile(r"\.unwrap\(\)|\.expect\(")
SYSTEMTIME_RE = re.compile(r"SystemTime::now\(\)")
CFG_TEST_RE = re.compile(r"#\[cfg\((?:test|miri)\)\]")


def strip_noncode(line):
    """Drop line comments and (crudely) string literals so a lint token
    inside a doc comment or log message doesn't count."""
    # Strings first (so "// ..." inside a string doesn't start a
    # comment), then comments. Raw strings are rare enough to ignore.
    line = re.sub(r'"(?:[^"\\]|\\.)*"', '""', line)
    return line.split("//", 1)[0]


def test_mod_mask(lines):
    """Boolean per line: is it inside a `#[cfg(test)] mod ... { }`
    block? Brace counting on comment/string-stripped text — the repo
    is rustfmt'd, so attribute and `mod` lines are well-formed."""
    mask = [False] * len(lines)
    i = 0
    while i < len(lines):
        if CFG_TEST_RE.search(strip_noncode(lines[i])):
            # Attributes may stack; find the item the cfg applies to.
            j = i + 1
            while j < len(lines) and strip_noncode(lines[j]).strip().startswith("#["):
                j += 1
            item = strip_noncode(lines[j]).strip() if j < len(lines) else ""
            if item.startswith(("mod ", "pub mod ", "pub(crate) mod ")):
                depth = 0
                k = j
                while k < len(lines):
                    code = strip_noncode(lines[k])
                    depth += code.count("{") - code.count("}")
                    mask[k] = True
                    if depth <= 0 and "{" in code.replace("{}", ""):
                        # degenerate one-line mod
                        break
                    if depth <= 0 and k > j:
                        break
                    k += 1
                i = k + 1
                continue
            # cfg(test) on a non-mod item (fn, use): mark through the
            # item's block, or just that line for braceless items.
            depth = 0
            k = j
            while k < len(lines):
                code = strip_noncode(lines[k])
                depth += code.count("{") - code.count("}")
                mask[k] = True
                if depth <= 0 and ("{" in code or code.rstrip().endswith(";")):
                    break
                k += 1
            i = k + 1
            continue
        i += 1
    return mask


def load_allowlist(root):
    budgets = {}
    path = os.path.join(root, ALLOWLIST)
    if not os.path.exists(path):
        return budgets
    with open(path, encoding="utf-8") as fh:
        for raw in fh:
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split()
            if len(parts) != 3:
                print(f"{ALLOWLIST}: malformed line: {raw.rstrip()}")
                sys.exit(2)
            rel, rule, budget = parts
            budgets[(rel.replace("\\", "/"), rule)] = int(budget)
    return budgets


def lint_file(root, rel):
    with open(os.path.join(root, rel), encoding="utf-8") as fh:
        lines = fh.read().splitlines()
    in_test = test_mod_mask(lines)
    hits = {"unwrap": [], "systemtime": []}
    for idx, line in enumerate(lines):
        code = strip_noncode(line)
        if not in_test[idx] and UNWRAP_RE.search(code):
            hits["unwrap"].append(idx + 1)
        if not in_test[idx] and SYSTEMTIME_RE.search(code):
            hits["systemtime"].append(idx + 1)
    return hits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--repo-root", default=".")
    ap.add_argument(
        "--print-counts",
        action="store_true",
        help="dump per-file counts (for regenerating the allowlist)",
    )
    args = ap.parse_args()
    root = args.repo_root

    budgets = load_allowlist(root)
    server = os.path.join(root, SERVER_DIR)
    if not os.path.isdir(server):
        print(f"missing {SERVER_DIR} (run from the repo root)")
        return 2

    failures = 0
    for name in sorted(os.listdir(server)):
        if not name.endswith(".rs"):
            continue
        rel = "/".join([SERVER_DIR.replace(os.sep, "/"), name])
        hits = lint_file(root, name and os.path.join(SERVER_DIR, name))
        for rule, linenos in sorted(hits.items()):
            budget = budgets.get((rel, rule), 0)
            if args.print_counts and linenos:
                print(f"{rel} {rule} {len(linenos)}")
                continue
            if len(linenos) > budget:
                failures += 1
                where = ", ".join(str(n) for n in linenos)
                print(
                    f"{rel}: {len(linenos)} {rule} violation(s) "
                    f"(budget {budget}) at line(s) {where}"
                )
            elif linenos and len(linenos) < budget:
                print(
                    f"note: {rel} {rule} count {len(linenos)} is under "
                    f"budget {budget} — ratchet {ALLOWLIST} down"
                )
    if failures:
        print(f"\nlint_sources: {failures} rule failure(s). Either fix "
              f"the code or (for deliberate debt) raise {ALLOWLIST}.")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
