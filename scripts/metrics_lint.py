#!/usr/bin/env python3
"""Lint a Prometheus text exposition read from stdin.

Checks the invariants the scrape pipeline relies on:

  * every sample belongs to a family announced by a HELP/TYPE pair
    (``_bucket``/``_sum``/``_count`` resolve to their histogram family);
  * TYPE is one of counter, gauge, histogram;
  * histogram bucket ``le`` bounds are finite, strictly increasing, and
    terminated by ``+Inf``;
  * cumulative bucket counts are non-decreasing per label set;
  * the ``+Inf`` bucket equals ``_count``, and ``_sum``/``_count`` exist
    for every histogram label set.

Usage:  curl -sf http://host:port/metrics | python3 metrics_lint.py
Exits non-zero with one line per violation.
"""

import re
import sys

SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^ ]+)$"
)
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_labels(raw):
    if not raw:
        return ()
    return tuple(sorted(LABEL_RE.findall(raw)))


def main():
    text = sys.stdin.read()
    helps, types = {}, {}
    # family -> {label_set_without_le: {"buckets": [(le, count)],
    #            "sum": float|None, "count": float|None}}
    histograms = {}
    errors = []

    for ln, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            helps[line.split(None, 3)[2]] = True
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            fam, typ = parts[2], parts[3]
            if typ not in ("counter", "gauge", "histogram"):
                errors.append(f"line {ln}: unknown TYPE {typ} for {fam}")
            types[fam] = typ
            continue
        if line.startswith("#"):
            continue

        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"line {ln}: unparseable sample: {line}")
            continue
        name = m.group("name")
        labels = parse_labels(m.group("labels"))
        try:
            value = float(m.group("value"))
        except ValueError:
            errors.append(f"line {ln}: non-numeric value in: {line}")
            continue

        fam = name
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[: -len(suffix)] if name.endswith(suffix) else None
            if base and types.get(base) == "histogram":
                fam = base
                break
        if fam not in types or fam not in helps:
            errors.append(f"line {ln}: sample {name} has no HELP/TYPE pair")
            continue

        if types[fam] == "histogram":
            series = histograms.setdefault(fam, {})
            key = tuple(kv for kv in labels if kv[0] != "le")
            entry = series.setdefault(
                key, {"buckets": [], "sum": None, "count": None}
            )
            if name.endswith("_bucket"):
                le = dict(labels).get("le")
                if le is None:
                    errors.append(f"line {ln}: bucket without le: {line}")
                    continue
                bound = float("inf") if le == "+Inf" else float(le)
                entry["buckets"].append((bound, value, ln))
            elif name.endswith("_sum"):
                entry["sum"] = value
            elif name.endswith("_count"):
                entry["count"] = value
            else:
                errors.append(
                    f"line {ln}: bare sample {name} on histogram {fam}"
                )

    for fam, series in histograms.items():
        for key, entry in series.items():
            where = f"{fam}{{{', '.join('='.join(kv) for kv in key)}}}"
            buckets = entry["buckets"]
            if not buckets:
                errors.append(f"{where}: histogram with no buckets")
                continue
            bounds = [b for b, _, _ in buckets]
            if sorted(bounds) != bounds or len(set(bounds)) != len(bounds):
                errors.append(f"{where}: le bounds not strictly increasing")
            if bounds[-1] != float("inf"):
                errors.append(f"{where}: missing terminal +Inf bucket")
            counts = [c for _, c, _ in buckets]
            if any(b > a for a, b in zip(counts[1:], counts)):
                errors.append(f"{where}: cumulative counts decrease")
            if entry["count"] is None:
                errors.append(f"{where}: missing _count")
            elif bounds[-1] == float("inf") and counts[-1] != entry["count"]:
                errors.append(
                    f"{where}: +Inf bucket {counts[-1]} != _count "
                    f"{entry['count']}"
                )
            if entry["sum"] is None:
                errors.append(f"{where}: missing _sum")

    if errors:
        for e in errors:
            print(f"metrics-lint: {e}", file=sys.stderr)
        sys.exit(1)
    nhist = sum(len(s) for s in histograms.values())
    print(
        f"metrics-lint: ok — {len(types)} families "
        f"({len(histograms)} histogram families, {nhist} label sets)"
    )


if __name__ == "__main__":
    main()
