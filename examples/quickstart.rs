//! Quickstart: build a tanh unit, evaluate it, inspect accuracy.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use tanh_vf::analysis::{exhaustive_error, region_error, ulp_histogram};
use tanh_vf::tanh::{TanhConfig, TanhUnit};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. The paper's 16-bit operating point: s3.12 in, s.15 out.
    let cfg = TanhConfig::s3_12();
    let unit = TanhUnit::new(cfg)?;
    println!("unit: {}\n", cfg.describe());

    // 2. Evaluate some values through the float convenience API.
    println!("{:>8} {:>12} {:>12} {:>10}", "x", "unit", "true", "err");
    for i in -8..=8 {
        let x = i as f64 * 0.5;
        let y = unit.eval_f64(x);
        println!(
            "{x:>8.2} {y:>12.8} {:>12.8} {:>10.2e}",
            x.tanh(),
            (y - x.tanh()).abs()
        );
    }

    // 3. Word-level API (what the hardware actually sees).
    let words: Vec<i64> = vec![0, 1024, 4096, 8192, 22713, 32767];
    let outs = unit.eval_batch(&words);
    println!("\nword-level: {words:?} -> {outs:?}");

    // 4. Exhaustive error over all 2^16 input words (Table II headline).
    let stats = exhaustive_error(&unit);
    println!(
        "\nexhaustive max error: {:.3e} ({:.2} output lsb) at word {}",
        stats.max_abs,
        stats.max_lsb(cfg.out_format()),
        stats.argmax
    );

    // 5. Error by region and ULP histogram.
    let rep = region_error(&unit);
    println!(
        "region max error: pass {:.2e}  processing {:.2e}  saturation {:.2e}",
        rep.pass.max_abs, rep.processing.max_abs, rep.saturation.max_abs
    );
    let unit8 = TanhUnit::new(TanhConfig::s3_5())?;
    print!("8-bit ULP histogram:");
    for (ulp, count) in ulp_histogram(&unit8, 3) {
        print!("  {ulp} ulp: {count}");
    }
    println!();

    // 6. Sigmoid comes free (same unit, 1-bit pre-shift).
    println!("\nsigmoid(1.0) = {:.6} (true {:.6})",
             unit.sigmoid_f64(1.0), 1.0 / (1.0 + (-1.0f64).exp()));
    Ok(())
}
