//! Accelerator-level accuracy experiment (paper §I motivation): train a
//! float MLP, run quantized inference on the accelerator simulator with
//! different activation hardware, and compare network accuracy; then
//! drive a fixed-point LSTM and measure state drift vs float.
//!
//! ```bash
//! cargo run --release --example accel_inference
//! ```

use tanh_vf::accel::trainer::{blobs, spirals, Mlp};
use tanh_vf::accel::{DenseNet, LstmCellFx, MacArray};
use tanh_vf::analysis::TanhImpl;
use tanh_vf::baselines::{fmt16, lut::UniformLut, pwl::Pwl, taylor::Taylor};
use tanh_vf::fixed::{QFormat, Round};
use tanh_vf::tanh::{TanhConfig, TanhUnit};
use tanh_vf::util::rng::Rng;
use tanh_vf::util::table::Table;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rng = Rng::new(2020);

    // ---- task 1: two-spiral classification -----------------------------
    println!("== training float MLP [2,24,2] on two spirals ==");
    let (xs, ys) = spirals(200, 0.03, &mut rng);
    let mut net = Mlp::new(&[2, 24, 2], &mut rng);
    let float_acc = net.train(&xs, &ys, 100, 0.03, &mut rng);
    println!("float train accuracy: {:.1}%\n", float_acc * 100.0);

    let (fi, fo) = fmt16();
    let vf = TanhUnit::new(TanhConfig::s3_12())?;
    let vf8 = TanhUnit::new(TanhConfig::s3_5())?;
    let pwl = Pwl::new(fi, fo, 32);
    let lut256 = UniformLut::new(fi, fo, 256);
    let lut16 = UniformLut::new(fi, fo, 16);
    let taylor3 = Taylor::new(fi, fo, 3);
    let acts: Vec<(&str, &dyn TanhImpl)> = vec![
        ("velocity-factor s3.12", &vf),
        ("velocity-factor s3.5", &vf8),
        ("PWL[32]", &pwl),
        ("uniform-LUT[256]", &lut256),
        ("uniform-LUT[16] (crude)", &lut16),
        ("Taylor[3]", &taylor3),
    ];

    println!("== quantized inference accuracy (w: s2.9, act: s3.12) ==\n");
    let mut t = Table::new(&["activation hardware", "accuracy", "drop vs float"]);
    for (name, act) in &acts {
        let dn = DenseNet::from_float(
            &net.layers(),
            QFormat::new(2, 9),
            QFormat::new(3, 12),
            *act,
        );
        let acc = dn.accuracy(&xs, &ys);
        t.row(&[
            name.to_string(),
            format!("{:.1}%", acc * 100.0),
            format!("{:+.1}pp", (acc - float_acc) * 100.0),
        ]);
    }
    println!("{}", t.render());

    // ---- task 2: blobs (easy) ------------------------------------------
    println!("== 3-class blobs (easy task, activation choice matters less) ==\n");
    let (bx, by) = blobs(3, 100, &mut rng);
    let mut bnet = Mlp::new(&[2, 16, 3], &mut rng);
    let bacc = bnet.train(&bx, &by, 40, 0.05, &mut rng);
    let mut t = Table::new(&["activation hardware", "accuracy"]);
    t.row(&["float".into(), format!("{:.1}%", bacc * 100.0)]);
    for (name, act) in &acts[..4] {
        let dn = DenseNet::from_float(
            &bnet.layers(),
            QFormat::new(2, 9),
            QFormat::new(3, 12),
            *act,
        );
        t.row(&[name.to_string(), format!("{:.1}%", dn.accuracy(&bx, &by) * 100.0)]);
    }
    println!("{}", t.render());

    // ---- task 3: LSTM state drift over a long sequence -----------------
    println!("== fixed-point LSTM drift over 64 steps (hidden=16) ==\n");
    let hid = 16usize;
    let input = 8usize;
    let wfmt = QFormat::new(1, 10);
    let afmt = QFormat::new(3, 12);
    let mk = |rng: &mut Rng, r: usize, c: usize, s: f64| -> Vec<Vec<f64>> {
        (0..r).map(|_| (0..c).map(|_| rng.normal() * s).collect()).collect()
    };
    let wx_f = mk(&mut rng, 4 * hid, input, 0.25);
    let wh_f = mk(&mut rng, 4 * hid, hid, 0.25);
    let b_f: Vec<f64> = (0..4 * hid).map(|_| rng.normal() * 0.05).collect();
    let seq: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..input).map(|_| rng.normal() * 0.7).collect())
        .collect();

    let q = |m: &Vec<Vec<f64>>| -> Vec<Vec<i64>> {
        m.iter()
            .map(|r| r.iter().map(|&v| wfmt.quantize(v, Round::Nearest)).collect())
            .collect()
    };
    let mut t = Table::new(&["activation hardware", "max |h - h_float|", "rms"]);
    for (name, act) in &acts[..4] {
        let cell = LstmCellFx {
            mac: MacArray::new(wfmt, afmt),
            wx: q(&wx_f),
            wh: q(&wh_f),
            b: b_f.iter().map(|&v| afmt.quantize(v, Round::Nearest)).collect(),
            act: *act,
            hidden: hid,
        };
        // Fixed-point trajectory.
        let mut h = vec![0i64; hid];
        let mut c = vec![0i64; hid];
        // Float trajectory.
        let sig = |v: f64| 1.0 / (1.0 + (-v).exp());
        let mut hf = vec![0.0f64; hid];
        let mut cf = vec![0.0f64; hid];
        let mut max_d = 0.0f64;
        let mut sq = 0.0f64;
        let mut count = 0u64;
        for x in &seq {
            let xw: Vec<i64> =
                x.iter().map(|&v| afmt.quantize(v, Round::Nearest)).collect();
            let (h2, c2) = cell.step(&xw, &h, &c);
            h = h2;
            c = c2;
            let mut z = vec![0.0f64; 4 * hid];
            for (j, zj) in z.iter_mut().enumerate() {
                *zj = (0..input).map(|k| wx_f[j][k] * x[k]).sum::<f64>()
                    + (0..hid).map(|k| wh_f[j][k] * hf[k]).sum::<f64>()
                    + b_f[j];
            }
            for j in 0..hid {
                cf[j] = sig(z[hid + j]) * cf[j]
                    + sig(z[j]) * z[2 * hid + j].tanh();
                hf[j] = sig(z[3 * hid + j]) * cf[j].tanh();
            }
            for j in 0..hid {
                let d = (afmt.dequantize(h[j]) - hf[j]).abs();
                max_d = max_d.max(d);
                sq += d * d;
                count += 1;
            }
        }
        t.row(&[
            name.to_string(),
            format!("{max_d:.4}"),
            format!("{:.5}", (sq / count as f64).sqrt()),
        ]);
    }
    println!("{}", t.render());
    println!("note: drift includes weight/MAC quantization common to all rows;\n\
              the activation-specific component is the row-to-row delta.");
    Ok(())
}
