//! END-TO-END DRIVER: the full four-layer system on a real workload.
//!
//! Phase 0 boots the L4 HTTP front-end over a two-precision route table
//! and serves mixed-precision traffic through real sockets, verifying a
//! sample against the golden model. Phases 1-2 then start the rust
//! coordinator directly, load the AOT-compiled JAX/Pallas artifacts
//! through PJRT, serve a batched activation + LSTM-inference workload,
//! verify bit-exactness on the fly, and report latency/throughput —
//! proving L1 (Pallas kernel), L2 (JAX model), L3 (rust coordinator)
//! and L4 (HTTP server) compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_activations
//! ```
//! (Phase 0 runs even without artifacts; the PJRT phases skip.)

use std::time::{Duration, Instant};

use tanh_vf::coordinator::{native_factory, pjrt_factory, Config, Coordinator};
use tanh_vf::runtime::{artifacts_dir, Runtime, Tensor};
use tanh_vf::server::loadgen::{self, LoadgenConfig};
use tanh_vf::server::{named_config, parse_routes, Server, ServerConfig};
use tanh_vf::tanh::golden::tanh_golden_batch;
use tanh_vf::tanh::TanhConfig;
use tanh_vf::util::rng::Rng;
use tanh_vf::util::table::Table;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---------------------------------------------------------------
    // Phase 0: the HTTP front door (L4) over two native precisions.
    // ---------------------------------------------------------------
    println!("== phase 0: HTTP activation service (L4) ==\n");
    {
        let routes = parse_routes("native:s3_12,native:s3_5")?;
        let mut srv = Server::start(
            ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
            routes,
        )?;
        let addr = srv.local_addr().to_string();
        println!("listening on http://{addr}");
        let (_, models) = loadgen::http_get(&addr, "/v1/models")?;
        println!("GET /v1/models -> {models}");

        // Spot-check bit-exactness through the socket.
        let words: Vec<i32> = (-8..8).map(|i| i * 500).collect();
        let got = loadgen::eval_words(&addr, "s3_12", &words)?;
        let want = tanh_golden_batch(
            &words.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            &named_config("s3_12")?,
        );
        assert_eq!(
            got.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            want,
            "HTTP path returned non-golden words"
        );
        println!("POST /v1/batch spot-check: bit-exact vs golden model");

        // Closed-loop mixed-precision load.
        let mut lg = LoadgenConfig::new(addr.clone(), &["s3_12", "s3_5"]);
        lg.connections = 4;
        lg.requests_per_connection = 100;
        lg.words_per_request = 64;
        let report = loadgen::run(&lg)?;
        assert_eq!(report.failures, 0, "{}", report.render());
        println!("loadgen: {}", report.render());
        srv.shutdown();
        println!("graceful shutdown: ok\n");
    }

    if !artifacts_dir().join("manifest.json").exists() {
        println!(
            "artifacts missing — run `make artifacts` for the PJRT phases \
             (1-2); HTTP phase (0) completed."
        );
        return Ok(());
    }

    // ---------------------------------------------------------------
    // Phase 1: serve batched tanh through BOTH backends; verify + time.
    // ---------------------------------------------------------------
    let n_requests = 400;
    let mut results = Table::new(&[
        "backend", "req/s", "words/s", "p50 us", "p99 us", "batches",
        "fill", "verified",
    ]);
    for backend_name in ["native", "pjrt"] {
        let factory = match backend_name {
            "native" => native_factory(TanhConfig::s3_12(), true),
            _ => pjrt_factory(artifacts_dir(), "tanh_s3_12".to_string()),
        };
        let c = Coordinator::start(
            Config {
                batch_capacity: 1024,
                max_wait: Duration::from_millis(2),
                workers: 2,
                queue_limit: 8192,
            },
            factory,
        );
        // Warm up: force backend construction + PJRT compilation to
        // finish before the timed window (compile is a one-off cost
        // amortized by the executable cache).
        c.eval_blocking(vec![0i32; 16]).map_err(|e| e.to_string())?;

        let mut rng = Rng::new(7);
        let reqs: Vec<Vec<i32>> = (0..n_requests)
            .map(|_| {
                let len = 1 + rng.below(300) as usize;
                (0..len).map(|_| rng.range_i64(-32768, 32768) as i32).collect()
            })
            .collect();
        let t0 = Instant::now();
        let handles: Vec<_> = reqs.iter().map(|r| c.submit(r.clone())).collect();
        let mut words = 0usize;
        let mut verified = true;
        for (req, h) in reqs.iter().zip(handles) {
            let out = h.recv().ok_or("dropped")?.map_err(|e| e.to_string())?;
            words += out.len();
            let want = tanh_golden_batch(
                &req.iter().map(|&w| w as i64).collect::<Vec<_>>(),
                &TanhConfig::s3_12(),
            );
            verified &=
                out.iter().map(|&v| v as i64).collect::<Vec<_>>() == want;
        }
        let dt = t0.elapsed();
        let s = c.snapshot();
        results.row(&[
            backend_name.to_string(),
            format!("{:.0}", n_requests as f64 / dt.as_secs_f64()),
            format!("{:.2e}", words as f64 / dt.as_secs_f64()),
            format!("{}", s.p50_latency_us),
            format!("{}", s.p99_latency_us),
            format!("{}", s.batches),
            format!("{:.2}", s.mean_batch_fill),
            if verified { "bit-exact".into() } else { "MISMATCH".into() },
        ]);
        assert!(verified, "{backend_name} returned non-golden results");
    }
    println!("== batched tanh serving ({n_requests} variable-size requests) ==\n");
    println!("{}", results.render());

    // ---------------------------------------------------------------
    // Phase 2: LSTM sequence inference through the PJRT artifact
    // (the paper's motivating RNN workload, L2 scan over T=8).
    // ---------------------------------------------------------------
    println!("== LSTM sequence inference via PJRT (lstm_seq_b16: T=8, B=16, H=64) ==\n");
    let rt = Runtime::new(&artifacts_dir())?;
    let entry = rt.entry("lstm_seq_b16")?;
    let mut rng = Rng::new(17);
    let mut mk = |n: usize, s: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * s) as f32).collect()
    };
    let sizes: Vec<usize> = entry.inputs.iter().map(|s| s.elements()).collect();
    let inputs = vec![
        Tensor::F32(mk(sizes[0], 0.8)),  // xs
        Tensor::F32(vec![0.0; sizes[1]]), // h0
        Tensor::F32(vec![0.0; sizes[2]]), // c0
        Tensor::F32(mk(sizes[3], 0.2)),  // wx
        Tensor::F32(mk(sizes[4], 0.2)),  // wh
        Tensor::F32(mk(sizes[5], 0.05)), // b
    ];
    rt.ensure_compiled("lstm_seq_b16")?; // compile outside the timed loop
    let iters = 30;
    let t0 = Instant::now();
    let mut checksum = 0.0f64;
    for _ in 0..iters {
        let out = rt.execute("lstm_seq_b16", &inputs)?;
        let h = out[0].as_f32().unwrap();
        checksum += h.iter().map(|&v| v as f64).sum::<f64>();
        assert!(h.iter().all(|v| v.abs() < 1.0), "LSTM h must stay bounded");
    }
    let dt = t0.elapsed();
    let steps = iters * 8 * 16; // iterations * T * batch
    println!(
        "{} LSTM cell-steps in {:?}  ->  {:.0} cell-steps/s (checksum {:.3})",
        steps,
        dt,
        steps as f64 / dt.as_secs_f64(),
        checksum
    );
    println!("\nEND-TO-END OK: Pallas kernel -> JAX model -> HLO artifact -> \
              PJRT -> rust coordinator, bit-exact against the golden model.");
    Ok(())
}
