//! PPA exploration: the paper's scalability claim, quantified.
//!
//! Sweeps precision, LUT grouping, pipeline depth and cell library;
//! prints the accuracy-vs-cost Pareto the "easily tuned for different
//! accuracy and precision requirements" abstract sentence promises.
//!
//! ```bash
//! cargo run --release --example ppa_explorer
//! ```

use tanh_vf::analysis::exhaustive_error;
use tanh_vf::gates::CellClass;
use tanh_vf::synth::ppa::ppa_for;
use tanh_vf::tanh::{Subtractor, TanhConfig, TanhUnit};
use tanh_vf::util::table::{sci, Table};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- sweep 1: precision scaling -------------------------------------
    println!("== precision scaling (g=4, shuffle, NR3, SVT 2-stage) ==\n");
    let mut t = Table::new(&[
        "format", "max err", "lsb", "area um2", "fmax MHz", "levels",
    ]);
    for (ii, if_, of, lb, mb, g) in [
        (2u32, 4u32, 6u32, 9u32, 8u32, 3u32),
        (3, 5, 7, 10, 9, 3),
        (3, 7, 9, 12, 11, 3),
        (3, 9, 11, 14, 12, 4),
        (3, 12, 15, 18, 16, 4),
        (4, 13, 17, 20, 18, 4),
    ] {
        let cfg = TanhConfig {
            in_int: ii, in_frac: if_, out_frac: of, lut_bits: lb,
            mult_bits: mb, lut_group: g, shuffle: true, nr_stages: 3,
            subtractor: Subtractor::Twos,
        };
        let unit = TanhUnit::new(cfg)?;
        let e = exhaustive_error(&unit);
        let r = ppa_for(&cfg, CellClass::Svt, 2);
        t.row(&[
            format!("s{ii}.{if_}->s.{of}"),
            sci(e.max_abs),
            format!("{:.2}", e.max_lsb(cfg.out_format())),
            format!("{:.0}", r.area_um2),
            format!("{:.0}", r.fmax_mhz),
            format!("{}", r.logic_levels),
        ]);
    }
    println!("{}", t.render());

    // --- sweep 2: LUT grouping (multiplier count vs ROM size) -----------
    println!("== LUT grouping at s3.12 (paper §IV.B.3) ==\n");
    let mut t = Table::new(&[
        "group", "LUTs", "chain muls", "ROM bits", "max err", "area um2",
    ]);
    for g in 1..=5u32 {
        let cfg = TanhConfig::s3_12().with_group(g);
        let unit = TanhUnit::new(cfg)?;
        let e = exhaustive_error(&unit);
        let r = ppa_for(&cfg, CellClass::Svt, 2);
        let rom_bits: u64 = cfg
            .group_positions()
            .iter()
            .map(|p| (1u64 << p.len()) * (cfg.lut_bits as u64 + 1))
            .sum();
        t.row(&[
            format!("{g}"),
            format!("{}", cfg.num_groups()),
            format!("{}", cfg.num_groups() - 1),
            format!("{rom_bits}"),
            sci(e.max_abs),
            format!("{:.0}", r.area_um2),
        ]);
    }
    println!("{}", t.render());

    // --- sweep 3: pipeline depth x library ------------------------------
    println!("== pipeline depth x cell library at s3.12 ==\n");
    let mut t = Table::new(&[
        "stages", "SVT MHz", "SVT um2", "SVT uW", "LVT MHz", "LVT um2",
        "LVT uW",
    ]);
    for stages in [1u32, 2, 3, 4, 5, 7, 10] {
        let s = ppa_for(&TanhConfig::s3_12(), CellClass::Svt, stages);
        let l = ppa_for(&TanhConfig::s3_12(), CellClass::Lvt, stages);
        t.row(&[
            format!("{stages}"),
            format!("{:.0}", s.fmax_mhz),
            format!("{:.0}", s.area_um2),
            format!("{:.2}", s.leakage_uw),
            format!("{:.0}", l.fmax_mhz),
            format!("{:.0}", l.area_um2),
            format!("{:.2}", l.leakage_uw),
        ]);
    }
    println!("{}", t.render());

    // --- sweep 4: throughput per area (the deployment metric) -----------
    println!("== throughput density (Gtanh/s per mm2, SVT) ==\n");
    let mut t = Table::new(&["config", "stages", "Gtanh/s", "per mm2"]);
    for (cfg, name) in [
        (TanhConfig::s3_5(), "8-bit"),
        (TanhConfig::s3_12(), "16-bit"),
    ] {
        for stages in [1u32, 7] {
            let r = ppa_for(&cfg, CellClass::Svt, stages);
            let gops = r.fmax_mhz / 1000.0; // one result per clock
            let per_mm2 = gops / (r.area_um2 / 1e6);
            t.row(&[
                name.to_string(),
                format!("{stages}"),
                format!("{gops:.2}"),
                format!("{per_mm2:.0}"),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}
