//! Generate the "reusable RTL" deliverable: synthesizable Verilog +
//! self-checking testbench for several precision/pipeline flavours.
//!
//! ```bash
//! cargo run --release --example codegen_verilog
//! ```

use tanh_vf::gates::CellClass;
use tanh_vf::synth::ppa::ppa_for;
use tanh_vf::tanh::TanhConfig;
use tanh_vf::verilog::generate;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let out_dir = tanh_vf::util::repo_path("target/verilog");
    std::fs::create_dir_all(&out_dir)?;

    for (cfg, stages) in [
        (TanhConfig::s3_12(), 1u32),
        (TanhConfig::s3_12(), 2),
        (TanhConfig::s3_12(), 7),
        (TanhConfig::s3_5(), 1),
        (TanhConfig::s3_5(), 7),
    ] {
        let gen = generate(&cfg, stages, 256);
        let v = out_dir.join(format!("{}.v", gen.module_name));
        let tb = out_dir.join(format!("{}_tb.v", gen.module_name));
        std::fs::write(&v, &gen.module)?;
        std::fs::write(&tb, &gen.testbench)?;
        let ppa = ppa_for(&cfg, CellClass::Svt, stages);
        println!(
            "{}  ({} lines RTL, {} lines TB)  modelled: {:.0} um2 @ {:.0} MHz",
            gen.module_name,
            gen.module.lines().count(),
            gen.testbench.lines().count(),
            ppa.area_um2,
            ppa.fmax_mhz,
        );
    }
    println!("\nwrote RTL to {}", out_dir.display());
    println!("(self-checking testbenches embed 256 golden vectors each; run \
              with any Verilog simulator)");
    Ok(())
}
