//! End-to-end coordinator integration: serve batched activation traffic
//! through BOTH backends (native unit and the PJRT-compiled Pallas
//! kernel) and check bit-identical responses, batching behaviour and
//! metrics sanity.

use std::time::Duration;

use tanh_vf::coordinator::{native_factory, pjrt_factory, Config, Coordinator};
use tanh_vf::runtime::artifacts_dir;
use tanh_vf::tanh::golden::tanh_golden_batch;
use tanh_vf::tanh::TanhConfig;
use tanh_vf::util::rng::Rng;

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn requests(n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = 1 + rng.below(200) as usize;
            (0..len)
                .map(|_| rng.range_i64(-32768, 32768) as i32)
                .collect()
        })
        .collect()
}

fn expected(req: &[i32]) -> Vec<i64> {
    tanh_golden_batch(
        &req.iter().map(|&w| w as i64).collect::<Vec<_>>(),
        &TanhConfig::s3_12(),
    )
}

#[test]
fn native_backend_end_to_end() {
    let c = Coordinator::start(
        Config {
            batch_capacity: 1024,
            max_wait: Duration::from_millis(1),
            workers: 3,
            queue_limit: 1024,
        },
        native_factory(TanhConfig::s3_12(), true),
    );
    let reqs = requests(100, 1);
    let handles: Vec<_> = reqs.iter().map(|r| c.submit(r.clone())).collect();
    for (r, h) in reqs.iter().zip(handles) {
        let got = h.recv().unwrap().unwrap();
        assert_eq!(
            got.iter().map(|&v| v as i64).collect::<Vec<_>>(),
            expected(r)
        );
    }
    let s = c.snapshot();
    assert_eq!(s.completed, 100);
    assert!(s.batches <= 100);
    assert!(s.p50_latency_us <= s.p99_latency_us);
}

#[test]
fn pjrt_backend_end_to_end_bit_exact() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let c = Coordinator::start(
        Config {
            batch_capacity: 1024, // must match the artifact batch shape
            max_wait: Duration::from_millis(5),
            workers: 1,
            queue_limit: 1024,
        },
        pjrt_factory(artifacts_dir(), "tanh_s3_12".to_string()),
    );
    let reqs = requests(40, 2);
    let handles: Vec<_> = reqs.iter().map(|r| c.submit(r.clone())).collect();
    for (r, h) in reqs.iter().zip(handles) {
        let got = h
            .recv_timeout(Duration::from_secs(120))
            .expect("response")
            .expect("pjrt execution");
        assert_eq!(
            got.iter().map(|&v| v as i64).collect::<Vec<_>>(),
            expected(r),
            "pjrt response must be bit-identical to the golden model"
        );
    }
    let s = c.snapshot();
    assert_eq!(s.completed, 40);
    // Co-batching must amortize PJRT dispatch.
    assert!(s.batches < 40, "batches {}", s.batches);
}

#[test]
fn native_and_pjrt_agree_under_same_traffic() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let native = Coordinator::start(
        Config::default(),
        native_factory(TanhConfig::s3_12(), false),
    );
    let pjrt = Coordinator::start(
        Config {
            batch_capacity: 1024,
            max_wait: Duration::from_millis(5),
            workers: 1,
            queue_limit: 1024,
        },
        pjrt_factory(artifacts_dir(), "tanh_s3_12".to_string()),
    );
    for r in requests(10, 3) {
        let a = native.eval_blocking(r.clone()).unwrap();
        let b = pjrt.eval_blocking(r).unwrap();
        assert_eq!(a, b);
    }
}
