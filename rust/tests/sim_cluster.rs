//! Deterministic cluster simulation: thousands of seeded fault
//! schedules driven entirely under virtual time — no real sockets, no
//! real sleeps (see `tanh_vf::server::sim`).
//!
//! Every scenario runs N-node clusters in-process over a `SimNet`,
//! injects partitions / message loss / delay / slow peers / restarts on
//! a seed-derived schedule, and asserts the cluster invariants:
//!
//! * gossip convergence after partitions heal (ring agreement,
//!   observer agreement, no up node left for dead),
//! * incarnation monotonicity and death-certificate refutation,
//! * the retry contract of the pooled client leg (never retry a
//!   timeout, never lose an acknowledged request),
//! * bounded virtual cost of gossiping with a stalled `--join` seed,
//! * load-adaptive routing (PR 10): hot-route expansion under zipfian
//!   skew beats the frozen-ring baseline's owner queue on the same
//!   seeded schedule, hysteresis keeps a flapping load from flapping
//!   the replica count, replica claims raised on both sides of a
//!   partition converge to one set after heal, p2c picks stay inside
//!   the replica set, and a stanza-less pre-PR-10 peer still
//!   interoperates.
//!
//! Any violation panics with the offending seed;
//! `TANHVF_SIM_SEED=<seed> cargo test -q sim_<name>` replays that one
//! schedule deterministically. `TANHVF_SIM_BASE_SEED` shifts a whole
//! suite (the CI randomized pass logs the base it used).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use tanh_vf::server::cluster::{
    Cluster, ClusterConfig, Node, HOT_COOLDOWN_ROUNDS,
};
use tanh_vf::server::gossip;
use tanh_vf::server::sim::{
    assert_converged, converged, scenario_rng, schedule_seeds, Handler,
    IncarnationMonitor, SimNet,
};
use tanh_vf::util::json::{self, Json};
use tanh_vf::util::rng::SplitMix64;

fn ms(n: u64) -> Duration {
    Duration::from_millis(n)
}

const PROBE_INTERVAL_MS: u64 = 100;
/// One seed-backoff period: the shortest delay `gossip_round` hands a
/// failing `--join` seed (2 rounds ≈ 2 probe intervals) — the bound the
/// per-leg gossip deadlines must keep one stalled exchange under.
const BACKOFF_PERIOD_MS: u64 = 2 * PROBE_INTERVAL_MS;

fn node_config(addr: &str, incarnation: u64) -> ClusterConfig {
    ClusterConfig {
        advertise: addr.to_string(),
        virtual_nodes: 16,
        probe_interval: ms(PROBE_INTERVAL_MS),
        probe_timeout: ms(PROBE_INTERVAL_MS),
        failure_threshold: 1,
        recovery_threshold: 1,
        proxy_timeout: ms(200),
        incarnation: Some(incarnation),
        manual_rounds: true,
        ..Default::default()
    }
}

/// A node with every other address as a *static* peer: immediately a
/// ring member, and its probe slot survives its tombstone — probing is
/// the resurrection path after a heal.
fn start_static_node(
    net: &Arc<SimNet>,
    addr: &str,
    addrs: &[String],
    incarnation: u64,
) -> Arc<Cluster> {
    let cfg = ClusterConfig {
        peers: addrs.iter().filter(|p| *p != addr).cloned().collect(),
        ..node_config(addr, incarnation)
    };
    Cluster::start_with_transport(cfg, net.transport(addr)).unwrap()
}

/// A node that knows the others only as `--join` gossip seeds: a
/// member's probe slot dies with it, so a tombstoned node can ONLY
/// re-enter by gossiping a refutation itself.
fn start_join_node(
    net: &Arc<SimNet>,
    addr: &str,
    addrs: &[String],
    incarnation: u64,
) -> Arc<Cluster> {
    let cfg = ClusterConfig {
        join: addrs.iter().filter(|p| *p != addr).cloned().collect(),
        ..node_config(addr, incarnation)
    };
    Cluster::start_with_transport(cfg, net.transport(addr)).unwrap()
}

/// Full static mesh on `net`, tight thresholds (1 failed probe evicts,
/// 1 success re-admits, death after `DEATH_FACTOR` failed rounds),
/// `manual_rounds` so the test drives every round under virtual time.
fn start_mesh(
    net: &Arc<SimNet>,
    addrs: &[String],
    base_inc: u64,
) -> Vec<Arc<Cluster>> {
    let clusters: Vec<Arc<Cluster>> = addrs
        .iter()
        .enumerate()
        .map(|(i, a)| start_static_node(net, a, addrs, base_inc + i as u64))
        .collect();
    for (a, c) in addrs.iter().zip(&clusters) {
        net.register_cluster(a, c);
    }
    clusters
}

/// One cluster-wide membership round under virtual time: each node
/// probes + gossips in a fixed order, then the interval elapses.
fn drive_round(
    net: &Arc<SimNet>,
    clusters: &[Arc<Cluster>],
    down: &BTreeSet<String>,
) {
    for c in clusters {
        if !down.contains(c.self_name()) {
            c.membership_round();
        }
    }
    net.advance(PROBE_INTERVAL_MS);
}

fn observe_all(
    monitor: &mut IncarnationMonitor,
    clusters: &[Arc<Cluster>],
    down: &BTreeSet<String>,
    seed: u64,
) {
    for c in clusters {
        if !down.contains(c.self_name()) {
            monitor.observe(c.self_name(), &c.members(), seed);
        }
    }
}

/// Drive rounds until the up set converges (or a generous round bound
/// runs out — then panic with the seed).
fn converge(
    net: &Arc<SimNet>,
    clusters: &[Arc<Cluster>],
    up: &BTreeSet<String>,
    monitor: &mut IncarnationMonitor,
    seed: u64,
    ctx: &str,
) {
    let none = BTreeSet::new();
    for _ in 0..50 {
        if converged(clusters, up).is_none() {
            return;
        }
        drive_round(net, clusters, &none);
        observe_all(monitor, clusters, &none, seed);
    }
    assert_converged(clusters, up, seed, ctx);
}

fn addrs(n: usize, prefix: &str) -> Vec<String> {
    (0..n).map(|i| format!("{prefix}{i}:7")).collect()
}

/// Symmetric partitions: a seed-chosen victim group is blackholed from
/// the rest (both directions) for a seed-chosen number of rounds —
/// sometimes short of the death threshold, sometimes far past it (full
/// mutual tombstoning). After healing, the cluster must re-converge:
/// identical rings covering every node, observers agreeing on every
/// third-party member, incarnations never regressing at any observer.
#[test]
fn sim_gossip_convergence_after_symmetric_partition() {
    for seed in schedule_seeds(0x51A1, 300) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(5, "p");
        let clusters = start_mesh(&net, &names, 100);
        let mut monitor = IncarnationMonitor::new();
        let none = BTreeSet::new();

        // Let the mesh learn real incarnations first.
        for _ in 0..2 {
            drive_round(&net, &clusters, &none);
            observe_all(&mut monitor, &clusters, &none, seed);
        }

        // Cut 1-2 victims off from the rest, both directions.
        let victims: Vec<&String> = if rng.chance(1, 3) {
            vec![&names[rng.below(5) as usize]]
        } else {
            let a = rng.below(5) as usize;
            let b = (a + 1 + rng.below(4) as usize) % 5;
            vec![&names[a], &names[b]]
        };
        for v in &victims {
            for other in names.iter().filter(|o| !victims.contains(o)) {
                net.partition_pair(v, other);
            }
        }
        // 2..=16 partitioned rounds: death certificates appear past
        // DEATH_FACTOR (10) failed probe rounds.
        let cut_rounds = 2 + rng.below(15);
        for _ in 0..cut_rounds {
            drive_round(&net, &clusters, &none);
            observe_all(&mut monitor, &clusters, &none, seed);
        }

        net.heal_all();
        let up: BTreeSet<String> = names.iter().cloned().collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "symmetric heal");
        for c in &clusters {
            c.stop();
        }
    }
}

/// Asymmetric faults: one-directional blackholes and one-directional
/// response delays (some past the probe/gossip read budgets, so one
/// side believes a peer dead while the reverse direction still works —
/// including refute/re-kill incarnation churn). Healing must still
/// converge every observer to one view.
#[test]
fn sim_gossip_convergence_under_asymmetric_partition_and_delay() {
    for seed in schedule_seeds(0xA57, 250) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(4, "a");
        let clusters = start_mesh(&net, &names, 200);
        let mut monitor = IncarnationMonitor::new();
        let none = BTreeSet::new();

        for _ in 0..2 {
            drive_round(&net, &clusters, &none);
            observe_all(&mut monitor, &clusters, &none, seed);
        }

        // 1-3 one-way blackholes plus 1-3 one-way delays (0..150 ms
        // virtual — beyond 99 ms a probe response misses its read
        // deadline, beyond the gossip leg budget an exchange fails).
        let mut delayed: Vec<(String, String)> = Vec::new();
        for _ in 0..1 + rng.below(3) {
            let f = rng.below(4) as usize;
            let t = (f + 1 + rng.below(3) as usize) % 4;
            net.partition(&names[f], &names[t]);
        }
        for _ in 0..1 + rng.below(3) {
            let f = rng.below(4) as usize;
            let t = (f + 1 + rng.below(3) as usize) % 4;
            net.set_delay(&names[f], &names[t], rng.below(150));
            delayed.push((names[f].clone(), names[t].clone()));
        }
        let cut_rounds = 2 + rng.below(13);
        for _ in 0..cut_rounds {
            drive_round(&net, &clusters, &none);
            observe_all(&mut monitor, &clusters, &none, seed);
        }

        net.heal_all();
        for (f, t) in &delayed {
            net.set_delay(f, t, 0);
        }
        let up: BTreeSet<String> = names.iter().cloned().collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "asymmetric heal");
        for c in &clusters {
            c.stop();
        }
    }
}

/// Kill a node long enough for the survivors to tombstone it, then
/// restart it as a NEW cluster instance with a *lower* incarnation than
/// its death certificate (a rebooted process has no memory of its old
/// one). The mesh is join-seeded, not static: a tombstoned member loses
/// its probe slot, so no survivor can probe-resurrect it — re-entry is
/// forced through the refutation path. The restarted node must see the
/// dead report about itself, out-bid the certificate, and end alive in
/// every table strictly above it.
#[test]
fn sim_death_and_rejoin_refutation() {
    for seed in schedule_seeds(0xDEAD, 200) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(4, "r");
        let clusters: Vec<Arc<Cluster>> = names
            .iter()
            .enumerate()
            .map(|(i, a)| start_join_node(&net, a, &names, 50 + i as u64))
            .collect();
        for (a, c) in names.iter().zip(&clusters) {
            net.register_cluster(a, c);
        }
        let mut monitor = IncarnationMonitor::new();
        let up: BTreeSet<String> = names.iter().cloned().collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "join warmup");

        let vi = rng.below(4) as usize;
        let victim = names[vi].clone();
        let victim_inc = {
            let members = clusters[vi].members();
            members[&victim].incarnation
        };
        net.crash(&victim);
        let down: BTreeSet<String> = [victim.clone()].into();
        // Past the death threshold (DEATH_FACTOR rounds at
        // failure_threshold 1) plus seed-chosen slack: every survivor
        // holds a death certificate for the victim.
        let dead_rounds = u64::from(gossip::DEATH_FACTOR) + 2 + rng.below(5);
        for _ in 0..dead_rounds {
            drive_round(&net, &clusters, &down);
            observe_all(&mut monitor, &clusters, &down, seed);
        }
        for c in clusters.iter().filter(|c| c.self_name() != victim) {
            let members = c.members();
            let m = &members[&victim];
            assert!(
                !m.alive,
                "[seed {seed}] survivor {} still sees {victim} alive \
                 after {dead_rounds} dead rounds",
                c.self_name()
            );
        }
        let cert = monitor.death_cert(&victim);
        assert!(
            cert >= victim_inc,
            "[seed {seed}] death certificate {cert} below the victim's \
             incarnation {victim_inc}"
        );

        // "Process restart": a brand-new Cluster under the same address
        // with an incarnation far below the certificate.
        let restarted = start_join_node(&net, &victim, &names, 1);
        net.register_cluster(&victim, &restarted);
        let clusters: Vec<Arc<Cluster>> = clusters
            .into_iter()
            .map(|c| {
                if c.self_name() == victim {
                    restarted.clone()
                } else {
                    c
                }
            })
            .collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "rejoin");

        // The rejoin must have out-bid the certificate everywhere —
        // including in the restarted node's own table.
        for c in &clusters {
            let members = c.members();
            let m = &members[&victim];
            assert!(
                m.alive && m.incarnation > cert,
                "[seed {seed}] {} sees {victim} as {m:?}, want alive past \
                 certificate {cert} (replay: TANHVF_SIM_SEED={seed} \
                 cargo test -q sim_death)",
                c.self_name()
            );
        }
        // And re-entry actually went through refutation (the satellite
        // counter surfaced on /metrics).
        assert!(
            restarted.stats.gossip_refutations.load(Ordering::Relaxed) >= 1,
            "[seed {seed}] rejoin converged without a refutation"
        );
        for c in &clusters {
            c.stop();
        }
    }
}

/// A stalled/blackholed `--join` seed must cost the membership loop at
/// most one seed-backoff period per gossip round (the per-leg gossip
/// deadline satellite): measure the virtual cost of every round while
/// the seed is stalled in a seed-chosen way and check the bound, plus
/// the exponential backoff actually suppressing most attempts.
#[test]
fn sim_slow_peer_and_deadline_bounds() {
    for seed in schedule_seeds(0x510, 150) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let seed_addr = "stalled-seed:7".to_string();
        // The seed exists but never usefully answers: responses beyond
        // any read budget, connects blackholed, or requests dropped.
        let idle: Handler = Arc::new(|_m, _p, _h, _b: &[u8]| (200, Vec::new()));
        net.register(&seed_addr, idle);
        let joiner = Cluster::start_with_transport(
            ClusterConfig {
                join: vec![seed_addr.clone()],
                ..node_config("joiner:7", 7)
            },
            net.transport("joiner:7"),
        )
        .unwrap();
        match rng.below(3) {
            0 => net.set_slow(&seed_addr, 10_000),
            1 => net.partition("joiner:7", &seed_addr),
            _ => net.drop_requests("joiner:7", &seed_addr, 1 << 20),
        }
        let mut contact_rounds = 0u32;
        for round in 0..20 {
            let t0 = net.now_ms();
            joiner.membership_round();
            let cost = net.now_ms() - t0;
            assert!(
                cost <= BACKOFF_PERIOD_MS,
                "[seed {seed}] round {round} spent {cost} ms virtual on a \
                 stalled seed; per-leg deadlines must cap one exchange at \
                 one backoff period ({BACKOFF_PERIOD_MS} ms) \
                 (replay: TANHVF_SIM_SEED={seed} cargo test -q sim_slow)"
            );
            if cost > 0 {
                contact_rounds += 1;
            }
        }
        assert!(
            contact_rounds >= 1,
            "[seed {seed}] the joiner never even tried its seed"
        );
        assert!(
            contact_rounds <= 6,
            "[seed {seed}] {contact_rounds} contact rounds in 20: seed \
             backoff is not suppressing retries"
        );
        assert!(
            joiner.stats.gossip_fail.load(Ordering::Relaxed) >= 1,
            "[seed {seed}] stalled exchanges must count as failures"
        );
        joiner.stop();
    }
}

#[derive(PartialEq, Clone, Copy, Debug)]
enum Fault {
    None,
    RespLost,
    ReqLost,
    Partition,
    Slow,
    Restart,
}

/// The pooled client leg's retry contract, under every fault class the
/// transport distinguishes. Per operation the driver stages at most one
/// fault, then checks the pool counters against the server-side
/// execution count:
///
/// * at most two attempts, and a second attempt only after a failure
///   on a *reused* (pooled) connection;
/// * a success's response came from its own (final) execution — an
///   acknowledged request is never lost;
/// * response timeouts (request lost, slow peer) are never retried, so
///   a request is never executed twice *because of* a timeout;
/// * every double execution is a retried response-loss that ended in
///   success — re-executed XOR lost, never both.
#[test]
fn sim_pool_redial_request_invariants() {
    for seed in schedule_seeds(0xF007, 200) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let server = "srv:7".to_string();
        let serial = Arc::new(AtomicU64::new(0));
        let s2 = serial.clone();
        let handler: Handler = Arc::new(move |_m, _p, _h, _b: &[u8]| {
            let n = s2.fetch_add(1, Ordering::SeqCst) + 1;
            (200, format!("{{\"serial\":{n}}}").into_bytes())
        });
        net.register(&server, handler);
        let client = Cluster::start_with_transport(
            node_config("cli:7", 9),
            net.transport("cli:7"),
        )
        .unwrap();

        for op in 0..20 {
            let fault = match rng.below(10) {
                0..=3 => Fault::None,
                4 | 5 => Fault::RespLost,
                6 => Fault::ReqLost,
                7 => Fault::Partition,
                8 => Fault::Slow,
                _ => Fault::Restart,
            };
            match fault {
                Fault::RespLost => net.drop_responses("cli:7", &server, 1),
                Fault::ReqLost => net.drop_requests("cli:7", &server, 1),
                Fault::Partition => net.partition("cli:7", &server),
                Fault::Slow => net.set_slow(&server, 1_000),
                Fault::Restart => {
                    net.crash(&server);
                    net.restart(&server);
                }
                Fault::None => {}
            }
            let h0 = client.pool.stats.hits.load(Ordering::Relaxed);
            let m0 = client.pool.stats.misses.load(Ordering::Relaxed);
            let e0 = net.executions(&server);

            let result = client.forward(&server, "/op", b"{}", &[]);

            let dh = client.pool.stats.hits.load(Ordering::Relaxed) - h0;
            let dm = client.pool.stats.misses.load(Ordering::Relaxed) - m0;
            let de = net.executions(&server) - e0;
            let attempts = dh + dm;
            let ctx = format!(
                "[seed {seed}] op {op} fault {fault:?} attempts {attempts} \
                 (hits {dh}, misses {dm}) executions {de} ok={} \
                 (replay: TANHVF_SIM_SEED={seed} cargo test -q sim_pool)",
                result.is_ok()
            );
            assert!((1..=2).contains(&attempts), "{ctx}");
            if attempts == 2 {
                assert_eq!(dh, 1, "retry without a pooled first attempt: {ctx}");
            }
            assert!(de <= 2, "more than two executions for one op: {ctx}");
            if de == 2 {
                // Double execution is legal ONLY as a retried response
                // loss that ultimately succeeded.
                assert!(
                    fault == Fault::RespLost && attempts == 2 && result.is_ok(),
                    "unexplained double execution: {ctx}"
                );
            }
            match fault {
                Fault::ReqLost | Fault::Partition => {
                    // The request vanished: the caller times out and
                    // MUST NOT retry (double-execution risk) — and the
                    // handler never ran.
                    assert!(result.is_err(), "{ctx}");
                    assert_eq!(attempts, 1, "timeout was retried: {ctx}");
                    assert_eq!(de, 0, "lost request executed: {ctx}");
                }
                Fault::Slow => {
                    // Executed, but the response missed the deadline:
                    // surfaced as a failure, never retried.
                    assert!(result.is_err(), "{ctx}");
                    assert_eq!(attempts, 1, "timeout was retried: {ctx}");
                    assert_eq!(de, 1, "{ctx}");
                }
                Fault::None | Fault::Restart => {
                    // Always recoverable: a stale pooled connection
                    // fails retryably and the fresh dial succeeds.
                    assert!(result.is_ok(), "{ctx}");
                    assert_eq!(de, 1, "{ctx}");
                }
                Fault::RespLost => {
                    // Pooled first attempt: retried to success (two
                    // executions, the answer is the second's). Fresh
                    // first attempt: surfaced as a failure (one
                    // execution, response lost — the "lost" half, never
                    // ALSO re-executed).
                    if dh == 1 {
                        assert!(result.is_ok(), "{ctx}");
                        assert_eq!((attempts, de), (2, 2), "{ctx}");
                    } else {
                        assert!(result.is_err(), "{ctx}");
                        assert_eq!((attempts, de), (1, 1), "{ctx}");
                    }
                }
            }
            if let Ok(resp) = result {
                // An acknowledged response is the final execution's —
                // a lost/abandoned attempt's answer is never served.
                let body = String::from_utf8(resp.body).unwrap();
                let want =
                    format!("{{\"serial\":{}}}", serial.load(Ordering::SeqCst));
                assert_eq!(body, want, "{ctx}");
            }
            // Clear whatever fault state persists across operations.
            match fault {
                Fault::Partition => net.heal("cli:7", &server),
                Fault::Slow => net.set_slow(&server, 0),
                _ => {}
            }
        }
        client.stop();
    }
}

/// Zipf CDF over `n` ranks with exponent `s` (rank 0 hottest) — the
/// same shape `loadgen --zipf` draws from.
fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0;
    for k in 0..n {
        acc += 1.0 / ((k + 1) as f64).powf(s);
        cdf.push(acc);
    }
    for c in &mut cdf {
        *c /= acc;
    }
    cdf
}

fn zipf_draw(cdf: &[f64], rng: &mut SplitMix64) -> usize {
    let u = (rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    cdf.partition_point(|&c| c < u).min(cdf.len() - 1)
}

/// Outcome of one seeded zipf-skew schedule. The adaptive and frozen
/// runs replay the exact same workload draws against the same ring
/// and service model — only `load_adaptive` differs.
struct SkewOutcome {
    /// Highest modeled queue depth the hot route's (pre-expansion)
    /// owner reached across all rounds.
    peak_owner_queue: u64,
    /// 95th-percentile per-round owner queue depth.
    p95_owner_queue: u64,
    /// Hot-route controller expansions, summed over all nodes.
    expansions: u64,
    /// Final `effective_replicas` for the hot route, per node.
    effective: Vec<usize>,
    /// First candidates chosen by p2c over gossiped loads.
    load_picks: u64,
}

/// Drive a 4-node cluster through a zipf-skewed request schedule under
/// a modeled queue: every request is noted at a round-robin ingress
/// front, routed via `candidates()`, and enqueued at the target; each
/// node then drains a fixed service rate per round and publishes its
/// modeled run-queue depth into the gossip load stanza.
fn run_zipf_schedule(seed: u64, load_adaptive: bool) -> SkewOutcome {
    const ROUNDS: usize = 46;
    const SERVICE_PER_ROUND: u64 = 100;
    let mut rng = scenario_rng(seed);
    let net = SimNet::new();
    let names = addrs(4, "z");
    let clusters: Vec<Arc<Cluster>> = names
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let cfg = ClusterConfig {
                peers: names.iter().filter(|p| *p != a).cloned().collect(),
                load_adaptive,
                ..node_config(a, 100 + i as u64)
            };
            Cluster::start_with_transport(cfg, net.transport(a)).unwrap()
        })
        .collect();
    for (a, c) in names.iter().zip(&clusters) {
        net.register_cluster(a, c);
    }
    let none = BTreeSet::new();
    let routes: Vec<String> = (0..4).map(|i| format!("zr{i}")).collect();
    let hot = routes[0].clone();
    // s=3 concentrates ~85% of draws on rank 0 — a hot route, not
    // just a warm one.
    let cdf = zipf_cdf(routes.len(), 3.0);
    // The hot route's pre-expansion owner: the node the frozen ring
    // piles every hot request onto.
    let owner = clusters[0].owner_name(&hot).unwrap();
    let mut queue: BTreeMap<String, u64> =
        names.iter().map(|n| (n.clone(), 0)).collect();
    let mut owner_series = Vec::with_capacity(ROUNDS);
    for round in 0..ROUNDS {
        let total = 224 + rng.below(64);
        for k in 0..total {
            let route = &routes[zipf_draw(&cdf, &mut rng)];
            let ing = (k as usize + round) % names.len();
            clusters[ing].note_route_request(route);
            let target =
                match clusters[ing].candidates(route).into_iter().next() {
                    Some(Node::Peer(p)) => p,
                    _ => names[ing].clone(),
                };
            *queue.get_mut(&target).unwrap() += 1;
        }
        for q in queue.values_mut() {
            *q = q.saturating_sub(SERVICE_PER_ROUND);
        }
        for (n, c) in names.iter().zip(&clusters) {
            c.load().set_queue_depth(queue[n]);
        }
        drive_round(&net, &clusters, &none);
        owner_series.push(queue[&owner]);
    }
    let peak_owner_queue = *owner_series.iter().max().unwrap();
    let mut sorted = owner_series;
    sorted.sort_unstable();
    let p95_owner_queue = sorted[(sorted.len() * 95) / 100];
    let expansions: u64 = clusters
        .iter()
        .map(|c| c.stats.route_expansions.load(Ordering::Relaxed))
        .sum();
    let load_picks: u64 = clusters
        .iter()
        .map(|c| c.stats.p2c_load_picks.load(Ordering::Relaxed))
        .sum();
    let effective: Vec<usize> =
        clusters.iter().map(|c| c.effective_replicas(&hot)).collect();
    for c in &clusters {
        c.stop();
    }
    SkewOutcome {
        peak_owner_queue,
        p95_owner_queue,
        expansions,
        effective,
        load_picks,
    }
}

/// The tentpole acceptance scenario: under a seeded zipfian workload
/// the adaptive cluster must expand the hot route, engage p2c, and
/// beat the frozen-ring baseline's owner queue by >= 1.3x — peak and
/// p95 both — on the SAME seeded schedule.
#[test]
fn sim_zipf_skew_expands_hot_route_and_drops_owner_queue() {
    for seed in schedule_seeds(0x21F, 40) {
        let adaptive = run_zipf_schedule(seed, true);
        let frozen = run_zipf_schedule(seed, false);
        let ctx = format!(
            "[seed {seed}] adaptive peak {} p95 {} expansions {} \
             effective {:?} load picks {}; frozen peak {} p95 {} \
             (replay: TANHVF_SIM_SEED={seed} cargo test -q sim_zipf)",
            adaptive.peak_owner_queue,
            adaptive.p95_owner_queue,
            adaptive.expansions,
            adaptive.effective,
            adaptive.load_picks,
            frozen.peak_owner_queue,
            frozen.p95_owner_queue,
        );
        assert_eq!(
            frozen.expansions, 0,
            "frozen ring must never expand: {ctx}"
        );
        assert!(
            frozen.peak_owner_queue > 0,
            "baseline never overloaded its owner: {ctx}"
        );
        assert!(adaptive.expansions >= 1, "hot route never expanded: {ctx}");
        assert!(adaptive.load_picks >= 1, "p2c never engaged: {ctx}");
        assert!(
            adaptive.effective.iter().all(|&e| e > 1),
            "expansion did not reach every node: {ctx}"
        );
        assert!(
            frozen.peak_owner_queue as f64
                >= 1.3 * adaptive.peak_owner_queue as f64,
            "peak owner queue not >= 1.3x lower than frozen: {ctx}"
        );
        assert!(
            frozen.p95_owner_queue as f64
                >= 1.3 * adaptive.p95_owner_queue as f64,
            "p95 owner queue not >= 1.3x lower than frozen: {ctx}"
        );
    }
}

/// Hysteresis: a request rate that flaps every round must not flap
/// the replica count. A mid-band profile (EWMA settles strictly
/// inside the expand/shrink band) makes zero transitions; a hot
/// profile (EWMA settles above the expand threshold — exactly the
/// shape a controller reacting to instantaneous rates would ping-pong
/// on ~24 times here) expands monotonically to the ring, never
/// shrinks, and spaces transitions at least one cooldown apart.
#[test]
fn sim_flapping_load_hysteresis_prevents_oscillation() {
    for seed in schedule_seeds(0xF1A, 60) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(4, "f");
        let clusters = start_mesh(&net, &names, 100);
        let none = BTreeSet::new();
        let route = "flappy";
        for _ in 0..2 {
            drive_round(&net, &clusters, &none);
        }
        let owner = clusters[0].owner_name(route).unwrap();
        let owner_cl = clusters
            .iter()
            .find(|c| c.self_name() == owner)
            .unwrap()
            .clone();
        let hot_profile = rng.chance(1, 2);
        let (high, low) = if hot_profile {
            (96 + rng.below(32), 0)
        } else {
            (30 + rng.below(8), 2 + rng.below(4))
        };
        let mut transition_rounds: Vec<usize> = Vec::new();
        let mut last = 0;
        for round in 0..48 {
            let n = if round % 2 == 0 { high } else { low };
            for _ in 0..n {
                owner_cl.note_route_request(route);
            }
            drive_round(&net, &clusters, &none);
            let now = owner_cl
                .stats
                .route_expansions
                .load(Ordering::Relaxed)
                + owner_cl.stats.route_shrinks.load(Ordering::Relaxed);
            if now != last {
                transition_rounds.push(round);
                last = now;
            }
        }
        let ctx = format!(
            "[seed {seed}] {} profile high {high} low {low} transitions \
             at rounds {transition_rounds:?} \
             (replay: TANHVF_SIM_SEED={seed} cargo test -q sim_flapping)",
            if hot_profile { "hot" } else { "mid-band" },
        );
        for w in transition_rounds.windows(2) {
            assert!(
                w[1] - w[0] >= HOT_COOLDOWN_ROUNDS as usize,
                "two transitions inside one cooldown window: {ctx}"
            );
        }
        assert_eq!(
            owner_cl.stats.route_shrinks.load(Ordering::Relaxed),
            0,
            "a flapping-but-hot load shrank its route: {ctx}"
        );
        if hot_profile {
            // Ring 4, base 1: exactly the three monotone expansions.
            assert_eq!(
                owner_cl.stats.route_expansions.load(Ordering::Relaxed),
                3,
                "{ctx}"
            );
            assert!(
                clusters.iter().all(|c| c.effective_replicas(route) == 4),
                "hot flapping must settle at full fan-out: {ctx}"
            );
        } else {
            assert!(
                transition_rounds.is_empty(),
                "mid-band flapping must make zero transitions: {ctx}"
            );
        }
        for c in &clusters {
            c.stop();
        }
    }
}

/// A partition that interrupts a hot-route expansion must not leave
/// the cluster with two replica sets. Both sides keep their own
/// steward (the heated side keeps raising, the cold side decays and
/// shrinks — each bumping epochs independently), so the halves hold
/// genuinely conflicting claims; after the heal the `(epoch,
/// replicas)` semilattice must converge every node to one winner, and
/// sustained heat must then carry the route to full fan-out
/// everywhere.
#[test]
fn sim_partition_during_expansion_heals_to_one_replica_set() {
    for seed in schedule_seeds(0x9EA1, 60) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(4, "h");
        let clusters = start_mesh(&net, &names, 100);
        let mut monitor = IncarnationMonitor::new();
        let none = BTreeSet::new();
        let route = "hotspot";
        let heat_round = |heated: &[usize]| {
            for &i in heated {
                for _ in 0..64 {
                    clusters[i].note_route_request(route);
                }
            }
            drive_round(&net, &clusters, &none);
        };
        let all: Vec<usize> = (0..names.len()).collect();

        for _ in 0..2 {
            drive_round(&net, &clusters, &none);
        }
        // Heat every front until the first expansion is in flight.
        let mut expanded = false;
        for _ in 0..10 {
            heat_round(&all);
            let n: u64 = clusters
                .iter()
                .map(|c| c.stats.route_expansions.load(Ordering::Relaxed))
                .sum();
            if n > 0 {
                expanded = true;
                break;
            }
        }
        assert!(
            expanded,
            "[seed {seed}] no expansion to interrupt (replay: \
             TANHVF_SIM_SEED={seed} cargo test -q sim_partition)"
        );

        // Cut the cluster into seed-chosen halves mid-expansion. Only
        // side A stays heated: past the death threshold each side runs
        // its own steward, so the claims diverge for real.
        let a0 = rng.below(4) as usize;
        let a1 = (a0 + 1 + rng.below(3) as usize) % 4;
        for x in [a0, a1] {
            for (y, other) in names.iter().enumerate() {
                if y != a0 && y != a1 {
                    net.partition_pair(&names[x], other);
                }
            }
        }
        let cut_rounds = 14 + rng.below(6);
        for _ in 0..cut_rounds {
            heat_round(&[a0, a1]);
        }

        net.heal_all();
        let up: BTreeSet<String> = names.iter().cloned().collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "claim heal");
        // Keep the route hot while claims re-spread, so a shrink can't
        // race the convergence this asserts; once every node holds the
        // same claim at full fan-out, the route has exactly one
        // replica set again.
        let mut agreed = false;
        for _ in 0..30 {
            heat_round(&all);
            let claim = clusters[0].route_claims().get(route).copied();
            if claim.is_some()
                && clusters.iter().all(|c| {
                    c.route_claims().get(route).copied() == claim
                        && c.effective_replicas(route) == names.len()
                })
            {
                agreed = true;
                break;
            }
        }
        let views: Vec<_> = clusters
            .iter()
            .map(|c| {
                (
                    c.self_name().to_string(),
                    c.route_claims().get(route).copied(),
                    c.effective_replicas(route),
                )
            })
            .collect();
        assert!(
            agreed,
            "[seed {seed}] nodes did not converge to one replica set \
             after heal: {views:?} (replay: TANHVF_SIM_SEED={seed} \
             cargo test -q sim_partition)"
        );
        for c in &clusters {
            c.stop();
        }
    }
}

/// p2c safety and balance properties, against a modeled queue and a
/// round-robin baseline fed the exact same draw sequence: the chosen
/// peer is always inside the key's replica set, a tombstoned member
/// is never offered as any candidate, and a heterogeneous starting
/// queue ends strictly less imbalanced than round-robin leaves it.
#[test]
fn sim_p2c_picks_stay_in_replica_set_and_beat_round_robin() {
    fn publish(cl: &Cluster, addr: &str, queue_depth: u64, version: u64) {
        cl.apply_remote_members(&[gossip::MemberEntry {
            addr: addr.to_string(),
            incarnation: 50,
            alive: true,
            load: Some(gossip::LoadInfo {
                version,
                queue_depth,
                ewma_latency_us: 10,
                arena_bytes: 0,
            }),
        }]);
    }
    for seed in schedule_seeds(0x2C5, 100) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(6, "q");
        let cfg = ClusterConfig {
            peers: names[1..].to_vec(),
            replicas: 3,
            ..node_config(&names[0], 100)
        };
        let cl = Cluster::start_with_transport(cfg, net.transport(&names[0]))
            .unwrap();
        // Tombstone one peer: it leaves the ring and must never be
        // offered again.
        let dead = names[5].clone();
        cl.apply_remote_members(&[gossip::MemberEntry {
            addr: dead.clone(),
            incarnation: 1,
            alive: false,
            load: None,
        }]);
        // An all-remote replica set isolates the p2c path (a Local
        // replica always short-circuits to serving in place).
        let key = (0..64)
            .map(|i| format!("k{i}"))
            .find(|k| {
                let reps = cl.replica_set(k);
                reps.len() == 3 && !reps.contains(&names[0])
            })
            .expect("no all-remote key among 64");
        let reps = cl.replica_set(&key);
        let mut version = 0u64;
        let mut queues: BTreeMap<String, u64> = BTreeMap::new();
        queues.insert(reps[0].clone(), 50 + rng.below(30));
        queues.insert(reps[1].clone(), rng.below(10));
        queues.insert(reps[2].clone(), 0);
        let mut rr = queues.clone();
        for r in &reps {
            version += 1;
            publish(&cl, r, queues[r], version);
        }
        const DRAWS: usize = 120;
        for i in 0..DRAWS {
            let cands = cl.candidates(&key);
            for c in &cands {
                if let Node::Peer(p) = c {
                    assert_ne!(
                        *p, dead,
                        "[seed {seed}] tombstoned peer offered as a \
                         candidate (replay: TANHVF_SIM_SEED={seed} \
                         cargo test -q sim_p2c)"
                    );
                }
            }
            let chosen = match &cands[0] {
                Node::Peer(p) => p.clone(),
                Node::Local => panic!(
                    "[seed {seed}] p2c chose Local for an all-remote key"
                ),
            };
            assert!(
                reps.contains(&chosen),
                "[seed {seed}] pick {chosen} outside the replica set \
                 {reps:?} (replay: TANHVF_SIM_SEED={seed} cargo test \
                 -q sim_p2c)"
            );
            *queues.get_mut(&chosen).unwrap() += 1;
            version += 1;
            publish(&cl, &chosen, queues[&chosen], version);
            *rr.get_mut(&reps[i % reps.len()]).unwrap() += 1;
        }
        assert_eq!(
            cl.stats.p2c_load_picks.load(Ordering::Relaxed),
            DRAWS as u64,
            "[seed {seed}] every draw had three known loads, so every \
             pick must be a p2c pick"
        );
        let spread = |m: &BTreeMap<String, u64>| {
            let max = *m.values().max().unwrap();
            let min = *m.values().min().unwrap();
            (max, max - min)
        };
        let (p2c_max, p2c_spread) = spread(&queues);
        let (rr_max, rr_spread) = spread(&rr);
        assert!(
            p2c_max < rr_max,
            "[seed {seed}] p2c max queue {p2c_max} not below \
             round-robin's {rr_max} ({queues:?} vs {rr:?})"
        );
        assert!(
            p2c_spread * 2 <= rr_spread,
            "[seed {seed}] p2c spread {p2c_spread} vs round-robin \
             {rr_spread}: p2c is not equalizing ({queues:?} vs {rr:?})"
        );
        cl.stop();
    }
}

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Wire compatibility with a pre-PR-10 node: a peer that emits only
/// `addr`/`incarnation`/`alive` (no load stanza, no routes key) and
/// parses incoming gossip with the old decoder must neither crash nor
/// stall convergence in either direction. Its load stays "unknown":
/// excluded from p2c, but fully routable.
#[test]
fn sim_legacy_peer_without_load_stanza_interops() {
    let net = SimNet::new();
    let legacy = "old0:7";
    let table: Arc<Mutex<BTreeMap<String, (u64, bool)>>> =
        Arc::new(Mutex::new(BTreeMap::new()));
    table.lock().unwrap().insert(legacy.to_string(), (44, true));
    let t2 = table.clone();
    let handler: Handler = Arc::new(move |_m, path, _h, body: &[u8]| {
        if path != gossip::GOSSIP_PATH {
            // Probes (`GET /health`) and anything else: plain 200.
            return (200, Vec::new());
        }
        // A PR-9-era decoder: reads v/from/addr/incarnation/alive and
        // nothing else — unknown keys (load stanzas, route claims)
        // must fall off the parse without breaking the exchange.
        let parsed = std::str::from_utf8(body)
            .ok()
            .and_then(|s| json::parse(s).ok());
        let Some(msg) = parsed else {
            return (400, Vec::new());
        };
        let Some(members) = msg.get("members").and_then(Json::as_arr) else {
            return (400, Vec::new());
        };
        let mut t = t2.lock().unwrap();
        for m in members {
            let (Some(addr), Some(inc), Some(&Json::Bool(alive))) = (
                m.get("addr").and_then(Json::as_str),
                m.get("incarnation").and_then(Json::as_f64),
                m.get("alive"),
            ) else {
                return (400, Vec::new());
            };
            let e = t.entry(addr.to_string()).or_insert((0, alive));
            if inc as u64 >= e.0 {
                *e = (inc as u64, alive);
            }
        }
        let wire: Vec<Json> = t
            .iter()
            .map(|(a, &(inc, alive))| {
                jobj(vec![
                    ("addr", Json::Str(a.clone())),
                    ("incarnation", Json::Num(inc as f64)),
                    ("alive", Json::Bool(alive)),
                ])
            })
            .collect();
        let reply = jobj(vec![
            ("v", Json::Num(1.0)),
            ("from", Json::Str(legacy.to_string())),
            ("members", Json::Arr(wire)),
        ]);
        (200, json::write(&reply).into_bytes())
    });
    net.register(legacy, handler);
    let joiner = Cluster::start_with_transport(
        ClusterConfig {
            join: vec![legacy.to_string()],
            ..node_config("new0:7", 9)
        },
        net.transport("new0:7"),
    )
    .unwrap();
    for _ in 0..8 {
        joiner.membership_round();
        net.advance(PROBE_INTERVAL_MS);
    }
    let members = joiner.members();
    assert_eq!(
        members.get(legacy).map(|m| m.alive),
        Some(true),
        "legacy peer must be an alive ring member: {members:?}"
    );
    assert!(
        !joiner.peer_loads().contains_key(legacy),
        "a stanza-less peer's load must stay unknown"
    );
    assert!(
        joiner.stats.gossip_ok.load(Ordering::Relaxed) >= 1,
        "no gossip exchange succeeded against the legacy peer"
    );
    // The legacy node's own (old-decoder) table converged on the new
    // node too: the stanza-bearing message parsed cleanly over there.
    assert_eq!(
        table.lock().unwrap().get("new0:7").map(|e| e.1),
        Some(true),
        "legacy peer never learned the new node"
    );
    // Unknown load keeps the peer fully routable, just outside p2c.
    let key = (0..64)
        .map(|i| format!("k{i}"))
        .find(|k| joiner.owner_name(k).as_deref() == Some(legacy))
        .expect("no legacy-owned key among 64");
    assert_eq!(joiner.candidates(&key)[0], Node::Peer(legacy.to_string()));
    assert_eq!(
        joiner.stats.p2c_load_picks.load(Ordering::Relaxed),
        0,
        "p2c must never draw an unknown-load peer"
    );
    joiner.stop();
}

/// Forcing an invariant violation must (a) panic with the seed in the
/// message and a one-command replay line, and (b) reproduce the exact
/// same failure when run again with the same seed.
#[test]
fn sim_violation_prints_seed_and_reproduces() {
    fn violating_run(seed: u64) -> String {
        let run = || {
            let mut rng = scenario_rng(seed);
            let net = SimNet::new();
            let names = addrs(3, "v");
            let clusters = start_mesh(&net, &names, 100);
            let none = BTreeSet::new();
            let victim = names[rng.below(3) as usize].clone();
            for other in names.iter().filter(|o| **o != victim) {
                net.partition_pair(&victim, other);
            }
            // Far enough for mutual tombstoning (the death threshold is
            // DEATH_FACTOR failed rounds), never healed.
            for _ in 0..12 + rng.below(4) {
                drive_round(&net, &clusters, &none);
            }
            // Deliberately wrong: the victim is still partitioned, so
            // claiming the full up set cannot verify.
            let up: BTreeSet<String> = names.iter().cloned().collect();
            assert_converged(&clusters, &up, seed, "forced violation");
        };
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run))
            .expect_err("a still-partitioned cluster must not verify");
        match err.downcast::<String>() {
            Ok(msg) => *msg,
            Err(other) => panic!("non-string panic payload: {other:?}"),
        }
    }

    let seed = 4242;
    let first = violating_run(seed);
    assert!(
        first.contains(&format!("[seed {seed}]")),
        "violation must name its seed: {first}"
    );
    assert!(
        first.contains(&format!("TANHVF_SIM_SEED={seed}")),
        "violation must print the one-command replay: {first}"
    );
    let second = violating_run(seed);
    assert_eq!(first, second, "same seed must reproduce the same violation");
}

/// The scenario matrix above must add up to the promised schedule count
/// (>= 1000 seeded schedules per full `cargo test -q sim` run).
#[test]
fn sim_schedule_matrix_covers_1000_seeds() {
    // A pinned replay seed intentionally shrinks every suite to one
    // schedule — nothing to count then.
    if std::env::var("TANHVF_SIM_SEED").is_ok() {
        return;
    }
    let total = schedule_seeds(1, 300).len()
        + schedule_seeds(1, 250).len()
        + schedule_seeds(1, 200).len()
        + schedule_seeds(1, 150).len()
        + schedule_seeds(1, 200).len()
        + schedule_seeds(1, 40).len() // zipf skew, adaptive vs frozen
        + schedule_seeds(1, 60).len() // flapping-load hysteresis
        + schedule_seeds(1, 60).len() // partition-during-expansion heal
        + schedule_seeds(1, 100).len() // p2c replica-set/balance property
        + 64; // in-crate fan-out bit-exactness schedules
    assert!(total >= 1000, "sim matrix shrank to {total} schedules");
}

/// Determinism of the harness itself: the same seed drives byte-equal
/// member tables and virtual clocks across two full runs (this is what
/// makes every printed seed a working reproduction).
#[test]
fn sim_same_seed_is_bit_identical() {
    fn fingerprint(seed: u64) -> String {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(4, "d");
        let clusters = start_mesh(&net, &names, 100);
        let none = BTreeSet::new();
        let f = rng.below(4) as usize;
        let t = (f + 1 + rng.below(3) as usize) % 4;
        net.partition(&names[f], &names[t]);
        for _ in 0..6 {
            drive_round(&net, &clusters, &none);
        }
        net.heal_all();
        for _ in 0..6 {
            drive_round(&net, &clusters, &none);
        }
        let mut out = format!("clock={}", net.now_ms());
        for c in &clusters {
            out.push_str(&format!("\n{}:", c.self_name()));
            for (m, e) in c.members() {
                out.push_str(&format!(" {m}={}/{}", e.incarnation, e.alive));
            }
        }
        for c in &clusters {
            c.stop();
        }
        out
    }
    for seed in schedule_seeds(0xD0, 4) {
        assert_eq!(
            fingerprint(seed),
            fingerprint(seed),
            "seed {seed} not reproducible"
        );
    }
}

/// SplitMix64 sanity at the integration boundary: distinct seeds give
/// distinct schedules (the matrix isn't silently running one schedule
/// N times).
#[test]
fn sim_seeds_vary_the_schedule() {
    let draws: BTreeSet<u64> =
        (0..32).map(|s| SplitMix64::new(s).next_u64()).collect();
    assert_eq!(draws.len(), 32);
}
