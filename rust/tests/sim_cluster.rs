//! Deterministic cluster simulation: thousands of seeded fault
//! schedules driven entirely under virtual time — no real sockets, no
//! real sleeps (see `tanh_vf::server::sim`).
//!
//! Every scenario runs N-node clusters in-process over a `SimNet`,
//! injects partitions / message loss / delay / slow peers / restarts on
//! a seed-derived schedule, and asserts the cluster invariants:
//!
//! * gossip convergence after partitions heal (ring agreement,
//!   observer agreement, no up node left for dead),
//! * incarnation monotonicity and death-certificate refutation,
//! * the retry contract of the pooled client leg (never retry a
//!   timeout, never lose an acknowledged request),
//! * bounded virtual cost of gossiping with a stalled `--join` seed.
//!
//! Any violation panics with the offending seed;
//! `TANHVF_SIM_SEED=<seed> cargo test -q sim_<name>` replays that one
//! schedule deterministically. `TANHVF_SIM_BASE_SEED` shifts a whole
//! suite (the CI randomized pass logs the base it used).

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tanh_vf::server::cluster::{Cluster, ClusterConfig};
use tanh_vf::server::gossip;
use tanh_vf::server::sim::{
    assert_converged, converged, scenario_rng, schedule_seeds, Handler,
    IncarnationMonitor, SimNet,
};
use tanh_vf::util::rng::SplitMix64;

fn ms(n: u64) -> Duration {
    Duration::from_millis(n)
}

const PROBE_INTERVAL_MS: u64 = 100;
/// One seed-backoff period: the shortest delay `gossip_round` hands a
/// failing `--join` seed (2 rounds ≈ 2 probe intervals) — the bound the
/// per-leg gossip deadlines must keep one stalled exchange under.
const BACKOFF_PERIOD_MS: u64 = 2 * PROBE_INTERVAL_MS;

fn node_config(addr: &str, incarnation: u64) -> ClusterConfig {
    ClusterConfig {
        advertise: addr.to_string(),
        virtual_nodes: 16,
        probe_interval: ms(PROBE_INTERVAL_MS),
        probe_timeout: ms(PROBE_INTERVAL_MS),
        failure_threshold: 1,
        recovery_threshold: 1,
        proxy_timeout: ms(200),
        incarnation: Some(incarnation),
        manual_rounds: true,
        ..Default::default()
    }
}

/// A node with every other address as a *static* peer: immediately a
/// ring member, and its probe slot survives its tombstone — probing is
/// the resurrection path after a heal.
fn start_static_node(
    net: &Arc<SimNet>,
    addr: &str,
    addrs: &[String],
    incarnation: u64,
) -> Arc<Cluster> {
    let cfg = ClusterConfig {
        peers: addrs.iter().filter(|p| *p != addr).cloned().collect(),
        ..node_config(addr, incarnation)
    };
    Cluster::start_with_transport(cfg, net.transport(addr)).unwrap()
}

/// A node that knows the others only as `--join` gossip seeds: a
/// member's probe slot dies with it, so a tombstoned node can ONLY
/// re-enter by gossiping a refutation itself.
fn start_join_node(
    net: &Arc<SimNet>,
    addr: &str,
    addrs: &[String],
    incarnation: u64,
) -> Arc<Cluster> {
    let cfg = ClusterConfig {
        join: addrs.iter().filter(|p| *p != addr).cloned().collect(),
        ..node_config(addr, incarnation)
    };
    Cluster::start_with_transport(cfg, net.transport(addr)).unwrap()
}

/// Full static mesh on `net`, tight thresholds (1 failed probe evicts,
/// 1 success re-admits, death after `DEATH_FACTOR` failed rounds),
/// `manual_rounds` so the test drives every round under virtual time.
fn start_mesh(
    net: &Arc<SimNet>,
    addrs: &[String],
    base_inc: u64,
) -> Vec<Arc<Cluster>> {
    let clusters: Vec<Arc<Cluster>> = addrs
        .iter()
        .enumerate()
        .map(|(i, a)| start_static_node(net, a, addrs, base_inc + i as u64))
        .collect();
    for (a, c) in addrs.iter().zip(&clusters) {
        net.register_cluster(a, c);
    }
    clusters
}

/// One cluster-wide membership round under virtual time: each node
/// probes + gossips in a fixed order, then the interval elapses.
fn drive_round(
    net: &Arc<SimNet>,
    clusters: &[Arc<Cluster>],
    down: &BTreeSet<String>,
) {
    for c in clusters {
        if !down.contains(c.self_name()) {
            c.membership_round();
        }
    }
    net.advance(PROBE_INTERVAL_MS);
}

fn observe_all(
    monitor: &mut IncarnationMonitor,
    clusters: &[Arc<Cluster>],
    down: &BTreeSet<String>,
    seed: u64,
) {
    for c in clusters {
        if !down.contains(c.self_name()) {
            monitor.observe(c.self_name(), &c.members(), seed);
        }
    }
}

/// Drive rounds until the up set converges (or a generous round bound
/// runs out — then panic with the seed).
fn converge(
    net: &Arc<SimNet>,
    clusters: &[Arc<Cluster>],
    up: &BTreeSet<String>,
    monitor: &mut IncarnationMonitor,
    seed: u64,
    ctx: &str,
) {
    let none = BTreeSet::new();
    for _ in 0..50 {
        if converged(clusters, up).is_none() {
            return;
        }
        drive_round(net, clusters, &none);
        observe_all(monitor, clusters, &none, seed);
    }
    assert_converged(clusters, up, seed, ctx);
}

fn addrs(n: usize, prefix: &str) -> Vec<String> {
    (0..n).map(|i| format!("{prefix}{i}:7")).collect()
}

/// Symmetric partitions: a seed-chosen victim group is blackholed from
/// the rest (both directions) for a seed-chosen number of rounds —
/// sometimes short of the death threshold, sometimes far past it (full
/// mutual tombstoning). After healing, the cluster must re-converge:
/// identical rings covering every node, observers agreeing on every
/// third-party member, incarnations never regressing at any observer.
#[test]
fn sim_gossip_convergence_after_symmetric_partition() {
    for seed in schedule_seeds(0x51A1, 300) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(5, "p");
        let clusters = start_mesh(&net, &names, 100);
        let mut monitor = IncarnationMonitor::new();
        let none = BTreeSet::new();

        // Let the mesh learn real incarnations first.
        for _ in 0..2 {
            drive_round(&net, &clusters, &none);
            observe_all(&mut monitor, &clusters, &none, seed);
        }

        // Cut 1-2 victims off from the rest, both directions.
        let victims: Vec<&String> = if rng.chance(1, 3) {
            vec![&names[rng.below(5) as usize]]
        } else {
            let a = rng.below(5) as usize;
            let b = (a + 1 + rng.below(4) as usize) % 5;
            vec![&names[a], &names[b]]
        };
        for v in &victims {
            for other in names.iter().filter(|o| !victims.contains(o)) {
                net.partition_pair(v, other);
            }
        }
        // 2..=16 partitioned rounds: death certificates appear past
        // DEATH_FACTOR (10) failed probe rounds.
        let cut_rounds = 2 + rng.below(15);
        for _ in 0..cut_rounds {
            drive_round(&net, &clusters, &none);
            observe_all(&mut monitor, &clusters, &none, seed);
        }

        net.heal_all();
        let up: BTreeSet<String> = names.iter().cloned().collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "symmetric heal");
        for c in &clusters {
            c.stop();
        }
    }
}

/// Asymmetric faults: one-directional blackholes and one-directional
/// response delays (some past the probe/gossip read budgets, so one
/// side believes a peer dead while the reverse direction still works —
/// including refute/re-kill incarnation churn). Healing must still
/// converge every observer to one view.
#[test]
fn sim_gossip_convergence_under_asymmetric_partition_and_delay() {
    for seed in schedule_seeds(0xA57, 250) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(4, "a");
        let clusters = start_mesh(&net, &names, 200);
        let mut monitor = IncarnationMonitor::new();
        let none = BTreeSet::new();

        for _ in 0..2 {
            drive_round(&net, &clusters, &none);
            observe_all(&mut monitor, &clusters, &none, seed);
        }

        // 1-3 one-way blackholes plus 1-3 one-way delays (0..150 ms
        // virtual — beyond 99 ms a probe response misses its read
        // deadline, beyond the gossip leg budget an exchange fails).
        let mut delayed: Vec<(String, String)> = Vec::new();
        for _ in 0..1 + rng.below(3) {
            let f = rng.below(4) as usize;
            let t = (f + 1 + rng.below(3) as usize) % 4;
            net.partition(&names[f], &names[t]);
        }
        for _ in 0..1 + rng.below(3) {
            let f = rng.below(4) as usize;
            let t = (f + 1 + rng.below(3) as usize) % 4;
            net.set_delay(&names[f], &names[t], rng.below(150));
            delayed.push((names[f].clone(), names[t].clone()));
        }
        let cut_rounds = 2 + rng.below(13);
        for _ in 0..cut_rounds {
            drive_round(&net, &clusters, &none);
            observe_all(&mut monitor, &clusters, &none, seed);
        }

        net.heal_all();
        for (f, t) in &delayed {
            net.set_delay(f, t, 0);
        }
        let up: BTreeSet<String> = names.iter().cloned().collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "asymmetric heal");
        for c in &clusters {
            c.stop();
        }
    }
}

/// Kill a node long enough for the survivors to tombstone it, then
/// restart it as a NEW cluster instance with a *lower* incarnation than
/// its death certificate (a rebooted process has no memory of its old
/// one). The mesh is join-seeded, not static: a tombstoned member loses
/// its probe slot, so no survivor can probe-resurrect it — re-entry is
/// forced through the refutation path. The restarted node must see the
/// dead report about itself, out-bid the certificate, and end alive in
/// every table strictly above it.
#[test]
fn sim_death_and_rejoin_refutation() {
    for seed in schedule_seeds(0xDEAD, 200) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(4, "r");
        let clusters: Vec<Arc<Cluster>> = names
            .iter()
            .enumerate()
            .map(|(i, a)| start_join_node(&net, a, &names, 50 + i as u64))
            .collect();
        for (a, c) in names.iter().zip(&clusters) {
            net.register_cluster(a, c);
        }
        let mut monitor = IncarnationMonitor::new();
        let up: BTreeSet<String> = names.iter().cloned().collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "join warmup");

        let vi = rng.below(4) as usize;
        let victim = names[vi].clone();
        let victim_inc = {
            let members = clusters[vi].members();
            members[&victim].incarnation
        };
        net.crash(&victim);
        let down: BTreeSet<String> = [victim.clone()].into();
        // Past the death threshold (DEATH_FACTOR rounds at
        // failure_threshold 1) plus seed-chosen slack: every survivor
        // holds a death certificate for the victim.
        let dead_rounds = u64::from(gossip::DEATH_FACTOR) + 2 + rng.below(5);
        for _ in 0..dead_rounds {
            drive_round(&net, &clusters, &down);
            observe_all(&mut monitor, &clusters, &down, seed);
        }
        for c in clusters.iter().filter(|c| c.self_name() != victim) {
            let members = c.members();
            let m = &members[&victim];
            assert!(
                !m.alive,
                "[seed {seed}] survivor {} still sees {victim} alive \
                 after {dead_rounds} dead rounds",
                c.self_name()
            );
        }
        let cert = monitor.death_cert(&victim);
        assert!(
            cert >= victim_inc,
            "[seed {seed}] death certificate {cert} below the victim's \
             incarnation {victim_inc}"
        );

        // "Process restart": a brand-new Cluster under the same address
        // with an incarnation far below the certificate.
        let restarted = start_join_node(&net, &victim, &names, 1);
        net.register_cluster(&victim, &restarted);
        let clusters: Vec<Arc<Cluster>> = clusters
            .into_iter()
            .map(|c| {
                if c.self_name() == victim {
                    restarted.clone()
                } else {
                    c
                }
            })
            .collect();
        converge(&net, &clusters, &up, &mut monitor, seed, "rejoin");

        // The rejoin must have out-bid the certificate everywhere —
        // including in the restarted node's own table.
        for c in &clusters {
            let members = c.members();
            let m = &members[&victim];
            assert!(
                m.alive && m.incarnation > cert,
                "[seed {seed}] {} sees {victim} as {m:?}, want alive past \
                 certificate {cert} (replay: TANHVF_SIM_SEED={seed} \
                 cargo test -q sim_death)",
                c.self_name()
            );
        }
        // And re-entry actually went through refutation (the satellite
        // counter surfaced on /metrics).
        assert!(
            restarted.stats.gossip_refutations.load(Ordering::Relaxed) >= 1,
            "[seed {seed}] rejoin converged without a refutation"
        );
        for c in &clusters {
            c.stop();
        }
    }
}

/// A stalled/blackholed `--join` seed must cost the membership loop at
/// most one seed-backoff period per gossip round (the per-leg gossip
/// deadline satellite): measure the virtual cost of every round while
/// the seed is stalled in a seed-chosen way and check the bound, plus
/// the exponential backoff actually suppressing most attempts.
#[test]
fn sim_slow_peer_and_deadline_bounds() {
    for seed in schedule_seeds(0x510, 150) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let seed_addr = "stalled-seed:7".to_string();
        // The seed exists but never usefully answers: responses beyond
        // any read budget, connects blackholed, or requests dropped.
        let idle: Handler = Arc::new(|_m, _p, _h, _b: &[u8]| (200, Vec::new()));
        net.register(&seed_addr, idle);
        let joiner = Cluster::start_with_transport(
            ClusterConfig {
                join: vec![seed_addr.clone()],
                ..node_config("joiner:7", 7)
            },
            net.transport("joiner:7"),
        )
        .unwrap();
        match rng.below(3) {
            0 => net.set_slow(&seed_addr, 10_000),
            1 => net.partition("joiner:7", &seed_addr),
            _ => net.drop_requests("joiner:7", &seed_addr, 1 << 20),
        }
        let mut contact_rounds = 0u32;
        for round in 0..20 {
            let t0 = net.now_ms();
            joiner.membership_round();
            let cost = net.now_ms() - t0;
            assert!(
                cost <= BACKOFF_PERIOD_MS,
                "[seed {seed}] round {round} spent {cost} ms virtual on a \
                 stalled seed; per-leg deadlines must cap one exchange at \
                 one backoff period ({BACKOFF_PERIOD_MS} ms) \
                 (replay: TANHVF_SIM_SEED={seed} cargo test -q sim_slow)"
            );
            if cost > 0 {
                contact_rounds += 1;
            }
        }
        assert!(
            contact_rounds >= 1,
            "[seed {seed}] the joiner never even tried its seed"
        );
        assert!(
            contact_rounds <= 6,
            "[seed {seed}] {contact_rounds} contact rounds in 20: seed \
             backoff is not suppressing retries"
        );
        assert!(
            joiner.stats.gossip_fail.load(Ordering::Relaxed) >= 1,
            "[seed {seed}] stalled exchanges must count as failures"
        );
        joiner.stop();
    }
}

#[derive(PartialEq, Clone, Copy, Debug)]
enum Fault {
    None,
    RespLost,
    ReqLost,
    Partition,
    Slow,
    Restart,
}

/// The pooled client leg's retry contract, under every fault class the
/// transport distinguishes. Per operation the driver stages at most one
/// fault, then checks the pool counters against the server-side
/// execution count:
///
/// * at most two attempts, and a second attempt only after a failure
///   on a *reused* (pooled) connection;
/// * a success's response came from its own (final) execution — an
///   acknowledged request is never lost;
/// * response timeouts (request lost, slow peer) are never retried, so
///   a request is never executed twice *because of* a timeout;
/// * every double execution is a retried response-loss that ended in
///   success — re-executed XOR lost, never both.
#[test]
fn sim_pool_redial_request_invariants() {
    for seed in schedule_seeds(0xF007, 200) {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let server = "srv:7".to_string();
        let serial = Arc::new(AtomicU64::new(0));
        let s2 = serial.clone();
        let handler: Handler = Arc::new(move |_m, _p, _h, _b: &[u8]| {
            let n = s2.fetch_add(1, Ordering::SeqCst) + 1;
            (200, format!("{{\"serial\":{n}}}").into_bytes())
        });
        net.register(&server, handler);
        let client = Cluster::start_with_transport(
            node_config("cli:7", 9),
            net.transport("cli:7"),
        )
        .unwrap();

        for op in 0..20 {
            let fault = match rng.below(10) {
                0..=3 => Fault::None,
                4 | 5 => Fault::RespLost,
                6 => Fault::ReqLost,
                7 => Fault::Partition,
                8 => Fault::Slow,
                _ => Fault::Restart,
            };
            match fault {
                Fault::RespLost => net.drop_responses("cli:7", &server, 1),
                Fault::ReqLost => net.drop_requests("cli:7", &server, 1),
                Fault::Partition => net.partition("cli:7", &server),
                Fault::Slow => net.set_slow(&server, 1_000),
                Fault::Restart => {
                    net.crash(&server);
                    net.restart(&server);
                }
                Fault::None => {}
            }
            let h0 = client.pool.stats.hits.load(Ordering::Relaxed);
            let m0 = client.pool.stats.misses.load(Ordering::Relaxed);
            let e0 = net.executions(&server);

            let result = client.forward(&server, "/op", b"{}", &[]);

            let dh = client.pool.stats.hits.load(Ordering::Relaxed) - h0;
            let dm = client.pool.stats.misses.load(Ordering::Relaxed) - m0;
            let de = net.executions(&server) - e0;
            let attempts = dh + dm;
            let ctx = format!(
                "[seed {seed}] op {op} fault {fault:?} attempts {attempts} \
                 (hits {dh}, misses {dm}) executions {de} ok={} \
                 (replay: TANHVF_SIM_SEED={seed} cargo test -q sim_pool)",
                result.is_ok()
            );
            assert!((1..=2).contains(&attempts), "{ctx}");
            if attempts == 2 {
                assert_eq!(dh, 1, "retry without a pooled first attempt: {ctx}");
            }
            assert!(de <= 2, "more than two executions for one op: {ctx}");
            if de == 2 {
                // Double execution is legal ONLY as a retried response
                // loss that ultimately succeeded.
                assert!(
                    fault == Fault::RespLost && attempts == 2 && result.is_ok(),
                    "unexplained double execution: {ctx}"
                );
            }
            match fault {
                Fault::ReqLost | Fault::Partition => {
                    // The request vanished: the caller times out and
                    // MUST NOT retry (double-execution risk) — and the
                    // handler never ran.
                    assert!(result.is_err(), "{ctx}");
                    assert_eq!(attempts, 1, "timeout was retried: {ctx}");
                    assert_eq!(de, 0, "lost request executed: {ctx}");
                }
                Fault::Slow => {
                    // Executed, but the response missed the deadline:
                    // surfaced as a failure, never retried.
                    assert!(result.is_err(), "{ctx}");
                    assert_eq!(attempts, 1, "timeout was retried: {ctx}");
                    assert_eq!(de, 1, "{ctx}");
                }
                Fault::None | Fault::Restart => {
                    // Always recoverable: a stale pooled connection
                    // fails retryably and the fresh dial succeeds.
                    assert!(result.is_ok(), "{ctx}");
                    assert_eq!(de, 1, "{ctx}");
                }
                Fault::RespLost => {
                    // Pooled first attempt: retried to success (two
                    // executions, the answer is the second's). Fresh
                    // first attempt: surfaced as a failure (one
                    // execution, response lost — the "lost" half, never
                    // ALSO re-executed).
                    if dh == 1 {
                        assert!(result.is_ok(), "{ctx}");
                        assert_eq!((attempts, de), (2, 2), "{ctx}");
                    } else {
                        assert!(result.is_err(), "{ctx}");
                        assert_eq!((attempts, de), (1, 1), "{ctx}");
                    }
                }
            }
            if let Ok(resp) = result {
                // An acknowledged response is the final execution's —
                // a lost/abandoned attempt's answer is never served.
                let body = String::from_utf8(resp.body).unwrap();
                let want =
                    format!("{{\"serial\":{}}}", serial.load(Ordering::SeqCst));
                assert_eq!(body, want, "{ctx}");
            }
            // Clear whatever fault state persists across operations.
            match fault {
                Fault::Partition => net.heal("cli:7", &server),
                Fault::Slow => net.set_slow(&server, 0),
                _ => {}
            }
        }
        client.stop();
    }
}

/// Forcing an invariant violation must (a) panic with the seed in the
/// message and a one-command replay line, and (b) reproduce the exact
/// same failure when run again with the same seed.
#[test]
fn sim_violation_prints_seed_and_reproduces() {
    fn violating_run(seed: u64) -> String {
        let run = || {
            let mut rng = scenario_rng(seed);
            let net = SimNet::new();
            let names = addrs(3, "v");
            let clusters = start_mesh(&net, &names, 100);
            let none = BTreeSet::new();
            let victim = names[rng.below(3) as usize].clone();
            for other in names.iter().filter(|o| **o != victim) {
                net.partition_pair(&victim, other);
            }
            // Far enough for mutual tombstoning (the death threshold is
            // DEATH_FACTOR failed rounds), never healed.
            for _ in 0..12 + rng.below(4) {
                drive_round(&net, &clusters, &none);
            }
            // Deliberately wrong: the victim is still partitioned, so
            // claiming the full up set cannot verify.
            let up: BTreeSet<String> = names.iter().cloned().collect();
            assert_converged(&clusters, &up, seed, "forced violation");
        };
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run))
            .expect_err("a still-partitioned cluster must not verify");
        match err.downcast::<String>() {
            Ok(msg) => *msg,
            Err(other) => panic!("non-string panic payload: {other:?}"),
        }
    }

    let seed = 4242;
    let first = violating_run(seed);
    assert!(
        first.contains(&format!("[seed {seed}]")),
        "violation must name its seed: {first}"
    );
    assert!(
        first.contains(&format!("TANHVF_SIM_SEED={seed}")),
        "violation must print the one-command replay: {first}"
    );
    let second = violating_run(seed);
    assert_eq!(first, second, "same seed must reproduce the same violation");
}

/// The scenario matrix above must add up to the promised schedule count
/// (>= 1000 seeded schedules per full `cargo test -q sim` run).
#[test]
fn sim_schedule_matrix_covers_1000_seeds() {
    // A pinned replay seed intentionally shrinks every suite to one
    // schedule — nothing to count then.
    if std::env::var("TANHVF_SIM_SEED").is_ok() {
        return;
    }
    let total = schedule_seeds(1, 300).len()
        + schedule_seeds(1, 250).len()
        + schedule_seeds(1, 200).len()
        + schedule_seeds(1, 150).len()
        + schedule_seeds(1, 200).len()
        + 64; // in-crate fan-out bit-exactness schedules
    assert!(total >= 1000, "sim matrix shrank to {total} schedules");
}

/// Determinism of the harness itself: the same seed drives byte-equal
/// member tables and virtual clocks across two full runs (this is what
/// makes every printed seed a working reproduction).
#[test]
fn sim_same_seed_is_bit_identical() {
    fn fingerprint(seed: u64) -> String {
        let mut rng = scenario_rng(seed);
        let net = SimNet::new();
        let names = addrs(4, "d");
        let clusters = start_mesh(&net, &names, 100);
        let none = BTreeSet::new();
        let f = rng.below(4) as usize;
        let t = (f + 1 + rng.below(3) as usize) % 4;
        net.partition(&names[f], &names[t]);
        for _ in 0..6 {
            drive_round(&net, &clusters, &none);
        }
        net.heal_all();
        for _ in 0..6 {
            drive_round(&net, &clusters, &none);
        }
        let mut out = format!("clock={}", net.now_ms());
        for c in &clusters {
            out.push_str(&format!("\n{}:", c.self_name()));
            for (m, e) in c.members() {
                out.push_str(&format!(" {m}={}/{}", e.incarnation, e.alive));
            }
        }
        for c in &clusters {
            c.stop();
        }
        out
    }
    for seed in schedule_seeds(0xD0, 4) {
        assert_eq!(
            fingerprint(seed),
            fingerprint(seed),
            "seed {seed} not reproducible"
        );
    }
}

/// SplitMix64 sanity at the integration boundary: distinct seeds give
/// distinct schedules (the matrix isn't silently running one schedule
/// N times).
#[test]
fn sim_seeds_vary_the_schedule() {
    let draws: BTreeSet<u64> =
        (0..32).map(|s| SplitMix64::new(s).next_u64()).collect();
    assert_eq!(draws.len(), 32);
}
