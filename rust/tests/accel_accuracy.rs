//! Network-level activation-accuracy experiment (paper §I motivation):
//! train a float MLP, quantize it onto the accelerator simulator, and
//! compare classification accuracy across activation implementations.
//! The velocity-factor unit must track float accuracy; crude baselines
//! must lose visibly more.

use tanh_vf::accel::trainer::{blobs, spirals, Mlp};
use tanh_vf::accel::DenseNet;
use tanh_vf::analysis::TanhImpl;
use tanh_vf::baselines::{fmt16, lut::UniformLut, pwl::Pwl};
use tanh_vf::fixed::QFormat;
use tanh_vf::tanh::{TanhConfig, TanhUnit};
use tanh_vf::util::rng::Rng;

fn quantized_accuracy(
    net: &Mlp,
    act: &dyn TanhImpl,
    xs: &[Vec<f64>],
    ys: &[usize],
) -> f64 {
    let dn = DenseNet::from_float(
        &net.layers(),
        QFormat::new(2, 9),
        QFormat::new(3, 12),
        act,
    );
    dn.accuracy(xs, ys)
}

#[test]
fn vf_unit_preserves_trained_accuracy_on_spirals() {
    let mut rng = Rng::new(41);
    let (xs, ys) = spirals(150, 0.03, &mut rng);
    let mut net = Mlp::new(&[2, 24, 2], &mut rng);
    let float_acc = net.train(&xs, &ys, 80, 0.03, &mut rng);
    assert!(float_acc > 0.85, "trainer failed: {float_acc}");

    let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
    let q_acc = quantized_accuracy(&net, &unit, &xs, &ys);
    assert!(
        q_acc >= float_acc - 0.03,
        "VF-quantized accuracy {q_acc} vs float {float_acc}"
    );
}

#[test]
fn crude_activation_loses_accuracy_on_spirals() {
    let mut rng = Rng::new(42);
    let (xs, ys) = spirals(150, 0.03, &mut rng);
    let mut net = Mlp::new(&[2, 24, 2], &mut rng);
    let float_acc = net.train(&xs, &ys, 80, 0.03, &mut rng);

    let (fi, fo) = fmt16();
    let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
    let crude = UniformLut::new(fi, fo, 16); // 16-entry LUT: very coarse
    let acc_vf = quantized_accuracy(&net, &unit, &xs, &ys);
    let acc_crude = quantized_accuracy(&net, &crude, &xs, &ys);
    assert!(
        acc_vf >= acc_crude,
        "VF {acc_vf} should be at least as accurate as crude LUT {acc_crude} \
         (float {float_acc})"
    );
}

#[test]
fn blobs_task_robust_across_reasonable_activations() {
    // On an easy task, any decent activation preserves accuracy — the
    // effect the paper notes is workload-dependent.
    let mut rng = Rng::new(43);
    let (xs, ys) = blobs(3, 80, &mut rng);
    let mut net = Mlp::new(&[2, 16, 3], &mut rng);
    let float_acc = net.train(&xs, &ys, 40, 0.05, &mut rng);
    assert!(float_acc > 0.95);

    let (fi, fo) = fmt16();
    let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
    let pwl = Pwl::new(fi, fo, 32);
    for act in [&unit as &dyn TanhImpl, &pwl] {
        let acc = quantized_accuracy(&net, act, &xs, &ys);
        assert!(
            acc >= float_acc - 0.05,
            "{}: {acc} vs float {float_acc}",
            act.name()
        );
    }
}
