//! Accuracy-band validation for the wider precision presets served by
//! `server::named_config` (ROADMAP open item): the paper publishes two
//! operating points (s3.12, s3.5), but the route table accepts any
//! `s<I>_<F>` and derives the secondary parameters. These tests pin
//! down that the derived presets (a) stay bit-exact against
//! `tanh::golden`, (b) keep their max error within a small
//! output-lsb band, and (c) get monotonically *more* accurate as
//! fractional precision grows.

use tanh_vf::analysis::exhaustive_error;
// The derived-preset catalog lives next to the static verifier so the
// `verify-datapath --all-presets` CLI, the CI `verify` job, and these
// accuracy-band tests all sweep the same list.
use tanh_vf::analysis::verify::DERIVED_PRESETS;
use tanh_vf::server::named_config;
use tanh_vf::tanh::{tanh_golden, TanhUnit};
use tanh_vf::util::rng::Rng;

#[test]
fn derived_presets_are_bit_exact_against_golden() {
    for name in DERIVED_PRESETS {
        let cfg = named_config(name).unwrap();
        cfg.validate().unwrap();
        let unit = TanhUnit::new(cfg).unwrap();
        let limit = 1i64 << cfg.mag_bits();
        let mut rng = Rng::new(0xBAD5EED ^ name.len() as u64);
        for _ in 0..512 {
            let x = rng.range_i64(-limit, limit);
            assert_eq!(
                unit.eval(x),
                tanh_golden(x, &cfg),
                "{name}: unit disagrees with golden at word {x}"
            );
        }
        // Boundary words explicitly.
        for x in [0, 1, -1, limit - 1, -limit, cfg.sat_threshold()] {
            assert_eq!(unit.eval(x), tanh_golden(x, &cfg), "{name} at {x}");
        }
    }
}

#[test]
fn derived_presets_stay_within_accuracy_band() {
    // The canonical points sit under ~2.6 output lsb (Table II); the
    // derived generator must stay in the same small band — a few lsb,
    // never tens.
    for name in DERIVED_PRESETS {
        let cfg = named_config(name).unwrap();
        let unit = TanhUnit::new(cfg).unwrap();
        let stats = exhaustive_error(&unit);
        let lsb = stats.max_lsb(cfg.out_format());
        assert!(
            lsb <= 6.0,
            "{name}: max error {} = {lsb:.2} output lsb exceeds band",
            stats.max_abs
        );
        assert!(stats.count > 0);
    }
}

#[test]
fn max_error_is_monotone_in_fractional_precision() {
    // Within one integer-width family the absolute max error against
    // true tanh must shrink as fractional bits are added: each +3 frac
    // bits shrinks the output lsb 8x, which dominates any lsb-count
    // wobble between configs. s3_12 resolves to the paper's canonical
    // config, so this also ties the derived presets to the published
    // operating point.
    let family = ["s3_6", "s3_9", "s3_12"];
    let mut prev = f64::INFINITY;
    for name in family {
        let cfg = named_config(name).unwrap();
        let unit = TanhUnit::new(cfg).unwrap();
        let stats = exhaustive_error(&unit);
        assert!(
            stats.max_abs < prev,
            "{name}: max error {} did not improve on coarser preset ({prev})",
            stats.max_abs
        );
        prev = stats.max_abs;
    }
}
