//! CLI smoke tests: every subcommand runs and prints what it promises.

use std::process::Command;

fn run(args: &[&str]) -> (String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_tanh-vf"))
        .args(args)
        .output()
        .expect("spawn tanh-vf");
    (
        String::from_utf8_lossy(&out.stdout).to_string()
            + &String::from_utf8_lossy(&out.stderr),
        out.status.success(),
    )
}

#[test]
fn no_args_prints_usage() {
    let (out, ok) = run(&[]);
    assert!(ok);
    assert!(out.contains("subcommands:"));
    for sub in ["table2", "table3", "codegen", "serve"] {
        assert!(out.contains(sub), "usage missing {sub}");
    }
}

#[test]
fn eval_prints_value_and_error() {
    let (out, ok) = run(&["eval", "--x", "0.5"]);
    assert!(ok, "{out}");
    assert!(out.contains("tanh(0.5)"));
    assert!(out.contains("s3.12"));
    let (out8, ok8) = run(&["eval", "--x", "0.5", "--bits", "8"]);
    assert!(ok8);
    assert!(out8.contains("s3.5"));
}

#[test]
fn eval_rejects_bad_bits() {
    let (out, ok) = run(&["eval", "--bits", "12"]);
    assert!(!ok);
    assert!(out.contains("use 8 or 16"));
}

#[test]
fn table2_reports_all_five_rows() {
    let (out, ok) = run(&["table2"]);
    assert!(ok, "{out}");
    assert!(out.contains("0 (fp ref)"));
    assert_eq!(out.matches("e-").count() >= 5, true);
    assert!(out.contains("2.77e-4")); // the paper column
}

#[test]
fn tables_3_and_4_have_six_flavours() {
    for t in ["table3", "table4"] {
        let (out, ok) = run(&[t]);
        assert!(ok, "{t}: {out}");
        assert_eq!(out.matches("SVT").count(), 3, "{t}");
        assert_eq!(out.matches("LVT").count(), 3, "{t}");
    }
}

#[test]
fn codegen_writes_files() {
    let dir = std::env::temp_dir().join("tanhvf-cli-codegen");
    let _ = std::fs::remove_dir_all(&dir);
    let (out, ok) = run(&[
        "codegen",
        "--stages",
        "2",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "{out}");
    let v = dir.join("tanh_vf_s3_12_15_p2.v");
    assert!(v.exists());
    let text = std::fs::read_to_string(v).unwrap();
    assert!(text.contains("endmodule"));
}

#[test]
fn sweep_and_fig1_and_table1_run() {
    for sub in ["sweep", "table1"] {
        let (out, ok) = run(&[sub]);
        assert!(ok, "{sub}: {out}");
        assert!(out.len() > 200, "{sub} output too short");
    }
    let (out, ok) = run(&["fig1", "--segments", "16", "--points", "9"]);
    assert!(ok);
    assert!(out.contains("PWL"));
}

#[test]
fn serve_native_small_run() {
    let (out, ok) = run(&["serve", "--backend", "native", "--requests", "50"]);
    assert!(ok, "{out}");
    assert!(out.contains("throughput"));
    assert!(out.contains("batches="));
}

#[test]
fn serve_rejects_unknown_backend_with_usage() {
    let (out, ok) = run(&["serve", "--backend", "bogus", "--requests", "1"]);
    assert!(!ok, "unknown backend must exit non-zero:\n{out}");
    assert!(out.contains("native|pjrt"), "{out}");
    assert!(out.contains("subcommands:"), "usage text missing:\n{out}");
}

#[test]
fn serve_http_rejects_unknown_route_backend_with_usage() {
    let (out, ok) = run(&["serve-http", "--routes", "bogus:s3_12"]);
    assert!(!ok, "unknown route backend must exit non-zero:\n{out}");
    assert!(out.contains("native|pjrt"), "{out}");
    assert!(out.contains("subcommands:"), "usage text missing:\n{out}");
    let (out2, ok2) = run(&["serve-http", "--routes", "native:nonsense"]);
    assert!(!ok2, "{out2}");
    assert!(out2.contains("unknown model config"), "{out2}");
}

#[test]
fn serve_http_timed_run_reports_metrics() {
    let (out, ok) = run(&[
        "serve-http",
        "--addr",
        "127.0.0.1:0",
        "--routes",
        "native:s3_5",
        "--duration-secs",
        "1",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("listening on http://127.0.0.1:"), "{out}");
    assert!(out.contains("route: s3_5"), "{out}");
    assert!(out.contains("tanhvf_http_connections_total"), "{out}");
}
