//! HTTP activation service end-to-end: boot the server on an ephemeral
//! port, drive mixed-precision traffic through real sockets, and verify
//! bit-exactness against the golden model plus both 503 backpressure
//! paths (connection limit, coordinator queue limit).

use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use tanh_vf::coordinator::router::Route;
use tanh_vf::server::cluster::{Cluster, ClusterConfig, PeerHealth};
use tanh_vf::server::http::HttpConn;
use tanh_vf::server::loadgen::{self, LoadgenConfig};
use tanh_vf::server::{named_config, parse_routes, Server, ServerConfig};
use tanh_vf::tanh::golden::tanh_golden_batch;
use tanh_vf::tanh::tanh_golden;
use tanh_vf::util::json::Json;
use tanh_vf::util::rng::Rng;

fn ephemeral_cfg() -> ServerConfig {
    ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() }
}

/// The acceptance-criteria route table: two native precisions.
fn start_two_precision() -> (Server, String) {
    let routes = parse_routes("native:s3_12,native:s2_8").unwrap();
    let srv = Server::start(ephemeral_cfg(), routes).unwrap();
    let addr = srv.local_addr().to_string();
    (srv, addr)
}

fn connect(addr: &str) -> HttpConn {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    HttpConn::new(s)
}

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(
        pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
    )
}

#[test]
fn health_models_and_metrics_endpoints() {
    let (_srv, addr) = start_two_precision();

    let (status, body) = loadgen::http_get(&addr, "/health").unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"ok\""), "{body}");

    let (status, body) = loadgen::http_get(&addr, "/v1/models").unwrap();
    assert_eq!(status, 200, "{body}");
    let v = tanh_vf::util::json::parse(&body).unwrap();
    let data = v.get("data").and_then(Json::as_arr).unwrap();
    let ids: Vec<&str> = data
        .iter()
        .map(|m| m.get("id").and_then(Json::as_str).unwrap())
        .collect();
    assert_eq!(ids, vec!["s2_8", "s3_12"]); // name-sorted route table
    assert!(body.contains("\"backend\":\"native\""), "{body}");

    let (status, body) = loadgen::http_get(&addr, "/metrics").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("tanhvf_requests_completed_total{route=\"s3_12\"}"));
    assert!(body.contains("tanhvf_requests_completed_total{route=\"s2_8\"}"));
    assert!(body.contains("tanhvf_http_requests_total"), "{body}");
}

#[test]
fn batch_eval_is_bit_exact_per_precision() {
    let (_srv, addr) = start_two_precision();
    // Full-range sweep per route: every response word must equal the
    // golden model under that route's exact config.
    for model in ["s3_12", "s2_8"] {
        let cfg = named_config(model).unwrap();
        let limit = 1i64 << cfg.mag_bits();
        let mut rng = Rng::new(0xE2E);
        let words: Vec<i32> = (0..257)
            .map(|_| rng.range_i64(-limit, limit) as i32)
            .collect();
        let got = loadgen::eval_words(&addr, model, &words).unwrap();
        let want = tanh_golden_batch(
            &words.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            &cfg,
        );
        assert_eq!(
            got.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            want,
            "route {model} not bit-exact"
        );
    }
}

#[test]
fn concurrent_mixed_precision_load_all_succeeds() {
    let (srv, addr) = start_two_precision();
    let mut cfg = LoadgenConfig::new(addr, &["s3_12", "s2_8"]);
    cfg.connections = 6;
    cfg.requests_per_connection = 40;
    cfg.words_per_request = 57;
    cfg.word_range = 128; // in-range for both precisions
    let report = loadgen::run(&cfg).unwrap();
    assert_eq!(report.failures, 0, "{}", report.render());
    assert_eq!(report.requests, 6 * 40);
    assert_eq!(report.words, 6 * 40 * 57);
    // Both routes saw traffic and completed everything they admitted.
    let snaps = srv.snapshots();
    assert_eq!(snaps["s3_12"].completed + snaps["s2_8"].completed, 6 * 40);
    assert!(snaps["s3_12"].completed > 0 && snaps["s2_8"].completed > 0);
}

#[test]
fn single_eval_word_and_float_agree_with_golden() {
    let (_srv, addr) = start_two_precision();
    let cfg = named_config("s3_12").unwrap();

    let (status, resp) = loadgen::http_post_json(
        &addr,
        "/v1/eval",
        &obj(&[
            ("model", Json::Str("s3_12".into())),
            ("word", Json::Num(4096.0)),
        ]),
    )
    .unwrap();
    assert_eq!(status, 200, "{resp:?}");
    let want = tanh_golden(4096, &cfg);
    assert_eq!(resp.get("y_word").and_then(Json::as_i64), Some(want));
    let y = resp.get("y").and_then(Json::as_f64).unwrap();
    assert!((y - 1.0f64.tanh()).abs() < 1e-3, "y = {y}");

    // Float input quantizes to the same word.
    let (status, resp) = loadgen::http_post_json(
        &addr,
        "/v1/eval",
        &obj(&[
            ("model", Json::Str("s3_12".into())),
            ("x", Json::Num(1.0)),
        ]),
    )
    .unwrap();
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("word").and_then(Json::as_i64), Some(4096));
    assert_eq!(resp.get("y_word").and_then(Json::as_i64), Some(want));
}

#[test]
fn api_error_paths_map_to_http_statuses() {
    let (_srv, addr) = start_two_precision();
    let post = |path: &str, body: &Json| {
        loadgen::http_post_json(&addr, path, body).unwrap().0
    };

    // Unknown path / wrong method.
    assert_eq!(loadgen::http_get(&addr, "/nope").unwrap().0, 404);
    assert_eq!(loadgen::http_get(&addr, "/v1/eval").unwrap().0, 405);

    // Unknown model.
    let body = obj(&[
        ("model", Json::Str("s9_9_bogus".into())),
        ("words", Json::Arr(vec![Json::Num(1.0)])),
    ]);
    assert_eq!(post("/v1/batch", &body), 404);

    // Missing model / empty words / non-integer / out-of-range word.
    assert_eq!(post("/v1/batch", &obj(&[("words", Json::Arr(vec![]))])), 400);
    let empty = obj(&[
        ("model", Json::Str("s3_12".into())),
        ("words", Json::Arr(vec![])),
    ]);
    assert_eq!(post("/v1/batch", &empty), 400);
    let frac = obj(&[
        ("model", Json::Str("s3_12".into())),
        ("words", Json::Arr(vec![Json::Num(1.5)])),
    ]);
    assert_eq!(post("/v1/batch", &frac), 400);
    let oob = obj(&[
        ("model", Json::Str("s3_12".into())),
        ("words", Json::Arr(vec![Json::Num(999_999.0)])),
    ]);
    assert_eq!(post("/v1/batch", &oob), 400);

    // Bodies that aren't JSON at all.
    let mut conn = connect(&addr);
    conn.write_request("POST", "/v1/eval", b"this is not json").unwrap();
    let (status, _, _) = conn.read_response(1 << 20).unwrap();
    assert_eq!(status, 400);
}

#[test]
fn malformed_and_oversized_requests_get_4xx() {
    let (_srv, addr) = start_two_precision();

    // Raw garbage instead of a request line.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    use std::io::{Read, Write};
    s.write_all(b"THIS IS NOT HTTP\r\n\r\n").unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 400"), "{text}");

    // Declared body beyond the limit -> 413 before any body bytes.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(
        b"POST /v1/batch HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n",
    )
    .unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 413"), "{text}");
}

#[test]
fn connection_limit_answers_503() {
    let routes = parse_routes("native:s3_5").unwrap();
    let srv = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: 1,
            ..Default::default()
        },
        routes,
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    // First connection occupies the only slot (request proves it is
    // fully registered before the second connect).
    let mut c1 = connect(&addr);
    c1.write_request("GET", "/health", b"").unwrap();
    assert_eq!(c1.read_response(1 << 20).unwrap().0, 200);

    // Second connection is rejected at accept time: the 503 is written
    // proactively, before any request bytes.
    let mut c2 = connect(&addr);
    let (status, _, body) = c2.read_response(1 << 20).unwrap();
    assert_eq!(status, 503, "{}", String::from_utf8_lossy(&body));
    drop(c1);
}

#[test]
fn queue_limit_backpressure_answers_503() {
    // One route with a one-deep queue and a long batching window: of N
    // simultaneous in-flight requests, exactly one can sit in the queue;
    // the rest must be answered 503 (not hang, not drop).
    let route = Route::native("tiny", named_config("s3_5").unwrap())
        .with_queue_limit(1)
        .with_workers(1)
        .with_batch(1024, Duration::from_millis(500));
    let srv = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 8,
            ..Default::default()
        },
        vec![route],
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    let body = tanh_vf::util::json::write(&obj(&[
        ("model", Json::Str("tiny".into())),
        ("words", Json::Arr(vec![Json::Num(3.0); 4])),
    ]));
    let mut conns: Vec<HttpConn> = (0..6).map(|_| connect(&addr)).collect();
    for c in conns.iter_mut() {
        c.write_request("POST", "/v1/batch", body.as_bytes()).unwrap();
    }
    let statuses: Vec<u16> = conns
        .iter_mut()
        .map(|c| c.read_response(1 << 20).unwrap().0)
        .collect();
    let ok = statuses.iter().filter(|&&s| s == 200).count();
    let busy = statuses.iter().filter(|&&s| s == 503).count();
    assert_eq!(ok + busy, 6, "unexpected statuses {statuses:?}");
    assert!(ok >= 1, "the queued request must complete: {statuses:?}");
    assert!(busy >= 1, "backpressure must trigger: {statuses:?}");
    assert!(srv.snapshots()["tiny"].rejected >= busy as u64);
}

#[test]
fn chunked_request_bodies_end_to_end() {
    // The parser's 501 refusal is gone: a chunked POST with chunk
    // boundaries split at awkward points (mid-size-line, mid-data) and
    // a trailer must evaluate bit-exactly.
    let (_srv, addr) = start_two_precision();
    let cfg = named_config("s2_8").unwrap();
    let body = r#"{"model":"s2_8","words":[1,2,3]}"#.as_bytes();

    use std::io::Write;
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(
        b"POST /v1/batch HTTP/1.1\r\nHost: t\r\n\
          Transfer-Encoding: chunked\r\n\r\n",
    )
    .unwrap();
    let (a, b) = body.split_at(10);
    // Chunk 1: size line split across two writes, data split mid-chunk.
    s.write_all(format!("{:x}", a.len()).as_bytes()).unwrap();
    std::thread::sleep(Duration::from_millis(40));
    s.write_all(b"\r\n").unwrap();
    s.write_all(&a[..4]).unwrap();
    std::thread::sleep(Duration::from_millis(40));
    s.write_all(&a[4..]).unwrap();
    s.write_all(b"\r\n").unwrap();
    // Chunk 2 in one piece, then the last chunk with a trailer.
    s.write_all(format!("{:x}\r\n", b.len()).as_bytes()).unwrap();
    s.write_all(b).unwrap();
    s.write_all(b"\r\n0\r\nX-Client-Checksum: none\r\n\r\n").unwrap();

    let mut conn = HttpConn::new(s);
    let (status, _, resp) = conn.read_response(1 << 20).unwrap();
    let text = String::from_utf8_lossy(&resp).into_owned();
    assert_eq!(status, 200, "{text}");
    let v = tanh_vf::util::json::parse(&text).unwrap();
    let got = v.get("words").and_then(Json::as_i64_vec).unwrap();
    assert_eq!(got, tanh_golden_batch(&[1, 2, 3], &cfg));
}

#[test]
fn pipelined_keep_alive_requests_answer_in_order() {
    let (_srv, addr) = start_two_precision();
    let cfg = named_config("s3_12").unwrap();
    let body = r#"{"model":"s3_12","word":4096}"#;
    let wire = format!(
        "GET /health HTTP/1.1\r\n\r\n\
         POST /v1/eval HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}\
         GET /health HTTP/1.1\r\nConnection: close\r\n\r\n",
        body.len(),
        body
    );

    use std::io::Write;
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(wire.as_bytes()).unwrap();
    let mut conn = HttpConn::new(s);
    let (s1, _, _) = conn.read_response(1 << 20).unwrap();
    let (s2, _, b2) = conn.read_response(1 << 20).unwrap();
    let (s3, _, _) = conn.read_response(1 << 20).unwrap();
    assert_eq!((s1, s2, s3), (200, 200, 200));
    let v = tanh_vf::util::json::parse(&String::from_utf8_lossy(&b2)).unwrap();
    assert_eq!(
        v.get("y_word").and_then(Json::as_i64),
        Some(tanh_golden(4096, &cfg))
    );
}

#[test]
fn slow_loris_partial_header_answers_408() {
    let routes = parse_routes("native:s3_5").unwrap();
    let srv = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            header_timeout: Duration::from_millis(300),
            ..Default::default()
        },
        routes,
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    use std::io::{Read, Write};
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // A partial request line, then silence: the per-state read deadline
    // must answer 408 and close rather than hold the slot forever.
    s.write_all(b"GET /health HT").unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 408"), "{text}");
}

#[test]
#[cfg(unix)] // event_loop falls back to the threaded backend off unix
fn reactor_decouples_connections_from_workers() {
    // 12 concurrently open connections over only 2 workers: the
    // blocking backend would cap at min(max_connections, workers) = 2,
    // the reactor serves them all.
    let routes = parse_routes("native:s3_5").unwrap();
    let srv = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            max_connections: 32,
            event_loop: true,
            ..Default::default()
        },
        routes,
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    let mut conns: Vec<HttpConn> = (0..12).map(|_| connect(&addr)).collect();
    for c in conns.iter_mut() {
        c.write_request("GET", "/health", b"").unwrap();
    }
    for c in conns.iter_mut() {
        let (status, _, body) = c.read_response(1 << 20).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    }
    // All still open: a second round on the same sockets must work too
    // (keep-alive across the whole set).
    for c in conns.iter_mut() {
        c.write_request("GET", "/health", b"").unwrap();
        assert_eq!(c.read_response(1 << 20).unwrap().0, 200);
    }
    assert!(srv.metrics_text().contains("tanhvf_http_requests_total"));
}

// ---------------------------------------------------------------------
// Cluster tier (consistent-hash fronts + health-checked peers)
// ---------------------------------------------------------------------

/// Reserve `n` distinct loopback addresses: each front needs the full
/// peer list before any of them starts.
fn free_addrs(n: usize) -> Vec<String> {
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect()
}

/// Start `n` cluster fronts, each serving `routes` and peering with
/// all the others; probing is fast so eviction tests stay quick.
/// `tweak` adjusts each node's `ClusterConfig` (replicas, pool size…).
/// Retries with a fresh port group if a concurrently running test
/// snatched a reserved port between release and re-bind.
fn start_cluster_fronts_with(
    n: usize,
    routes: &str,
    tweak: impl Fn(&mut ClusterConfig),
) -> (Vec<Server>, Vec<String>) {
    'attempt: for _ in 0..5 {
        let addrs = free_addrs(n);
        let mut fronts = Vec::with_capacity(n);
        for i in 0..n {
            let peers: Vec<String> = addrs
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != i)
                .map(|(_, a)| a.clone())
                .collect();
            let mut ccfg = ClusterConfig {
                advertise: addrs[i].clone(),
                peers,
                probe_interval: Duration::from_millis(100),
                probe_timeout: Duration::from_millis(500),
                failure_threshold: 2,
                recovery_threshold: 1,
                ..Default::default()
            };
            tweak(&mut ccfg);
            match Server::start_cluster(
                ServerConfig {
                    addr: addrs[i].clone(),
                    ..Default::default()
                },
                parse_routes(routes).unwrap(),
                ccfg,
            ) {
                Ok(srv) => fronts.push(srv),
                Err(_) => continue 'attempt, // port stolen; regroup
            }
        }
        return (fronts, addrs);
    }
    panic!("could not bind a free port group for the cluster");
}

fn start_cluster_fronts(n: usize, routes: &str) -> (Vec<Server>, Vec<String>) {
    start_cluster_fronts_with(n, routes, |_| {})
}

#[test]
fn cluster_proxied_eval_is_bit_exact_vs_direct() {
    // Two fronts, two models: whichever front a request lands on, the
    // answer must be bit-identical to the golden model — i.e. the
    // proxy hop is transparent. At least one (front, model) pair is
    // necessarily remote, so the proxy path is provably exercised.
    let (fronts, addrs) = start_cluster_fronts(2, "native:s3_12,native:s2_8");
    let mut rng = Rng::new(0xC105);
    for model in ["s3_12", "s2_8"] {
        let cfg = named_config(model).unwrap();
        let limit = 1i64 << cfg.mag_bits();
        let words: Vec<i32> =
            (0..97).map(|_| rng.range_i64(-limit, limit) as i32).collect();
        let want = tanh_golden_batch(
            &words.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            &cfg,
        );
        for addr in &addrs {
            let got = loadgen::eval_words(addr, model, &words).unwrap();
            assert_eq!(
                got.iter().map(|&w| w as i64).collect::<Vec<_>>(),
                want,
                "model {model} via front {addr} not bit-exact"
            );
        }
        // Single-word /v1/eval agrees too.
        let (status, resp) = loadgen::http_post_json(
            &addrs[0],
            "/v1/eval",
            &obj(&[
                ("model", Json::Str(model.into())),
                ("word", Json::Num(words[0] as f64)),
            ]),
        )
        .unwrap();
        assert_eq!(status, 200, "{resp:?}");
        assert_eq!(resp.get("y_word").and_then(Json::as_i64), Some(want[0]));
    }
    let proxied: u64 = fronts
        .iter()
        .map(|f| {
            f.cluster()
                .unwrap()
                .stats
                .proxied
                .load(std::sync::atomic::Ordering::Relaxed)
        })
        .sum();
    assert!(proxied >= 1, "no request crossed the proxy path");
}

#[test]
fn cluster_models_metrics_and_health_are_peer_aware() {
    let (_fronts, addrs) = start_cluster_fronts(2, "native:s3_5");
    let (status, body) = loadgen::http_get(&addrs[0], "/v1/models").unwrap();
    assert_eq!(status, 200, "{body}");
    let v = tanh_vf::util::json::parse(&body).unwrap();
    let cluster = v.get("cluster").expect("cluster section");
    assert_eq!(
        cluster.get("self").and_then(Json::as_str),
        Some(addrs[0].as_str())
    );
    let nodes = cluster.get("nodes").and_then(Json::as_arr).unwrap();
    assert_eq!(nodes.len(), 2);
    let model = &v.get("data").and_then(Json::as_arr).unwrap()[0];
    let owner = model.get("owner").and_then(Json::as_str).unwrap();
    assert!(addrs.iter().any(|a| a == owner), "owner {owner}");

    let (status, body) = loadgen::http_get(&addrs[0], "/metrics").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("tanhvf_cluster_peer_up"), "{body}");
    assert!(body.contains("tanhvf_cluster_ring_nodes 2"), "{body}");

    let (status, body) = loadgen::http_get(&addrs[0], "/health").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"cluster_nodes\":2"), "{body}");
}

#[test]
fn cluster_peer_death_evicts_and_only_owned_keys_move() {
    let (mut fronts, addrs) = start_cluster_fronts(3, "native:s3_5");
    let victim = addrs[2].clone();

    // Placement before the death, as front 0 sees it (all nodes live).
    let keys: Vec<String> = (0..300).map(|i| format!("model-{i}")).collect();
    let before: Vec<String> = {
        let cl = fronts[0].cluster().unwrap();
        keys.iter().map(|k| cl.owner_name(k).unwrap()).collect()
    };

    // Kill the third front; its keys must move, everyone else's stay.
    let dead = fronts.remove(2);
    drop(dead);

    // The prober (100 ms interval, threshold 2) evicts it shortly.
    let cl = fronts[0].cluster().unwrap();
    let t0 = Instant::now();
    while cl.peer_health()[&victim] != PeerHealth::Down {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "peer never evicted: {:?}",
            cl.peer_health()
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    let mut moved = 0usize;
    for (k, owner_before) in keys.iter().zip(&before) {
        let owner_after = cl.owner_name(k).unwrap();
        if owner_before == &victim {
            moved += 1;
            assert_ne!(owner_after, victim, "{k} still routed to dead peer");
        } else {
            assert_eq!(
                &owner_after, owner_before,
                "{k} moved off a live node"
            );
        }
    }
    // Rebalance bound: about a third of the keys (the victim's ring
    // share, plus slack for the hash spread over random ephemeral
    // ports) — never more than ~half, never none.
    let frac = moved as f64 / keys.len() as f64;
    assert!(
        frac > 0.1 && frac < 1.0 / 3.0 + 0.2,
        "moved fraction {frac}"
    );

    // And the cluster keeps serving every model, including remapped
    // ones, with bit-exact answers.
    let cfg = named_config("s3_5").unwrap();
    let words = vec![1i32, -7, 13];
    let want = tanh_golden_batch(&[1, -7, 13], &cfg);
    for addr in &addrs[..2] {
        let got = loadgen::eval_words(addr, "s3_5", &words).unwrap();
        assert_eq!(got.iter().map(|&w| w as i64).collect::<Vec<_>>(), want);
    }
}

#[test]
fn cluster_survives_peer_death_before_eviction_via_failover() {
    // Between a peer dying and the prober noticing, a forwarded
    // request hits a dead socket: the front must fail over along the
    // ring within the same request, not 502.
    let (mut fronts, addrs) = start_cluster_fronts(2, "native:s3_5");
    // Find which front owns s3_5 and kill it; ask the survivor.
    let owner = fronts[0]
        .cluster()
        .unwrap()
        .owner_name("s3_5")
        .unwrap();
    let (dead_idx, live_idx) =
        if owner == addrs[0] { (0, 1) } else { (1, 0) };
    let dead = fronts.remove(dead_idx);
    drop(dead);
    let live_addr = &addrs[live_idx];

    let cfg = named_config("s3_5").unwrap();
    let want = tanh_golden_batch(&[5, -5], &cfg);
    let got = loadgen::eval_words(live_addr, "s3_5", &[5, -5]).unwrap();
    assert_eq!(got.iter().map(|&w| w as i64).collect::<Vec<_>>(), want);
    let live = &fronts[0];
    let st = &live.cluster().unwrap().stats;
    use std::sync::atomic::Ordering as O;
    // Either the failure was already evicted by a probe tick (local
    // from the start), or the request failed over mid-flight.
    assert!(
        st.local.load(O::Relaxed) >= 1,
        "survivor must have answered locally"
    );
}

#[test]
fn cluster_proxied_chunked_body_is_bit_exact() {
    // A chunked request to a front that does NOT own the model: the
    // incremental parser decodes the chunked framing, the proxy hop
    // re-frames it as Content-Length, and the answer is bit-exact.
    let (fronts, addrs) = start_cluster_fronts(2, "native:s2_8");
    let cl0 = fronts[0].cluster().unwrap();
    let owner = cl0.owner_name("s2_8").unwrap();
    // Send to the front that will have to proxy.
    let send_to = if owner == addrs[0] { &addrs[1] } else { &addrs[0] };
    let cfg = named_config("s2_8").unwrap();
    let body = r#"{"model":"s2_8","words":[3,-11,19]}"#.as_bytes();

    use std::io::Write;
    let mut s = TcpStream::connect(send_to).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(
        b"POST /v1/batch HTTP/1.1\r\nHost: t\r\n\
          Transfer-Encoding: chunked\r\n\r\n",
    )
    .unwrap();
    let (a, b) = body.split_at(13);
    s.write_all(format!("{:x}\r\n", a.len()).as_bytes()).unwrap();
    s.write_all(&a[..5]).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    s.write_all(&a[5..]).unwrap();
    s.write_all(b"\r\n").unwrap();
    s.write_all(format!("{:x}\r\n", b.len()).as_bytes()).unwrap();
    s.write_all(b).unwrap();
    s.write_all(b"\r\n0\r\n\r\n").unwrap();

    let mut conn = HttpConn::new(s);
    let (status, _, resp) = conn.read_response(1 << 20).unwrap();
    let text = String::from_utf8_lossy(&resp).into_owned();
    assert_eq!(status, 200, "{text}");
    let v = tanh_vf::util::json::parse(&text).unwrap();
    let got = v.get("words").and_then(Json::as_i64_vec).unwrap();
    assert_eq!(got, tanh_golden_batch(&[3, -11, 19], &cfg));
    // The hop really happened.
    let sender = if send_to == &addrs[0] { &fronts[0] } else { &fronts[1] };
    assert!(
        sender
            .cluster()
            .unwrap()
            .stats
            .proxied
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 1,
        "request was not proxied"
    );
}

#[test]
fn cluster_loadgen_drives_every_front() {
    let (fronts, addrs) = start_cluster_fronts(3, "native:s3_12,native:s3_5");
    let mut cfg = LoadgenConfig::new(addrs[0].clone(), &["s3_12", "s3_5"]);
    cfg.addrs = addrs.clone();
    cfg.connections = 6;
    cfg.requests_per_connection = 20;
    cfg.words_per_request = 31;
    cfg.word_range = 128;
    let report = loadgen::run(&cfg).unwrap();
    assert_eq!(report.failures, 0, "{}", report.render());
    assert_eq!(report.requests, 6 * 20);
    // Every front saw traffic (connections are dealt round-robin):
    // each request it received was answered locally or proxied out.
    use std::sync::atomic::Ordering as O;
    for f in &fronts {
        let st = &f.cluster().unwrap().stats;
        let n = st.local.load(O::Relaxed)
            + st.proxied.load(O::Relaxed)
            + st.proxied_in.load(O::Relaxed);
        assert!(n > 0, "a front saw no cluster traffic");
    }
}

// ---------------------------------------------------------------------
// Gossip membership (dynamic join via --join seeds)
// ---------------------------------------------------------------------

#[test]
fn gossip_join_discovers_all_peers_and_serves_bit_exact() {
    // A seed front with no peers at all; two more nodes join knowing
    // only the seed. Gossip must spread full membership to everyone.
    let mk = |join: Vec<String>| -> Server {
        Server::start_cluster(
            ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
            parse_routes("native:s3_12,native:s2_8").unwrap(),
            ClusterConfig {
                join,
                probe_interval: Duration::from_millis(100),
                probe_timeout: Duration::from_millis(500),
                failure_threshold: 2,
                recovery_threshold: 1,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let seed = mk(vec![]);
    let seed_addr = seed.local_addr().to_string();
    let b = mk(vec![seed_addr.clone()]);
    let c = mk(vec![seed_addr.clone()]);
    let fronts = [&seed, &b, &c];
    let addrs: Vec<String> =
        fronts.iter().map(|f| f.local_addr().to_string()).collect();

    // Convergence: every front's member table reaches 3 alive members
    // within a bounded number of probe intervals (100 ms each; the
    // 15 s ceiling is ~150 rounds of slack for a loaded CI box).
    let t0 = Instant::now();
    while !fronts
        .iter()
        .all(|f| f.cluster().unwrap().alive_members() == 3)
    {
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "gossip never converged: {:?}",
            fronts
                .iter()
                .map(|f| f.cluster().unwrap().members())
                .collect::<Vec<_>>()
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // The /health peer table on every front lists both other nodes,
    // and every ring has all three (the joiner owns shards).
    for (i, addr) in addrs.iter().enumerate() {
        let (status, body) = loadgen::http_get(addr, "/health").unwrap();
        assert_eq!(status, 200, "{body}");
        let v = tanh_vf::util::json::parse(&body).unwrap();
        let peers = v.get("cluster_peers").and_then(Json::as_obj).unwrap();
        for (j, other) in addrs.iter().enumerate() {
            if i != j {
                assert!(
                    peers.contains_key(other),
                    "front {i} /health missing {other}: {body}"
                );
            }
        }
        assert_eq!(v.get("cluster_members").and_then(Json::as_f64), Some(3.0));
        assert_eq!(
            fronts[i].cluster().unwrap().ring().nodes().len(),
            3,
            "front {i} ring incomplete"
        );
    }

    // Whatever front a request lands on, the answer is bit-exact —
    // i.e. gossip-discovered peers serve proxied traffic correctly.
    let cfg = named_config("s3_12").unwrap();
    let words = vec![100i32, -3000, 4096];
    let want = tanh_golden_batch(&[100, -3000, 4096], &cfg);
    for addr in &addrs {
        let got = loadgen::eval_words(addr, "s3_12", &words).unwrap();
        assert_eq!(
            got.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            want,
            "via front {addr}"
        );
    }
    use std::sync::atomic::Ordering as O;
    let proxied: u64 = fronts
        .iter()
        .map(|f| f.cluster().unwrap().stats.proxied.load(O::Relaxed))
        .sum();
    assert!(proxied >= 1, "no request crossed the proxy path");
}

#[test]
fn gossip_killed_seed_rejoins_with_bumped_incarnation_and_ring_share() {
    // A seed and two joiners with a tight death clock (threshold 1 →
    // tombstone after DEATH_FACTOR failed probe rounds ≈ 1 s). The
    // seed is killed, tombstoned by both survivors, then restarted on
    // the SAME address with a deliberately stale incarnation — re-entry
    // must go through gossip refutation and win back ring ranges.
    fn wait(what: &str, mut cond: impl FnMut() -> bool) {
        let t0 = Instant::now();
        while !cond() {
            assert!(
                t0.elapsed() < Duration::from_secs(20),
                "timed out waiting for {what}"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    let mk = |addr: String, join: Vec<String>, inc: Option<u64>| {
        Server::start_cluster(
            ServerConfig { addr, ..Default::default() },
            parse_routes("native:s3_5").unwrap(),
            ClusterConfig {
                join,
                probe_interval: Duration::from_millis(100),
                probe_timeout: Duration::from_millis(500),
                failure_threshold: 1,
                recovery_threshold: 1,
                incarnation: inc,
                ..Default::default()
            },
        )
    };
    let seed = mk("127.0.0.1:0".into(), vec![], None).unwrap();
    let seed_addr = seed.local_addr().to_string();
    let b = mk("127.0.0.1:0".into(), vec![seed_addr.clone()], None).unwrap();
    let c = mk("127.0.0.1:0".into(), vec![seed_addr.clone()], None).unwrap();
    wait("initial 3-member convergence", || {
        [&seed, &b, &c]
            .iter()
            .all(|f| f.cluster().unwrap().alive_members() == 3)
    });

    // Kill the seed; both survivors must tombstone it and shrink their
    // rings to two nodes. (The seed was gossip-learned, so its probe
    // slot dies with it — nothing can probe-resurrect it.)
    drop(seed);
    let survivors = [&b, &c];
    wait("seed tombstoned on both survivors", || {
        survivors.iter().all(|f| {
            let cl = f.cluster().unwrap();
            let dead = cl
                .members()
                .get(&seed_addr)
                .map(|m| !m.alive)
                .unwrap_or(false);
            dead && cl.ring().nodes().len() == 2
        })
    });
    let cert = b.cluster().unwrap().members()[&seed_addr].incarnation;

    // Restart on the same address with an incarnation far below the
    // death certificate (a rebooted process remembers nothing). It has
    // no join list either: the survivors keep targeting their
    // tombstoned seed, deliver the death certificate, and the reborn
    // seed must refute it to get back in. The bind can briefly race
    // the dying listener's shutdown, hence the retry loop.
    let mut reborn = None;
    let t0 = Instant::now();
    while reborn.is_none() {
        match mk(seed_addr.clone(), vec![], Some(1)) {
            Ok(s) => reborn = Some(s),
            Err(e) => {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "could not rebind {seed_addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
    let reborn = reborn.unwrap();
    wait("reborn seed alive past its death certificate everywhere", || {
        survivors.iter().all(|f| {
            let cl = f.cluster().unwrap();
            let back = cl
                .members()
                .get(&seed_addr)
                .map(|m| m.alive && m.incarnation > cert)
                .unwrap_or(false);
            back && cl.ring().nodes().len() == 3
        }) && reborn.cluster().unwrap().alive_members() == 3
    });
    use std::sync::atomic::Ordering as O;
    let refutations =
        reborn.cluster().unwrap().stats.gossip_refutations.load(O::Relaxed);
    assert!(
        refutations >= 1,
        "stale-incarnation rejoin must go through refutation"
    );

    // It reclaims real ring ranges (owns some keys again)…
    let cl = b.cluster().unwrap();
    let owned = (0..300)
        .filter(|i| cl.owner_name(&format!("model-{i}")).unwrap() == seed_addr)
        .count();
    assert!(owned > 0, "reborn seed owns no ring range");

    // …and every front serves bit-exact answers again.
    let cfg = named_config("s3_5").unwrap();
    let want = tanh_golden_batch(&[9, -9, 77], &cfg);
    let addrs = [&b, &c].map(|f| f.local_addr().to_string());
    for addr in [seed_addr.clone()].iter().chain(addrs.iter()) {
        let got = loadgen::eval_words(addr, "s3_5", &[9i32, -9, 77]).unwrap();
        assert_eq!(
            got.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            want,
            "via front {addr} after rejoin"
        );
    }
}

// ---------------------------------------------------------------------
// Replicated routes (read fan-out)
// ---------------------------------------------------------------------

#[test]
fn replicated_routes_fan_out_batches_and_stay_bit_exact() {
    // 3 fronts, static full mesh, replicas=2: each model lives on two
    // ring successors; batches big enough to split fan out across the
    // live replica set and merge in order.
    let (fronts, addrs) =
        start_cluster_fronts_with(3, "native:s3_5", |c| c.replicas = 2);
    let cfg = named_config("s3_5").unwrap();
    let limit = 1i64 << cfg.mag_bits();
    let mut rng = Rng::new(0xFA20);
    let words: Vec<i32> =
        (0..60).map(|_| rng.range_i64(-limit, limit) as i32).collect();
    let want = tanh_golden_batch(
        &words.iter().map(|&w| w as i64).collect::<Vec<_>>(),
        &cfg,
    );
    for addr in &addrs {
        let got = loadgen::eval_words(addr, "s3_5", &words).unwrap();
        assert_eq!(
            got.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            want,
            "fan-out merge not bit-exact via {addr}"
        );
    }
    use std::sync::atomic::Ordering as O;
    let fanouts: u64 = fronts
        .iter()
        .map(|f| f.cluster().unwrap().stats.fanout_batches.load(O::Relaxed))
        .sum();
    assert!(fanouts >= 1, "no batch was fanned out across replicas");

    // Single-word evals are served by any replica — and stay bit-exact
    // from every entry point.
    for addr in &addrs {
        let (status, resp) = loadgen::http_post_json(
            addr,
            "/v1/eval",
            &obj(&[
                ("model", Json::Str("s3_5".into())),
                ("word", Json::Num(words[0] as f64)),
            ]),
        )
        .unwrap();
        assert_eq!(status, 200, "{resp:?}");
        assert_eq!(resp.get("y_word").and_then(Json::as_i64), Some(want[0]));
    }

    // /v1/models reports a two-node replica set per model.
    let (status, body) = loadgen::http_get(&addrs[0], "/v1/models").unwrap();
    assert_eq!(status, 200);
    let v = tanh_vf::util::json::parse(&body).unwrap();
    let model = &v.get("data").and_then(Json::as_arr).unwrap()[0];
    let reps = model.get("replicas").and_then(Json::as_arr).unwrap();
    assert_eq!(reps.len(), 2, "{body}");
}

// ---------------------------------------------------------------------
// Proxy connection pool
// ---------------------------------------------------------------------

#[test]
fn pooled_forward_reuses_connections_across_sequential_requests() {
    // A plain single-node server acts as the peer; a bare Cluster
    // drives its client leg.
    let peer = Server::start(
        ephemeral_cfg(),
        parse_routes("native:s3_5").unwrap(),
    )
    .unwrap();
    let peer_addr = peer.local_addr().to_string();
    let cl = Cluster::start(ClusterConfig {
        advertise: "127.0.0.1:1".into(),
        peers: vec![peer_addr.clone()],
        probe_interval: Duration::from_secs(3600),
        ..Default::default()
    })
    .unwrap();
    let body = br#"{"model":"s3_5","words":[1,2,3]}"#;
    for _ in 0..3 {
        let resp = cl.forward(&peer_addr, "/v1/batch", body, &[]).unwrap();
        assert_eq!(resp.status, 200);
    }
    use std::sync::atomic::Ordering as O;
    assert_eq!(
        cl.pool.stats.misses.load(O::Relaxed),
        1,
        "only the first forward may dial"
    );
    assert_eq!(cl.pool.stats.hits.load(O::Relaxed), 2);
    assert_eq!(cl.pool.idle_count(), 1);
    cl.stop();
}

/// A minimal HTTP peer that *claims* keep-alive but closes after one
/// response per connection — the worst keep-alive liar a pool can
/// meet, and a stand-in for a peer restarting between forwards.
fn one_shot_keepalive_peer() -> (String, std::thread::JoinHandle<()>) {
    use std::io::{Read, Write};
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    let t = std::thread::spawn(move || {
        for _ in 0..8 {
            let Ok((mut s, _)) = l.accept() else { return };
            let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
            // Read one full request: headers + Content-Length body.
            let mut buf = Vec::new();
            let mut chunk = [0u8; 2048];
            let (mut head_end, mut want) = (None, 0usize);
            loop {
                match s.read(&mut chunk) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => buf.extend_from_slice(&chunk[..n]),
                }
                if head_end.is_none() {
                    if let Some(p) =
                        buf.windows(4).position(|w| w == b"\r\n\r\n")
                    {
                        head_end = Some(p + 4);
                        let head =
                            String::from_utf8_lossy(&buf[..p]).to_lowercase();
                        want = head
                            .lines()
                            .find_map(|l| {
                                l.strip_prefix("content-length:")
                                    .and_then(|v| v.trim().parse().ok())
                            })
                            .unwrap_or(0);
                    }
                }
                if let Some(he) = head_end {
                    if buf.len() >= he + want {
                        break;
                    }
                }
            }
            let body = br#"{"ok":true}"#;
            let resp = format!(
                "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
                body.len()
            );
            let _ = s.write_all(resp.as_bytes());
            let _ = s.write_all(body);
            // Drop the socket: the advertised keep-alive was a lie.
        }
    });
    (addr, t)
}

#[test]
fn pooled_forward_discards_and_redials_when_peer_drops_connections() {
    let (peer_addr, peer_thread) = one_shot_keepalive_peer();
    let cl = Cluster::start(ClusterConfig {
        advertise: "127.0.0.1:1".into(),
        peers: vec![peer_addr.clone()],
        probe_interval: Duration::from_secs(3600),
        ..Default::default()
    })
    .unwrap();
    // First forward dials and pools the connection (the peer said
    // keep-alive).
    let r1 = cl.forward(&peer_addr, "/v1/batch", b"{}", &[]).unwrap();
    assert_eq!(r1.status, 200);
    assert_eq!(cl.pool.idle_count(), 1);
    // Second forward checks the dead connection out, fails on it, and
    // must transparently redial — the caller sees one clean success.
    let r2 = cl.forward(&peer_addr, "/v1/batch", b"{}", &[]).unwrap();
    assert_eq!(r2.status, 200);
    use std::sync::atomic::Ordering as O;
    assert_eq!(cl.pool.stats.hits.load(O::Relaxed), 1);
    assert_eq!(
        cl.pool.stats.misses.load(O::Relaxed),
        2,
        "redial after the broken reuse must be a fresh dial"
    );
    assert!(cl.pool.stats.discards.load(O::Relaxed) >= 1);
    cl.stop();
    drop(peer_thread);
}

// ---------------------------------------------------------------------
// Prometheus exposition compliance
// ---------------------------------------------------------------------

#[test]
fn metrics_help_and_type_pair_for_every_family() {
    let (fronts, addrs) = start_cluster_fronts(2, "native:s3_5");
    // Touch the eval path so the cluster counters are exercised.
    let _ = loadgen::eval_words(&addrs[0], "s3_5", &[1, 2]);
    let (status, body) = loadgen::http_get(&addrs[0], "/metrics").unwrap();
    assert_eq!(status, 200);
    let mut helped = std::collections::BTreeSet::new();
    let mut typed = std::collections::BTreeSet::new();
    let mut histograms = std::collections::BTreeSet::new();
    let mut sampled = std::collections::BTreeSet::new();
    let mut premature = Vec::new();
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap().to_string();
            assert!(
                rest.len() > name.len() + 1,
                "HELP without any text: {line}"
            );
            helped.insert(name);
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap().to_string();
            let typ = it.next().unwrap_or("");
            assert!(
                matches!(typ, "counter" | "gauge" | "histogram"),
                "unexpected metric type: {line}"
            );
            if typ == "histogram" {
                histograms.insert(name.clone());
            }
            typed.insert(name);
        } else if !line.trim().is_empty() {
            let mut name = line.split(['{', ' ']).next().unwrap().to_string();
            // Histogram samples carry the family name plus a
            // _bucket/_sum/_count suffix; resolve them to the family.
            for suffix in ["_bucket", "_sum", "_count"] {
                if let Some(base) = name.strip_suffix(suffix) {
                    if histograms.contains(base) {
                        name = base.to_string();
                        break;
                    }
                }
            }
            if !helped.contains(&name) || !typed.contains(&name) {
                premature.push(name.clone());
            }
            sampled.insert(name);
        }
    }
    assert!(
        premature.is_empty(),
        "samples before their HELP/TYPE preamble: {premature:?}"
    );
    assert_eq!(helped, typed, "every family needs both HELP and TYPE");
    for name in &sampled {
        assert!(helped.contains(name), "{name} sampled without metadata");
    }
    // The new cluster-tier families are present.
    for fam in [
        "tanhvf_cluster_pool_checkouts_total",
        "tanhvf_cluster_gossip_total",
        "tanhvf_cluster_members",
        "tanhvf_cluster_membership_events_total",
        "tanhvf_cluster_fanout_batches_total",
        "tanhvf_request_duration_seconds",
        "tanhvf_cluster_forward_duration_seconds",
        "tanhvf_cluster_pool_dial_seconds",
        "tanhvf_spans_dropped_total",
        "tanhvf_trace_store_bytes",
    ] {
        assert!(
            sampled.contains(&fam.to_string()),
            "missing family {fam}"
        );
    }
    // Histogram buckets are cumulative and end in +Inf == _count.
    assert!(
        body.contains("tanhvf_request_duration_seconds_bucket"),
        "request histogram has bucket samples"
    );
    assert!(
        body.contains("le=\"+Inf\""),
        "histograms must expose the +Inf bucket"
    );
    drop(fronts);
}

#[test]
fn keep_alive_and_graceful_shutdown() {
    let routes = parse_routes("native:s3_5").unwrap();
    let mut srv = Server::start(ephemeral_cfg(), routes).unwrap();
    let addr = srv.local_addr().to_string();

    // Two requests over one connection.
    let mut c = connect(&addr);
    for _ in 0..2 {
        c.write_request("GET", "/health", b"").unwrap();
        assert_eq!(c.read_response(1 << 20).unwrap().0, 200);
    }

    srv.shutdown(); // must join promptly, not hang
    assert!(
        TcpStream::connect(&addr).is_err(),
        "listener must be closed after shutdown"
    );
}

// ---------------------------------------------------------------------
// Distributed tracing
// ---------------------------------------------------------------------

const TRACE_HEADER: &str = "x-tanhvf-trace";

/// GET a trace's span tree from `/debug/trace/{id}` and return the
/// parsed root spans.
fn fetch_trace(addr: &str, id: &str) -> Vec<Json> {
    let (status, body) =
        loadgen::http_get(addr, &format!("/debug/trace/{id}")).unwrap();
    assert_eq!(status, 200, "trace {id} not found on {addr}: {body}");
    let v = tanh_vf::util::json::parse(&body).unwrap();
    v.get("spans").and_then(Json::as_arr).unwrap().to_vec()
}

fn span_field<'a>(span: &'a Json, key: &str) -> &'a Json {
    span.get(key).unwrap_or_else(|| panic!("span missing {key}"))
}

fn span_str<'a>(span: &'a Json, key: &str) -> &'a str {
    span_field(span, key).as_str().unwrap()
}

fn span_num(span: &Json, key: &str) -> u64 {
    span_field(span, key).as_f64().unwrap() as u64
}

#[test]
fn trace_propagates_across_proxied_chunked_eval() {
    let (fronts, addrs) = start_cluster_fronts(2, "native:s2_8");
    let cl0 = fronts[0].cluster().unwrap();
    let owner = cl0.owner_name("s2_8").unwrap();
    let (send_to, owner_addr) = if owner == addrs[0] {
        (&addrs[1], &addrs[0])
    } else {
        (&addrs[0], &addrs[1])
    };

    // Chunked POST /v1/eval to the non-owner: the proxy hop re-frames
    // the body as Content-Length while the trace context rides the
    // forward leg's header.
    use std::io::Write;
    let body = r#"{"model":"s2_8","word":7}"#.as_bytes();
    let mut s = TcpStream::connect(send_to.as_str()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(
        b"POST /v1/eval HTTP/1.1\r\nHost: t\r\n\
          Transfer-Encoding: chunked\r\n\r\n",
    )
    .unwrap();
    let (a, b) = body.split_at(9);
    s.write_all(format!("{:x}\r\n", a.len()).as_bytes()).unwrap();
    s.write_all(a).unwrap();
    s.write_all(b"\r\n").unwrap();
    s.write_all(format!("{:x}\r\n", b.len()).as_bytes()).unwrap();
    s.write_all(b).unwrap();
    s.write_all(b"\r\n0\r\n\r\n").unwrap();
    let mut conn = HttpConn::new(s);
    let (status, headers, resp) = conn.read_response(1 << 20).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    let trace_id = headers
        .get(TRACE_HEADER)
        .expect("response carries the trace id")
        .clone();
    assert_eq!(trace_id.len(), 32, "bare trace id: {trace_id}");

    // Front: one server root with a forward child aimed at the owner.
    let roots = fetch_trace(send_to, &trace_id);
    assert_eq!(roots.len(), 1, "front holds one root");
    let root = &roots[0];
    assert_eq!(span_str(root, "kind"), "server");
    assert_eq!(span_str(root, "route"), "/v1/eval");
    let kids = span_field(root, "children").as_arr().unwrap();
    let fwd = kids
        .iter()
        .find(|k| span_str(k, "kind") == "forward")
        .expect("forward child span");
    assert_eq!(span_str(fwd, "peer"), owner_addr.as_str());
    assert_eq!(span_num(fwd, "status"), 200);
    // Monotone within the node: the forward leg nests in the root.
    assert!(span_num(root, "start_us") <= span_num(fwd, "start_us"));
    assert!(span_num(fwd, "start_us") <= span_num(fwd, "end_us"));
    assert!(span_num(fwd, "end_us") <= span_num(root, "end_us"));

    // Owner: its server span joined the same trace, parented by the
    // front's forward span (cross-node propagation by IDs; clocks are
    // per-node, so timestamps only order within one node).
    let owner_roots = fetch_trace(owner_addr, &trace_id);
    assert_eq!(owner_roots.len(), 1, "owner holds one root");
    let oroot = &owner_roots[0];
    assert_eq!(span_str(oroot, "kind"), "server");
    assert_eq!(
        span_str(oroot, "parent_id"),
        span_str(fwd, "span_id"),
        "owner's server span must nest under the forward leg"
    );
    drop(fronts);
}

#[test]
fn trace_covers_replica_fanout_shards() {
    let (fronts, addrs) =
        start_cluster_fronts_with(2, "native:s2_8", |c| c.replicas = 2);
    // 8 words across 2 replicas → one local shard plus one remote
    // shard leg, all under one trace.
    let words: Vec<Json> =
        (0..8).map(|i| Json::Num((i * 3 - 12) as f64)).collect();
    let mut conn = connect(&addrs[0]);
    let body = tanh_vf::util::json::write(&obj(&[
        ("model", Json::Str("s2_8".into())),
        ("words", Json::Arr(words)),
    ]));
    conn.write_request("POST", "/v1/batch", body.as_bytes()).unwrap();
    let (status, headers, resp) = conn.read_response(1 << 20).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    assert_eq!(
        fronts[0]
            .cluster()
            .unwrap()
            .stats
            .fanout_batches
            .load(std::sync::atomic::Ordering::Relaxed),
        1,
        "batch must fan out"
    );
    let trace_id = headers.get(TRACE_HEADER).unwrap().clone();

    // Front tree: server root with the local shard and the remote
    // shard leg as children.
    let roots = fetch_trace(&addrs[0], &trace_id);
    assert_eq!(roots.len(), 1);
    let root = &roots[0];
    assert_eq!(span_str(root, "kind"), "server");
    assert_eq!(span_str(root, "route"), "/v1/batch");
    let kids = span_field(root, "children").as_arr().unwrap();
    let local = kids
        .iter()
        .find(|k| span_str(k, "kind") == "local")
        .expect("local shard span");
    let shard = kids
        .iter()
        .find(|k| span_str(k, "kind") == "shard")
        .expect("remote shard span");
    assert_eq!(span_str(shard, "peer"), addrs[1].as_str());
    for leg in [local, shard] {
        assert!(span_num(leg, "start_us") <= span_num(leg, "end_us"));
        assert!(span_num(root, "start_us") <= span_num(leg, "start_us"));
        assert!(span_num(leg, "end_us") <= span_num(root, "end_us"));
    }

    // Replica: its server span nests under the front's shard leg —
    // client → front → shard, stitched across nodes by span IDs.
    let rep_roots = fetch_trace(&addrs[1], &trace_id);
    assert_eq!(rep_roots.len(), 1);
    assert_eq!(
        span_str(&rep_roots[0], "parent_id"),
        span_str(shard, "span_id")
    );
    drop(fronts);
}

#[test]
fn debug_trace_answers_404_for_unknown_and_400_for_garbage() {
    let (_srv, addr) = start_two_precision();
    let unknown = "0123456789abcdef0123456789abcdef";
    let (status, _) =
        loadgen::http_get(&addr, &format!("/debug/trace/{unknown}")).unwrap();
    assert_eq!(status, 404);
    let (status, _) =
        loadgen::http_get(&addr, "/debug/trace/not-a-trace-id").unwrap();
    assert_eq!(status, 400);
}

#[test]
fn loadgen_trace_sampling_captures_slowest_span_tree() {
    let (_srv, addr) = start_two_precision();
    let mut cfg = LoadgenConfig::new(addr, &["s3_12"]);
    cfg.connections = 2;
    cfg.requests_per_connection = 10;
    cfg.words_per_request = 16;
    cfg.trace_sample = 2;
    let report = loadgen::run(&cfg).unwrap();
    assert_eq!(report.failures, 0, "{}", report.render());
    let id = report.slowest_trace_id.as_deref().expect("sampled trace id");
    assert_eq!(id.len(), 32);
    let tree = report.slowest_trace.as_ref().expect("sampled span tree");
    let spans = tree.get("spans").and_then(Json::as_arr).unwrap();
    assert!(!spans.is_empty(), "slowest trace renders at least one span");
    let json = tanh_vf::util::json::write(&report.to_json());
    assert!(json.contains("slowest_trace_id"), "{json}");
}
