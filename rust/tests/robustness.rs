//! Failure injection + cross-module property tests: the suite that
//! checks the system degrades loudly and correctly, not silently.

use tanh_vf::proptest::{assert_prop, int};
use tanh_vf::runtime::Manifest;
use tanh_vf::synth::datapath::{build_tanh_datapath, eval_datapath};
use tanh_vf::synth::pipeline::assign_stages;
use tanh_vf::tanh::{Subtractor, TanhConfig, TanhUnit};
use tanh_vf::util::json;

// ---------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tanhvf-test-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn corrupt_manifest_is_a_loud_error() {
    let dir = tmpdir("corrupt-manifest");
    std::fs::write(dir.join("manifest.json"), "{\"entries\": [not json").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
}

#[test]
fn missing_manifest_mentions_make_artifacts() {
    let dir = tmpdir("missing-manifest");
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn manifest_with_bad_dtype_rejected() {
    let dir = tmpdir("bad-dtype");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"entries": {"m": {"file": "m.hlo.txt",
            "inputs": [{"name": "x", "shape": [4], "dtype": "f64"}],
            "outputs": []}}}"#,
    )
    .unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn json_parser_rejects_truncation_at_every_prefix() {
    // Robustness sweep: no prefix of a valid document may parse.
    let doc = r#"{"a": [1, 2.5, true], "b": {"c": "x\n"}}"#;
    for cut in 1..doc.len() {
        let prefix = &doc[..cut];
        if prefix.trim() == doc.trim() {
            continue;
        }
        assert!(
            json::parse(prefix).is_err(),
            "prefix of length {cut} should not parse: {prefix:?}"
        );
    }
    assert!(json::parse(doc).is_ok());
}

#[test]
fn invalid_configs_fail_construction_not_evaluation() {
    let mut cfg = TanhConfig::s3_12();
    cfg.lut_bits = 3; // < mult_bits - 1
    assert!(TanhUnit::new(cfg).is_err());
    let mut cfg = TanhConfig::s3_12();
    cfg.in_int = 40; // blows the i64 headroom guard
    cfg.in_frac = 20;
    assert!(TanhUnit::new(cfg).is_err());
}

// ---------------------------------------------------------------------
// Cross-module property tests
// ---------------------------------------------------------------------

#[test]
fn property_netlist_equals_unit_for_random_configs() {
    // For randomized (nr, group, shuffle, subtractor) configurations,
    // the structural netlist and the optimized unit agree word-for-word
    // on random inputs.
    let g = int(0, i64::MAX - 1);
    assert_prop("netlist==unit over configs", 0xBEEF, 60, &g, |&seed| {
        let mut rng = tanh_vf::util::rng::Rng::new(seed as u64);
        let cfg = TanhConfig {
            in_int: 3,
            in_frac: 5 + rng.below(8) as u32,
            out_frac: 7 + rng.below(9) as u32,
            lut_bits: 0,
            mult_bits: 0,
            lut_group: 1 + rng.below(5) as u32,
            shuffle: rng.below(2) == 1,
            nr_stages: 1 + rng.below(3) as u32,
            subtractor: if rng.below(2) == 1 {
                Subtractor::Ones
            } else {
                Subtractor::Twos
            },
        };
        let cfg = TanhConfig {
            lut_bits: cfg.out_frac + 3,
            mult_bits: cfg.out_frac + 1,
            ..cfg
        };
        if cfg.validate().is_err() {
            return Ok(()); // skip invalid corners
        }
        let unit = TanhUnit::new(cfg).map_err(|e| e)?;
        let net = build_tanh_datapath(&cfg);
        let half = 1i64 << cfg.mag_bits();
        for _ in 0..24 {
            let x = rng.range_i64(-half, half);
            let a = unit.eval(x);
            let b = eval_datapath(&net, x);
            if a != b {
                return Err(format!("{}: x={x} unit={a} netlist={b}",
                                   cfg.describe()));
            }
        }
        Ok(())
    });
}

#[test]
fn property_pipeline_legal_for_any_stage_count() {
    let net = build_tanh_datapath(&TanhConfig::s3_12());
    let g = int(1, 40);
    assert_prop("pipeline legality", 0xCAFE, 40, &g, |&stages| {
        let p = assign_stages(&net, stages as u32);
        for (id, node) in net.nodes.iter().enumerate() {
            for &i in &node.inputs {
                if p.stage_of[i] > p.stage_of[id] {
                    return Err(format!("edge {i}->{id} goes backwards"));
                }
            }
        }
        if p.worst_stage_levels() <= 0.0 {
            return Err("empty critical path".into());
        }
        // Register bits monotone-ish in stages is NOT required (depends
        // on cut placement), but output register must always exist.
        if p.reg_bits < 16 {
            return Err(format!("reg_bits {} too small", p.reg_bits));
        }
        Ok(())
    });
}

#[test]
fn property_unit_bounded_and_odd_for_all_configs() {
    let g = int(0, i64::MAX - 1);
    assert_prop("unit bounded+odd", 0xF00D, 40, &g, |&seed| {
        let mut rng = tanh_vf::util::rng::Rng::new(seed as u64);
        let in_frac = 4 + rng.below(9) as u32;
        let out_frac = 6 + rng.below(10) as u32;
        let cfg = TanhConfig {
            in_int: 2 + rng.below(3) as u32,
            in_frac,
            out_frac,
            lut_bits: out_frac + 3,
            mult_bits: out_frac + 1,
            lut_group: 3 + rng.below(3) as u32,
            shuffle: true,
            nr_stages: 3,
            subtractor: Subtractor::Twos,
        };
        if cfg.validate().is_err() {
            return Ok(());
        }
        let unit = TanhUnit::new(cfg)?;
        let half = 1i64 << cfg.mag_bits();
        for _ in 0..32 {
            let x = rng.range_i64(-(half - 1), half);
            let y = unit.eval(x);
            if y.abs() > cfg.out_max() {
                return Err(format!("{}: |{y}| > out_max", cfg.describe()));
            }
            if unit.eval(-x) != -y {
                return Err(format!("{}: not odd at {x}", cfg.describe()));
            }
        }
        Ok(())
    });
}

#[test]
fn property_verilog_generates_for_random_configs() {
    let g = int(1, 10);
    assert_prop("verilog generation", 0xDEAD, 12, &g, |&stages| {
        for cfg in [TanhConfig::s3_12(), TanhConfig::s3_5()] {
            let out = tanh_vf::verilog::generate(&cfg, stages as u32, 16);
            if !out.module.contains("endmodule") {
                return Err("no endmodule".into());
            }
            if out.module.matches("case (").count()
                != out.module.matches("endcase").count()
            {
                return Err("unbalanced case".into());
            }
        }
        Ok(())
    });
}

#[test]
fn rtl_sim_rejects_mismatched_pipeline() {
    let net16 = build_tanh_datapath(&TanhConfig::s3_12());
    let net8 = build_tanh_datapath(&TanhConfig::s3_5());
    let pipe8 = assign_stages(&net8, 2);
    // Different node counts: constructor must panic (assert), not read OOB.
    let result = std::panic::catch_unwind(|| {
        tanh_vf::rtl::RtlSim::new(&net16, &pipe8)
    });
    assert!(result.is_err());
}
