//! Cross-layer bit-exactness: replay the golden vectors emitted by the
//! python oracle (`make artifacts` -> `artifacts/golden_vectors.json`)
//! through every rust implementation of the datapath:
//!
//!   python numpy oracle == rust golden model == TanhUnit (live + memo)
//!   == structural netlist == cycle-accurate RTL simulation.
//!
//! This is the test that makes "the same hardware, specified once" a
//! checked property rather than a claim.

use tanh_vf::rtl::RtlSim;
use tanh_vf::synth::datapath::{build_tanh_datapath, eval_datapath};
use tanh_vf::synth::pipeline::assign_stages;
use tanh_vf::tanh::golden::tanh_golden_with_tables;
use tanh_vf::tanh::lut::lut_tables;
use tanh_vf::tanh::{Subtractor, TanhConfig, TanhUnit};
use tanh_vf::util::json::{self, Json};

fn load_vectors() -> Option<Json> {
    let path = tanh_vf::runtime::artifacts_dir().join("golden_vectors.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(json::parse(&text).expect("golden_vectors.json parses"))
}

fn config_from(v: &Json) -> TanhConfig {
    let c = v.get("config").expect("config");
    let get = |k: &str| c.get(k).and_then(Json::as_i64).unwrap() as u32;
    TanhConfig {
        in_int: get("in_int"),
        in_frac: get("in_frac"),
        out_frac: get("out_frac"),
        lut_bits: get("lut_bits"),
        mult_bits: get("mult_bits"),
        lut_group: get("lut_group"),
        shuffle: c.get("shuffle").and_then(Json::as_bool).unwrap(),
        nr_stages: get("nr_stages"),
        subtractor: match c.get("subtractor").and_then(Json::as_str).unwrap() {
            "ones" => Subtractor::Ones,
            _ => Subtractor::Twos,
        },
    }
}

fn vectors_of(v: &Json) -> (Vec<i64>, Vec<i64>) {
    (
        v.get("inputs").and_then(Json::as_i64_vec).unwrap(),
        v.get("outputs").and_then(Json::as_i64_vec).unwrap(),
    )
}

#[test]
fn python_oracle_matches_rust_golden_model() {
    let Some(root) = load_vectors() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    for key in ["tanh_s3_12", "tanh_s3_5", "tanh_s3_12_nr2_ones"] {
        let entry = root.get(key).expect(key);
        let cfg = config_from(entry);
        let (xs, want) = vectors_of(entry);
        let tables = lut_tables(&cfg);
        for (&x, &w) in xs.iter().zip(&want) {
            let got = tanh_golden_with_tables(x, &cfg, &tables);
            assert_eq!(got, w, "{key}: x={x}");
        }
    }
}

#[test]
fn python_oracle_matches_tanh_unit_live_and_memo() {
    let Some(root) = load_vectors() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    for key in ["tanh_s3_12", "tanh_s3_5"] {
        let entry = root.get(key).expect(key);
        let cfg = config_from(entry);
        let (xs, want) = vectors_of(entry);
        let mut unit = TanhUnit::new(cfg).unwrap();
        for (&x, &w) in xs.iter().zip(&want) {
            assert_eq!(unit.eval(x), w, "{key} live: x={x}");
        }
        unit.precompute_all();
        for (&x, &w) in xs.iter().zip(&want) {
            assert_eq!(unit.eval(x), w, "{key} memo: x={x}");
        }
    }
}

#[test]
fn python_oracle_matches_structural_netlist() {
    let Some(root) = load_vectors() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    for key in ["tanh_s3_12", "tanh_s3_5", "tanh_s3_12_nr2_ones"] {
        let entry = root.get(key).expect(key);
        let cfg = config_from(entry);
        let (xs, want) = vectors_of(entry);
        let net = build_tanh_datapath(&cfg);
        for (&x, &w) in xs.iter().zip(&want) {
            assert_eq!(eval_datapath(&net, x), w, "{key}: x={x}");
        }
    }
}

#[test]
fn python_oracle_matches_pipelined_rtl_sim() {
    let Some(root) = load_vectors() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let entry = root.get("tanh_s3_12").unwrap();
    let cfg = config_from(entry);
    let (xs, want) = vectors_of(entry);
    let net = build_tanh_datapath(&cfg);
    for stages in [1u32, 2, 7] {
        let pipe = assign_stages(&net, stages);
        let mut sim = RtlSim::new(&net, &pipe);
        let (got, cycles) = sim.run_batch(&xs);
        assert_eq!(got, want, "stages={stages}");
        assert_eq!(cycles, xs.len() as u64 + stages as u64);
    }
}

#[test]
fn exhaustive_max_error_matches_python_report() {
    // The python oracle records its exhaustive max error; the rust unit
    // must land on exactly the same accuracy (same datapath).
    let Some(root) = load_vectors() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    for key in ["tanh_s3_12", "tanh_s3_5"] {
        let entry = root.get(key).unwrap();
        let cfg = config_from(entry);
        let py_err = entry
            .get("exhaustive_max_error")
            .and_then(Json::as_f64)
            .unwrap();
        let unit = TanhUnit::new(cfg).unwrap();
        let stats = tanh_vf::analysis::exhaustive_error(&unit);
        let rel = (stats.max_abs - py_err).abs() / py_err;
        assert!(rel < 1e-9, "{key}: rust {} vs python {py_err}", stats.max_abs);
    }
}
