//! SIMD vs scalar bit-exactness: the vectorized batch kernels must be
//! indistinguishable from the golden model under every [`SimdMode`],
//! on every precision preset and datapath variant, including boundary
//! inputs (format extremes, saturation edges) and ragged batch lengths
//! that leave partial vector lanes.
//!
//! On hosts without AVX2 the `Avx2` rows silently exercise the scalar
//! fallback — still a valid run (the CI `simd` job pins the feature on
//! one leg and `TANHVF_SIMD=off` on another, so all three paths get
//! real coverage somewhere).

use tanh_vf::analysis::TanhImpl;
use tanh_vf::baselines::dctif::Dctif;
use tanh_vf::baselines::fmt16;
use tanh_vf::baselines::pwl::Pwl;
use tanh_vf::baselines::ralut::RangeLut;
use tanh_vf::tanh::golden::tanh_golden_batch;
use tanh_vf::tanh::{SigmoidUnit, SimdMode, Subtractor, TanhConfig, TanhUnit};
use tanh_vf::util::rng::Rng;

const MODES: [SimdMode; 3] =
    [SimdMode::Off, SimdMode::Scalar, SimdMode::Avx2];

/// Presets plus datapath variants that steer the kernel down every
/// branch: float divider (nr=0, SIMD-ineligible), each NR depth, both
/// subtractors, odd LUT groupings, unshuffled addressing.
fn variant_configs() -> Vec<TanhConfig> {
    let v = vec![
        TanhConfig::s3_12(),
        TanhConfig::s3_5(),
        TanhConfig::s3_12().with_nr(0),
        TanhConfig::s3_12().with_nr(1),
        TanhConfig::s3_12().with_nr(4),
        TanhConfig::s3_12().with_subtractor(Subtractor::Ones),
        TanhConfig::s3_12().with_group(2),
        TanhConfig::s3_12().with_group(5),
        TanhConfig::s3_12().with_shuffle(false),
        TanhConfig::s3_5().with_subtractor(Subtractor::Ones),
        TanhConfig::s3_5().with_shuffle(false),
    ];
    for c in &v {
        c.validate().unwrap();
    }
    v
}

/// Format extremes, zero neighborhood, and both sides of the
/// saturation threshold — the words most likely to expose a lane that
/// rounds, clamps, or sign-extends differently from the scalar path.
fn boundary_words(cfg: &TanhConfig) -> Vec<i64> {
    let mag = 1i64 << cfg.mag_bits();
    let sat = cfg.sat_threshold();
    let mut v = vec![0, 1, -1, 2, -2, mag - 1, -mag, 1 - mag];
    for d in -2..=2 {
        v.push(sat + d);
        v.push(-(sat + d));
    }
    v.retain(|&x| x >= -mag && x < mag);
    v
}

/// First-mismatch assertion: a 64k-element `assert_eq!` dump is
/// useless; the failing word is what matters.
fn assert_words_eq(got: &[i64], want: &[i64], xs: &[i64], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g, w,
            "{tag}: x={} (index {i}): got {g}, want {w}",
            xs[i]
        );
    }
}

#[test]
fn presets_bit_exact_over_full_domain_all_modes() {
    for cfg in [TanhConfig::s3_12(), TanhConfig::s3_5()] {
        let mag = 1i64 << cfg.mag_bits();
        let xs: Vec<i64> = (-mag..mag).collect();
        let want = tanh_golden_batch(&xs, &cfg);
        let live = TanhUnit::new(cfg).unwrap();
        let mut memo = TanhUnit::new(cfg).unwrap();
        memo.precompute_all();
        let mut out = vec![0i64; xs.len()];
        for mode in MODES {
            let tag = format!("live/{} {}", mode.name(), cfg.describe());
            live.eval_batch_mode(mode, &xs, &mut out);
            assert_words_eq(&out, &want, &xs, &tag);
            let tag = format!("memo/{} {}", mode.name(), cfg.describe());
            memo.eval_batch_mode(mode, &xs, &mut out);
            assert_words_eq(&out, &want, &xs, &tag);
        }
    }
}

#[test]
fn variants_bit_exact_with_ragged_tails() {
    let mut rng = Rng::new(0x51_3d);
    for cfg in variant_configs() {
        let live = TanhUnit::new(cfg).unwrap();
        let mag = 1i64 << cfg.mag_bits();
        let mut pool = boundary_words(&cfg);
        while pool.len() < 1200 {
            pool.push(rng.range_i64(-mag, mag));
        }
        let want = tanh_golden_batch(&pool, &cfg);
        // Lengths straddling the 4-lane vector width: empty, single,
        // sub-vector, vector+tail, and long-with-odd-tail shapes.
        for len in [0usize, 1, 3, 5, 7, 9, 17, 31, 33, 100, 1023] {
            let len = len.min(pool.len());
            let mut out = vec![0i64; len];
            for mode in MODES {
                live.eval_batch_mode(mode, &pool[..len], &mut out);
                let tag = format!(
                    "live/{}/len={len} {}",
                    mode.name(),
                    cfg.describe()
                );
                assert_words_eq(&out, &want[..len], &pool[..len], &tag);
            }
        }
    }
}

#[test]
fn memoized_variants_bit_exact_all_modes() {
    // Memoization swaps the datapath for a gather; the SIMD gather
    // must agree on variants too (grouping/shuffle change the tables
    // the memo was built from, not the memo lookup itself).
    for cfg in [
        TanhConfig::s3_12().with_group(2),
        TanhConfig::s3_12().with_shuffle(false),
        TanhConfig::s3_5().with_subtractor(Subtractor::Ones),
    ] {
        let mut memo = TanhUnit::new(cfg).unwrap();
        memo.precompute_all();
        let mag = 1i64 << cfg.mag_bits();
        let xs: Vec<i64> = (-mag..mag).step_by(3).collect();
        let want = tanh_golden_batch(&xs, &cfg);
        let mut out = vec![0i64; xs.len()];
        for mode in MODES {
            memo.eval_batch_mode(mode, &xs, &mut out);
            let tag = format!("memo/{} {}", mode.name(), cfg.describe());
            assert_words_eq(&out, &want, &xs, &tag);
        }
    }
}

#[test]
fn i32_batch_matches_scalar_eval() {
    // The coordinator's wire-type path (the PR fixes it to reuse the
    // batch kernels instead of per-element `eval` calls).
    for cfg in [TanhConfig::s3_12(), TanhConfig::s3_5()] {
        let live = TanhUnit::new(cfg).unwrap();
        let mut memo = TanhUnit::new(cfg).unwrap();
        memo.precompute_all();
        let mag = 1i64 << cfg.mag_bits();
        let xs32: Vec<i32> = (-mag..mag).map(|x| x as i32).collect();
        let mut out32 = vec![0i32; xs32.len()];
        for (tag, unit) in [("live", &live), ("memo", &memo)] {
            unit.eval_batch_i32_into(&xs32, &mut out32);
            for (&x, &y) in xs32.iter().zip(&out32) {
                assert_eq!(
                    y as i64,
                    unit.eval(x as i64),
                    "{tag} i32 path at x={x} ({})",
                    cfg.describe()
                );
            }
        }
    }
}

#[test]
fn sigmoid_batch_matches_per_word_across_presets() {
    for cfg in [TanhConfig::s3_12(), TanhConfig::s3_5()] {
        let sig = SigmoidUnit::new(cfg).unwrap();
        let mag = 1i64 << cfg.mag_bits();
        let xs: Vec<i64> = (-mag..mag).step_by(3).collect();
        let mut out = vec![0i64; xs.len()];
        sig.eval_batch_into(&xs, &mut out);
        for (&x, &y) in xs.iter().zip(&out) {
            assert_eq!(y, sig.eval(x), "sigmoid at x={x} ({})",
                       cfg.describe());
        }
    }
}

#[test]
fn baseline_batch_overrides_match_per_word() {
    let (fi, fo) = fmt16();
    let pwl = Pwl::new(fi, fo, 64);
    let dctif = Dctif::new(fi, fo, 4, 64);
    let ralut = RangeLut::new(fi, fo, 6);
    let impls: [&dyn TanhImpl; 3] = [&pwl, &dctif, &ralut];
    let mut xs: Vec<i64> = (-32768..32768).step_by(11).collect();
    xs.extend([0, 1, -1, 32767, -32768, -32767]);
    for imp in impls {
        let mut out = vec![0i64; xs.len()];
        imp.eval_batch_words(&xs, &mut out);
        for (&x, &y) in xs.iter().zip(&out) {
            assert_eq!(y, imp.eval_word(x), "{} at x={x}", imp.name());
        }
    }
}
