//! PJRT integration: the AOT-compiled JAX/Pallas artifacts, executed
//! from rust, must agree with the native implementations — the L1/L2/L3
//! composition proof.

use tanh_vf::runtime::{artifacts_dir, Runtime, Tensor};
use tanh_vf::tanh::{TanhConfig, TanhUnit};
use tanh_vf::util::json::{self, Json};

fn runtime() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("pjrt cpu client"))
}

fn golden() -> Option<Json> {
    let text =
        std::fs::read_to_string(artifacts_dir().join("golden_vectors.json"))
            .ok()?;
    Some(json::parse(&text).unwrap())
}

#[test]
fn tanh_artifact_matches_native_unit_bit_exactly() {
    let Some(rt) = runtime() else { return };
    let entry = rt.entry("tanh_s3_12").unwrap();
    let n = entry.inputs[0].elements();

    let mut rng = tanh_vf::util::rng::Rng::new(0xA07);
    let words: Vec<i32> =
        (0..n).map(|_| rng.range_i64(-32768, 32768) as i32).collect();
    let out = rt
        .execute("tanh_s3_12", &[Tensor::I32(words.clone())])
        .expect("execute");
    let got = out[0].as_i32().unwrap();

    let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
    let want = unit.eval_batch_i32(&words);
    assert_eq!(got, want.as_slice());
}

#[test]
fn tanh_8bit_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let n = rt.entry("tanh_s3_5").unwrap().inputs[0].elements();
    let mut rng = tanh_vf::util::rng::Rng::new(77);
    let words: Vec<i32> =
        (0..n).map(|_| rng.range_i64(-256, 256) as i32).collect();
    let out = rt
        .execute("tanh_s3_5", &[Tensor::I32(words.clone())])
        .unwrap();
    let unit = TanhUnit::new(TanhConfig::s3_5()).unwrap();
    assert_eq!(out[0].as_i32().unwrap(), unit.eval_batch_i32(&words).as_slice());
}

#[test]
fn tanh_artifact_matches_python_golden_vectors() {
    let (Some(rt), Some(g)) = (runtime(), golden()) else { return };
    let entry = g.get("tanh_s3_12").unwrap();
    let xs: Vec<i32> = entry
        .get("inputs")
        .and_then(Json::as_i64_vec)
        .unwrap()
        .iter()
        .map(|&v| v as i32)
        .collect();
    let want: Vec<i32> = entry
        .get("outputs")
        .and_then(Json::as_i64_vec)
        .unwrap()
        .iter()
        .map(|&v| v as i32)
        .collect();
    let out = rt.execute("tanh_s3_12", &[Tensor::I32(xs)]).unwrap();
    assert_eq!(out[0].as_i32().unwrap(), want.as_slice());
}

#[test]
fn mlp_artifact_matches_python_golden() {
    let (Some(rt), Some(g)) = (runtime(), golden()) else { return };
    let entry = g.get("mlp_b32").unwrap();
    let f32s = |k: &str| -> Vec<f32> {
        entry
            .get(k)
            .and_then(Json::as_f64_vec)
            .unwrap()
            .iter()
            .map(|&v| v as f32)
            .collect()
    };
    let params = entry.get("params").unwrap();
    let p32 = |k: &str| -> Vec<f32> {
        params
            .get(k)
            .and_then(Json::as_f64_vec)
            .unwrap()
            .iter()
            .map(|&v| v as f32)
            .collect()
    };
    let inputs = vec![
        Tensor::F32(f32s("x")),
        Tensor::F32(p32("w1")),
        Tensor::F32(p32("b1")),
        Tensor::F32(p32("w2")),
        Tensor::F32(p32("b2")),
        Tensor::F32(p32("w3")),
        Tensor::F32(p32("b3")),
    ];
    let out = rt.execute("mlp_b32", &inputs).unwrap();
    let got = out[0].as_f32().unwrap();
    let want = f32s("logits");
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * b.abs().max(1.0),
            "logit {i}: {a} vs {b}"
        );
    }
}

#[test]
fn lstm_cell_artifact_matches_python_golden() {
    let (Some(rt), Some(g)) = (runtime(), golden()) else { return };
    let entry = g.get("lstm_cell_b16").unwrap();
    let f32s = |v: &Json| -> Vec<f32> {
        v.as_f64_vec().unwrap().iter().map(|&x| x as f32).collect()
    };
    let params = entry.get("params").unwrap();
    let inputs = vec![
        Tensor::F32(f32s(entry.get("x").unwrap())),
        Tensor::F32(f32s(entry.get("h").unwrap())),
        Tensor::F32(f32s(entry.get("c").unwrap())),
        Tensor::F32(f32s(params.get("wx").unwrap())),
        Tensor::F32(f32s(params.get("wh").unwrap())),
        Tensor::F32(f32s(params.get("b").unwrap())),
    ];
    let out = rt.execute("lstm_cell_b16", &inputs).unwrap();
    let want_h = f32s(entry.get("h_new").unwrap());
    let want_c = f32s(entry.get("c_new").unwrap());
    let got_h = out[0].as_f32().unwrap();
    let got_c = out[1].as_f32().unwrap();
    for (a, b) in got_h.iter().zip(&want_h) {
        assert!((a - b).abs() <= 1e-5, "h: {a} vs {b}");
    }
    for (a, b) in got_c.iter().zip(&want_c) {
        assert!((a - b).abs() <= 1e-5, "c: {a} vs {b}");
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(rt) = runtime() else { return };
    rt.ensure_compiled("tanh_s3_12").unwrap();
    let t0 = std::time::Instant::now();
    rt.ensure_compiled("tanh_s3_12").unwrap();
    // Cached path must be instant (no recompile).
    assert!(t0.elapsed() < std::time::Duration::from_millis(50));
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    // Wrong length.
    assert!(rt
        .execute("tanh_s3_12", &[Tensor::I32(vec![0; 17])])
        .is_err());
    // Wrong dtype.
    assert!(rt
        .execute("tanh_s3_12", &[Tensor::F32(vec![0.0; 1024])])
        .is_err());
    // Wrong arity.
    assert!(rt.execute("mlp_b32", &[Tensor::F32(vec![0.0; 2048])]).is_err());
    // Unknown entry.
    assert!(rt.execute("nope", &[]).is_err());
}
