//! Wire-hardening for `util::json`: now that JSON crosses the HTTP
//! boundary, the writer and parser must round-trip arbitrary documents —
//! control characters, surrogate pairs, astral plane, deep nesting —
//! property-tested through the crate's own proptest module.

use tanh_vf::proptest::{assert_prop, Gen};
use tanh_vf::util::json::{parse, write, Json};
use tanh_vf::util::rng::Rng;

/// Strings drawn from the nasty corners: control chars, JSON
/// metacharacters, multi-byte UTF-8, astral-plane (surrogate-pair) code
/// points, and the BMP boundary values.
fn random_string(rng: &mut Rng) -> String {
    const POOL: &[char] = &[
        'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t',
        '\u{0}', '\u{1}', '\u{8}', '\u{b}', '\u{c}', '\u{1f}', '\u{7f}',
        'é', '☃', '中', '\u{d7ff}', '\u{e000}', '\u{fffd}',
        '😀', '\u{10000}', '\u{10ffff}',
    ];
    let n = rng.below(12);
    (0..n).map(|_| POOL[rng.below(POOL.len() as u64) as usize]).collect()
}

/// Random JSON value, numbers restricted to exactly-representable
/// integers and dyadic rationals so equality is well-defined.
fn random_json(rng: &mut Rng, depth: u32) -> Json {
    let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 1),
        2 => {
            let int = rng.range_i64(-1_000_000, 1_000_000);
            if rng.below(2) == 0 {
                Json::Num(int as f64)
            } else {
                Json::Num(int as f64 / (1u64 << rng.below(20)) as f64)
            }
        }
        3 => Json::Str(random_string(rng)),
        4 => Json::Arr(
            (0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|_| (random_string(rng), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn property_parse_write_roundtrip() {
    let gen = Gen::new(
        |rng: &mut Rng| random_json(rng, 3),
        |_| vec![], // no shrinking for structured values
    );
    assert_prop("json parse<->write roundtrip", 0x1A7E, 600, &gen, |v| {
        let text = write(v);
        match parse(&text) {
            Ok(back) if back == *v => Ok(()),
            Ok(back) => Err(format!("wrote {text:?}, reparsed as {back:?}")),
            Err(e) => Err(format!("wrote {text:?}, reparse failed: {e}")),
        }
    });
}

#[test]
fn property_written_strings_are_ascii_safe_json() {
    // Whatever we emit must itself be valid JSON for *other* parsers:
    // no raw control bytes may survive in the output.
    let gen = Gen::new(|rng: &mut Rng| random_string(rng), |_| vec![]);
    assert_prop("writer escapes control bytes", 0x5AFE, 400, &gen, |s| {
        let text = write(&Json::Str(s.clone()));
        if text.bytes().any(|b| b < 0x20) {
            Err(format!("raw control byte in {text:?}"))
        } else {
            Ok(())
        }
    });
}

#[test]
fn escaped_surrogate_pairs_equal_raw_utf8() {
    let escaped = parse("\"\\uD83D\\uDE00\"").unwrap();
    let raw = parse("\"😀\"").unwrap();
    assert_eq!(escaped, raw);
    assert_eq!(parse(&write(&escaped)).unwrap(), raw);
}

#[test]
fn all_control_characters_roundtrip_in_one_string() {
    let s: String =
        (0u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
    let v = Json::Obj(
        [(s.clone(), Json::Str(s))].into_iter().collect(),
    );
    assert_eq!(parse(&write(&v)).unwrap(), v);
}

#[test]
fn deep_nesting_roundtrips() {
    let mut v = Json::Num(7.0);
    for i in 0..300 {
        v = if i % 2 == 0 {
            Json::Arr(vec![v])
        } else {
            Json::Obj([("k".to_string(), v)].into_iter().collect())
        };
    }
    let text = write(&v);
    assert_eq!(parse(&text).unwrap(), v);
}
