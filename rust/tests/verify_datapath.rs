//! Static datapath verifier vs reality.
//!
//! Three directions of cross-checking:
//!
//! 1. **Soundness on shipped configs** — every preset (canonical +
//!    derived) and every datapath variant the SIMD suite exercises must
//!    be fully PROVEN, and the static worst-case error bound must
//!    dominate the empirically measured max error from the exhaustive
//!    sweep (while staying finite/non-vacuous).
//! 2. **Gate soundness** — a grid sweep over the config space asserts
//!    that every config `datapath_eligible` admits is verifier-provable
//!    (exact low-32 multiplies, non-negative shift operands), i.e. the
//!    gate constants really are re-derived, not wishful.
//! 3. **Mutation coverage** — deliberately broken datapaths (oversized
//!    LUT, truncated multiplier, divergent seed, halved saturation
//!    threshold, ineligible config forced down the AVX2 path) must each
//!    be REJECTED, and by the specific obligation that models the break.

use tanh_vf::analysis::verify::{
    all_preset_names, simd_gate, verify, verify_params, DatapathParams,
    DERIVED_PRESETS, SHIPPED_PRESETS,
};
use tanh_vf::analysis::exhaustive_error;
use tanh_vf::server::named_config;
use tanh_vf::tanh::{SimdMode, Subtractor, TanhConfig, TanhUnit};
use tanh_vf::util::json;

/// The SIMD suite's datapath variants (kept in sync by hand; these are
/// cheap to verify so drift just adds coverage, never loses it).
fn variant_configs() -> Vec<TanhConfig> {
    vec![
        TanhConfig::s3_12(),
        TanhConfig::s3_5(),
        TanhConfig::s3_12().with_nr(0),
        TanhConfig::s3_12().with_nr(1),
        TanhConfig::s3_12().with_nr(4),
        TanhConfig::s3_12().with_subtractor(Subtractor::Ones),
        TanhConfig::s3_12().with_group(2),
        TanhConfig::s3_12().with_group(5),
        TanhConfig::s3_12().with_shuffle(false),
        TanhConfig::s3_5().with_subtractor(Subtractor::Ones),
        TanhConfig::s3_5().with_shuffle(false),
    ]
}

fn check_proven_and_dominating(cfg: &TanhConfig, tag: &str) {
    let rep = verify(cfg);
    assert!(
        rep.proven(),
        "{tag}: expected PROVEN, failed: {:?}",
        rep.failed()
    );
    let static_ulp = rep
        .static_max_ulp
        .unwrap_or_else(|| panic!("{tag}: no static bound"));
    let unit = TanhUnit::new(*cfg).unwrap();
    let emp = exhaustive_error(&unit).max_lsb(cfg.out_format());
    assert!(
        static_ulp >= emp,
        "{tag}: static bound {static_ulp:.3} < empirical {emp:.3}"
    );
}

#[test]
fn every_preset_is_proven_and_bound_dominates_empirical() {
    assert_eq!(all_preset_names().len(),
               SHIPPED_PRESETS.len() + DERIVED_PRESETS.len());
    for name in all_preset_names() {
        let cfg = named_config(name).unwrap();
        check_proven_and_dominating(&cfg, name);
        // Non-vacuity: a bound of "anything under 2^out lsb" proves
        // nothing. Shipped presets are all within a few lsb; 64 leaves
        // generous analysis slack while still excluding junk bounds.
        let rep = verify(&cfg);
        assert!(
            rep.static_max_ulp.unwrap() <= 64.0,
            "{name}: static bound {:.3} is vacuous",
            rep.static_max_ulp.unwrap()
        );
    }
}

#[test]
fn every_simd_suite_variant_is_proven_and_dominated() {
    for cfg in variant_configs() {
        cfg.validate().unwrap();
        check_proven_and_dominating(&cfg, &cfg.describe());
    }
}

#[test]
fn admitted_configs_are_bit_exact_under_avx2() {
    // The gate-soundness claim, checked dynamically where it matters:
    // for every *admitted* preset/variant, the Avx2 batch mode must be
    // bit-exact against the plain per-word loop over the full domain.
    // (On non-AVX2 hosts this degrades to scalar-vs-scalar — the CI
    // `simd` job pins a leg with the feature enabled.)
    for cfg in variant_configs() {
        if !simd_gate(&cfg) {
            continue;
        }
        let unit = TanhUnit::new(cfg).unwrap();
        let mag = 1i64 << cfg.mag_bits();
        let xs: Vec<i64> = (-mag..mag).collect();
        let mut scalar = vec![0i64; xs.len()];
        let mut vector = vec![0i64; xs.len()];
        unit.eval_batch_mode(SimdMode::Off, &xs, &mut scalar);
        unit.eval_batch_mode(SimdMode::Avx2, &xs, &mut vector);
        for (i, (&s, &v)) in scalar.iter().zip(&vector).enumerate() {
            assert_eq!(
                s, v,
                "{}: x={} scalar {s} vs avx2 {v}",
                cfg.describe(),
                xs[i]
            );
        }
    }
}

#[test]
fn gate_admission_implies_verifier_proof_over_config_grid() {
    // The constants in `simd_gate` were chosen inside the provable
    // region with margin; this sweep pins that containment. Every
    // gate-admitted point of the grid must discharge all SIMD
    // obligations (the reverse is allowed: the verifier proves more
    // than the gate admits).
    let mut admitted = 0u32;
    for out in 1u32..=16 {
        for l in (out + 3)..=26 {
            for m in 2..=(l + 1).min(26) {
                for nr in 1u32..=4 {
                    for sub in [Subtractor::Twos, Subtractor::Ones] {
                        let cfg = TanhConfig {
                            in_int: 1,
                            in_frac: 1,
                            out_frac: out,
                            lut_bits: l,
                            mult_bits: m,
                            lut_group: 1,
                            shuffle: false,
                            nr_stages: nr,
                            subtractor: sub,
                        };
                        if !simd_gate(&cfg) {
                            continue;
                        }
                        admitted += 1;
                        let rep = verify_params(
                            &DatapathParams::from_config(&cfg),
                            false,
                        );
                        assert!(
                            rep.simd_provable,
                            "gate admits unprovable {}: {:?}",
                            cfg.describe(),
                            rep.failed()
                        );
                        assert!(
                            rep.proven(),
                            "admitted config unproven {}: {:?}",
                            cfg.describe(),
                            rep.failed()
                        );
                    }
                }
            }
        }
    }
    // The grid must actually exercise the admitted region.
    assert!(admitted > 10_000, "grid too sparse: {admitted} admitted");
}

#[test]
fn verifier_is_strictly_stronger_than_the_gate() {
    // A config the gate rejects (one's-complement, margin 2 instead of
    // the gate's 3) that the verifier can still prove — documents that
    // the shipped constants are conservative, i.e. gate ⊂ provable.
    let cfg = TanhConfig {
        out_frac: 15,
        lut_bits: 17, // margin 2
        mult_bits: 16,
        subtractor: Subtractor::Ones,
        ..TanhConfig::s3_12()
    };
    assert!(!simd_gate(&cfg));
    let rep = verify_params(&DatapathParams::from_config(&cfg), false);
    assert!(rep.simd_provable, "{:?}", rep.failed());
    assert!(rep.proven(), "{:?}", rep.failed());
}

// ---------------------------------------------------------------------
// Mutation tests: each proof obligation must be able to FAIL, and on
// the mutation that models exactly its failure mode.
// ---------------------------------------------------------------------

fn failed_names(p: &DatapathParams) -> Vec<&'static str> {
    verify_params(p, true).failed().iter().map(|o| o.name).collect()
}

#[test]
fn mutation_oversized_lut_overflows_chain() {
    let mut p = DatapathParams::from_config(&TanhConfig::s3_12());
    p.cfg.lut_bits = 40; // chain product ~2^81
    let fails = failed_names(&p);
    assert!(fails.contains(&"chain_fits_i64"), "{fails:?}");
}

#[test]
fn mutation_truncated_multiplier_breaks_simd_exactness() {
    // Model a 16-bit vector multiply: the 18-bit chain factors no
    // longer fit, so the gate (which still admits s3_12) is unsound
    // for this hardware — the simd_gate_sound obligation must trip.
    let mut p = DatapathParams::from_config(&TanhConfig::s3_12());
    p.mul_keep_bits = 16;
    let rep = verify_params(&p, true);
    assert!(rep.simd_admitted && !rep.simd_provable);
    let fails: Vec<_> = rep.failed().iter().map(|o| o.name).collect();
    assert!(fails.contains(&"simd_chain_mul_exact"), "{fails:?}");
    assert!(fails.contains(&"simd_gate_sound"), "{fails:?}");
}

#[test]
fn mutation_float_divider_forced_down_avx2_is_rejected() {
    let mut p =
        DatapathParams::from_config(&TanhConfig::s3_12().with_nr(0));
    p.force_simd = true;
    let fails = failed_names(&p);
    assert!(fails.contains(&"simd_nr_stages"), "{fails:?}");
    assert!(fails.contains(&"forced_simd_provable"), "{fails:?}");
}

#[test]
fn mutation_ones_complement_margin_one_breaks_logical_shift() {
    // With L = out + 1 the recompose rounding constant 2^(L+M-out)
    // no longer clears the num = -1 corner times xr_hi ~ 2^(M+1): the
    // pre-shift word can go negative, where a logical shift differs
    // from the scalar arithmetic shift.
    let cfg = TanhConfig {
        out_frac: 15,
        lut_bits: 16,
        mult_bits: 16,
        subtractor: Subtractor::Ones,
        ..TanhConfig::s3_12()
    };
    assert!(!simd_gate(&cfg)); // the gate already refuses it...
    let mut p = DatapathParams::from_config(&cfg);
    p.force_simd = true; // ...and forcing it is provably unsafe
    let fails = failed_names(&p);
    assert!(
        fails.contains(&"simd_recompose_shift_nonneg"),
        "{fails:?}"
    );
}

#[test]
fn mutation_broken_seed_diverges() {
    // Seed 1.0*2^M instead of 2.75*2^M: the NR residual at D=1 is
    // |1 - 1 + 2| = 2 >= 1 and the iteration squares it — no proof.
    let mut p = DatapathParams::from_config(&TanhConfig::s3_12());
    p.seed_const = 1i64 << p.cfg.mult_bits;
    let fails = failed_names(&p);
    assert!(fails.contains(&"nr_converges"), "{fails:?}");
}

#[test]
fn mutation_halved_saturation_threshold_uncovers_domain() {
    let mut p = DatapathParams::from_config(&TanhConfig::s3_12());
    p.sat_threshold /= 2;
    let fails = failed_names(&p);
    assert!(fails.contains(&"saturation_covers_domain"), "{fails:?}");
}

#[test]
fn mutation_zero_lut_group_fails_structurally_without_panic() {
    let mut p = DatapathParams::from_config(&TanhConfig::s3_12());
    p.cfg.lut_group = 0;
    let fails = failed_names(&p);
    assert!(fails.contains(&"lut_grouping_valid"), "{fails:?}");
}

// ---------------------------------------------------------------------
// Report plumbing
// ---------------------------------------------------------------------

#[test]
fn report_json_round_trips_with_stable_schema() {
    let rep = verify(&TanhConfig::s3_5());
    let text = json::write(&rep.to_json());
    let parsed = json::parse(&text).unwrap();
    let obj = match parsed {
        json::Json::Obj(m) => m,
        other => panic!("expected object, got {other:?}"),
    };
    for key in [
        "config",
        "proven",
        "simd_admitted",
        "simd_provable",
        "nr_residual",
        "static_max_ulp",
        "obligations",
        "simd_obligations",
        "stages",
    ] {
        assert!(obj.contains_key(key), "missing key {key}");
    }
    match &obj["obligations"] {
        json::Json::Arr(a) => {
            assert!(!a.is_empty());
            for o in a {
                let m = match o {
                    json::Json::Obj(m) => m,
                    other => panic!("obligation not object: {other:?}"),
                };
                assert!(m.contains_key("name"));
                assert!(m.contains_key("proved"));
                assert!(m.contains_key("detail"));
            }
        }
        other => panic!("obligations not array: {other:?}"),
    }
}

#[test]
fn derived_presets_catalog_is_resolvable_and_disjoint() {
    for name in DERIVED_PRESETS {
        assert!(
            !SHIPPED_PRESETS.contains(name),
            "{name} listed in both catalogs"
        );
        named_config(name).unwrap().validate().unwrap();
    }
}
