//! Zero-copy request path e2e: drive keep-alive `/v1/batch` traffic
//! through a real socket and prove, via the `tanhvf_word_arena_*`
//! metric families, that the word buffers are checked out of the
//! per-thread arena and *reused* — allocations happen while the arena
//! warms up and then stop, while checkouts keep counting one per
//! request. Responses stay bit-exact against the golden model the
//! whole time, so the reuse is observably free.

use std::net::TcpStream;
use std::time::Duration;

use tanh_vf::server::http::HttpConn;
use tanh_vf::server::loadgen;
use tanh_vf::server::{named_config, parse_routes, Server, ServerConfig};
use tanh_vf::tanh::golden::tanh_golden_batch;
use tanh_vf::util::json::{self, Json};

/// Pull `name value` out of a Prometheus exposition body. `# HELP` /
/// `# TYPE` lines start with '#', so the prefix match skips them.
fn metric(body: &str, name: &str) -> u64 {
    body.lines()
        .find_map(|l| {
            l.strip_prefix(name).and_then(|rest| rest.strip_prefix(' '))
        })
        .unwrap_or_else(|| panic!("metric {name} missing:\n{body}"))
        .trim()
        .parse::<u64>()
        .unwrap_or_else(|e| panic!("metric {name}: {e}"))
}

// One #[test] on purpose: the arena counters are process-global, and
// parallel test threads in this file would race the deltas. Other
// integration-test files are separate processes, so they can't
// interfere.
#[test]
fn batch_requests_reuse_word_arena() {
    let routes = parse_routes("native:s3_12").unwrap();
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        // Threaded backend: a keep-alive connection owns one handler
        // thread for its whole life, so every request below lands on
        // the same arena slot and the warm-tail assertion can demand
        // *zero* new allocations instead of a pool-sized bound.
        event_loop: false,
        ..Default::default()
    };
    let srv = Server::start(cfg, routes).unwrap();
    let addr = srv.local_addr().to_string();

    let tanh_cfg = named_config("s3_12").unwrap();
    let words: Vec<i64> = (-32i64..32).map(|i| i * 777).collect();
    let want = tanh_golden_batch(&words, &tanh_cfg);
    let mut body = String::from("{\"model\":\"s3_12\",\"words\":");
    json::write_i64_array(&words, &mut body);
    body.push('}');

    let (status, before) = loadgen::http_get(&addr, "/metrics").unwrap();
    assert_eq!(status, 200);
    let checkouts0 = metric(&before, "tanhvf_word_arena_checkouts_total");

    let s = TcpStream::connect(addr.as_str()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut conn = HttpConn::new(s);

    const WARM: usize = 4;
    const TOTAL: usize = 32;
    let mut allocs_warm = 0u64;
    for i in 0..TOTAL {
        conn.write_request("POST", "/v1/batch", body.as_bytes()).unwrap();
        let (status, _, resp) = conn.read_response(1 << 20).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
        let v = json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
        let got: Vec<i64> = v
            .get("words")
            .and_then(Json::as_arr)
            .expect("words array")
            .iter()
            .map(|w| w.as_i64().unwrap())
            .collect();
        assert_eq!(got, want, "request {i} must stay bit-exact");
        if i + 1 == WARM {
            let (_, m) = loadgen::http_get(&addr, "/metrics").unwrap();
            allocs_warm = metric(&m, "tanhvf_word_arena_allocs_total");
        }
    }

    let (_, after) = loadgen::http_get(&addr, "/metrics").unwrap();
    let checkouts = metric(&after, "tanhvf_word_arena_checkouts_total");
    let allocs = metric(&after, "tanhvf_word_arena_allocs_total");
    let bytes = metric(&after, "tanhvf_word_arena_bytes");

    // One checkout per batch request, nothing else runs in-process.
    assert_eq!(
        checkouts - checkouts0,
        TOTAL as u64,
        "one arena checkout per request"
    );
    // Growth is front-loaded: whatever the first few requests cost,
    // the warm tail (requests WARM..TOTAL) must not allocate at all.
    assert!(allocs >= 1, "first request must grow the fresh slot");
    assert_eq!(
        allocs, allocs_warm,
        "warm tail allocated: {} -> {} over {} reuse requests",
        allocs_warm,
        allocs,
        TOTAL - WARM
    );
    // The acceptance shape: allocations per request tend to zero.
    assert!(
        allocs < TOTAL as u64,
        "allocs {allocs} must stay below {TOTAL} requests"
    );
    assert!(bytes > 0, "retained capacity must be accounted");
    drop(srv);
}
