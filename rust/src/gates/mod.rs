//! Standard-cell library model (the paper's synthesis substrate).
//!
//! The paper synthesizes Verilog RTL against SVT and LVT flavours of a
//! 40nm-class library and reports area / leakage / fmax / logic levels
//! (Tables III & IV). We have no commercial library or synthesis tool,
//! so this module models the quantities a synthesizer derives from one:
//!
//! * per-gate (NAND2-equivalent) delay, area, leakage for each threshold
//!   flavour — LVT switches faster but leaks ~30x more;
//! * register (DFF) cost and clk->q + setup overhead;
//! * a *mapping depth factor*: with timing pressure, technology mapping
//!   onto rich cells (AOI/OAI/compound) shortens the critical path — the
//!   reason the paper's LVT runs report fewer logic levels than SVT for
//!   the same RTL;
//! * a *sizing speedup*: tight stage budgets make the synthesizer upsize
//!   drive strengths, trading area/leakage for per-level delay.
//!
//! Calibration (documented in DESIGN.md §6): constants are chosen so the
//! 16-bit 1-stage SVT point lands near Table III's order of magnitude
//! (135 levels / 188 MHz / ~3.7 kµm²); every other row must then follow
//! from structure, not further tuning.

/// Threshold-voltage flavour of the library.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CellClass {
    /// Standard-Vt: slow, very low leakage.
    Svt,
    /// Low-Vt: ~30% faster gates, ~30x leakage.
    Lvt,
}

impl CellClass {
    pub fn name(&self) -> &'static str {
        match self {
            CellClass::Svt => "SVT",
            CellClass::Lvt => "LVT",
        }
    }
}

/// A characterized standard-cell library.
#[derive(Clone, Debug)]
pub struct CellLibrary {
    pub class: CellClass,
    /// Average NAND2-equivalent gate delay at nominal sizing (ps/level).
    pub gate_delay_ps: f64,
    /// NAND2-equivalent gate area (µm²).
    pub gate_area_um2: f64,
    /// NAND2-equivalent gate leakage (nW).
    pub gate_leak_nw: f64,
    /// Flop clk->q + setup overhead per stage (ps).
    pub reg_overhead_ps: f64,
    /// DFF area (µm² per bit).
    pub reg_area_um2: f64,
    /// DFF leakage (nW per bit).
    pub reg_leak_nw: f64,
    /// Technology-mapping depth reduction available to this flavour
    /// (multiplies structural levels; < 1 means richer mapping).
    pub mapping_depth_factor: f64,
}

impl CellLibrary {
    /// 40nm-class SVT calibration point.
    pub fn svt() -> Self {
        CellLibrary {
            class: CellClass::Svt,
            gate_delay_ps: 38.0,
            gate_area_um2: 0.40,
            gate_leak_nw: 0.45,
            reg_overhead_ps: 210.0,
            reg_area_um2: 1.8,
            reg_leak_nw: 1.6,
            mapping_depth_factor: 1.0,
        }
    }

    /// 40nm-class LVT calibration point.
    pub fn lvt() -> Self {
        CellLibrary {
            class: CellClass::Lvt,
            gate_delay_ps: 26.5,
            gate_area_um2: 0.40,
            gate_leak_nw: 13.5,
            reg_overhead_ps: 150.0,
            reg_area_um2: 1.8,
            reg_leak_nw: 40.0,
            mapping_depth_factor: 0.82,
        }
    }

    pub fn by_class(class: CellClass) -> Self {
        match class {
            CellClass::Svt => Self::svt(),
            CellClass::Lvt => Self::lvt(),
        }
    }

    /// Drive-sizing speedup under timing pressure: when the stage budget
    /// is short (few levels per stage), synthesis upsizes the path. The
    /// factor multiplies per-level delay; the companion
    /// [`CellLibrary::sizing_area_factor`] charges for it.
    pub fn sizing_speedup(&self, levels_per_stage: f64) -> f64 {
        // Nominal above ~100 levels; up to ~20% faster below ~20 levels.
        let x = (levels_per_stage / 100.0).clamp(0.15, 1.0);
        0.80 + 0.20 * x
    }

    /// Area/leakage multiplier paid for the sizing speedup.
    pub fn sizing_area_factor(&self, levels_per_stage: f64) -> f64 {
        let speed = self.sizing_speedup(levels_per_stage);
        // Only the critical cone is upsized while the relaxed cloud is
        // simultaneously downsized, so net area grows sub-linearly with
        // the drive speedup.
        speed.powf(-0.75)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lvt_faster_and_leakier() {
        let svt = CellLibrary::svt();
        let lvt = CellLibrary::lvt();
        assert!(lvt.gate_delay_ps < svt.gate_delay_ps);
        assert!(lvt.gate_leak_nw > 20.0 * svt.gate_leak_nw);
        assert!(lvt.mapping_depth_factor < 1.0);
    }

    #[test]
    fn sizing_monotone() {
        let lib = CellLibrary::svt();
        assert!(lib.sizing_speedup(10.0) < lib.sizing_speedup(150.0));
        assert!(lib.sizing_area_factor(10.0) > lib.sizing_area_factor(150.0));
        // Bounded effects.
        assert!(lib.sizing_speedup(1.0) >= 0.80);
        assert!((lib.sizing_speedup(200.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn calibration_point_svt_period() {
        // 135 levels * 38 ps + 210 ps ~ 5.3 ns -> ~188 MHz (Table III r1).
        let lib = CellLibrary::svt();
        let period = 135.0 * lib.gate_delay_ps + lib.reg_overhead_ps;
        let fmax_mhz = 1e6 / period;
        assert!((fmax_mhz - 188.0).abs() < 15.0, "fmax {fmax_mhz}");
    }
}
