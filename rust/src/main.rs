//! tanh-vf CLI: the leader entry point.
//!
//! Subcommands regenerate every table/figure of the paper, generate
//! Verilog, explore the scalability space, and run the serving demo.

use std::time::Duration;

use tanh_vf::analysis::{exhaustive_error, TanhImpl};
use tanh_vf::baselines;
use tanh_vf::cli::{usage, Args};
use tanh_vf::coordinator::{native_factory, pjrt_factory, Config, Coordinator};
use tanh_vf::gates::CellClass;
use tanh_vf::synth::ppa::{ppa_for, table_rows};
use tanh_vf::tanh::lut::table1_rows;
use tanh_vf::tanh::published::{published_max_error, PublishedConfig};
use tanh_vf::tanh::{Subtractor, TanhConfig, TanhUnit};
use tanh_vf::util::rng::Rng;
use tanh_vf::util::table::{sci, Table};

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("eval", "evaluate tanh on a value: --x 1.25 [--bits 8|16]"),
    ("table1", "multi-bit velocity-factor LUT contents (paper Table I)"),
    ("table2", "error analysis: NR stages x subtractor (paper Table II)"),
    ("table3", "PPA sweep, 16-bit unit (paper Table III)"),
    ("table4", "PPA sweep, 8-bit unit (paper Table IV)"),
    ("fig1", "tanh + PWL series (paper fig. 1): --segments 8 --points 33"),
    ("baselines", "accuracy/cost comparison vs published baselines (§II/§V)"),
    ("codegen", "emit Verilog + testbench: --stages 2 --bits 16 --out DIR"),
    ("sweep", "scalability sweep over precision (the paper's key claim)"),
    ("serve", "serving demo: --backend native|pjrt --requests 1000"),
    (
        "serve-http",
        "HTTP activation service: --addr 127.0.0.1:8787 \
         --routes native:s3_12,native:s3_5 [--workers 8] [--max-conns 64] \
         [--event-loop reactor|threaded] [--duration-secs 0]",
    ),
    (
        "serve-cluster",
        "cluster front: serve-http plus consistent-hash routing. \
         Membership: --peers host:port,... (static bootstrap) and/or \
         --join host:port,... (gossip seeds; neither = seed node). \
         [--advertise host:port] [--replicas 1] [--pool-idle 4] \
         [--virtual-nodes 64] [--probe-interval-ms 500] \
         [--failure-threshold 3] [--recovery-threshold 2] \
         [--load-adaptive on|off] (p2c reads + hot-route autoscaling)",
    ),
    (
        "loadgen",
        "closed-loop load generator: --addrs host:port,... \
         [--connections 4] [--requests 100] [--words 64] \
         [--models s3_12,s3_5] [--word-range 128] [--seed 42] \
         [--zipf 0] (Zipf exponent for skewed model popularity; first \
         model hottest; 0 = uniform cycling) \
         [--trace-sample 0] (sample every Nth request's trace; the \
         report includes the slowest sampled span tree)",
    ),
    (
        "verify-datapath",
        "static datapath verifier: prove overflow-freedom, SIMD-gate \
         soundness, saturation coverage and an error bound. \
         [--bits 8|16 | --config s3_12 | --all-presets] [--json] \
         [--stages] [--no-empirical]",
    ),
    ("info", "artifact manifest summary"),
];

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_default();
    let result = match sub.as_str() {
        "eval" => cmd_eval(&args),
        "table1" => cmd_table1(),
        "table2" => cmd_table2(),
        "table3" => cmd_ppa(TanhConfig::s3_12(), "Table III (s3.12 -> s.15)"),
        "table4" => cmd_ppa(TanhConfig::s3_5(), "Table IV (s3.5 -> s.7)"),
        "fig1" => cmd_fig1(&args),
        "baselines" => cmd_baselines(),
        "codegen" => cmd_codegen(&args),
        "sweep" => cmd_sweep(),
        "serve" => cmd_serve(&args),
        "serve-http" => cmd_serve_http(&args),
        "serve-cluster" => cmd_serve_cluster(&args),
        "loadgen" => cmd_loadgen(&args),
        "verify-datapath" => cmd_verify_datapath(&args),
        "info" => cmd_info(),
        _ => {
            println!("{}", usage("tanh-vf", SUBCOMMANDS));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

type R = Result<(), Box<dyn std::error::Error>>;

/// Invalid-flag error that also reprints the usage block, so a typo'd
/// `--backend`/`--routes` fails loudly with the valid choices in view.
fn usage_err(msg: impl std::fmt::Display) -> Box<dyn std::error::Error> {
    format!("{msg}\n\n{}", usage("tanh-vf", SUBCOMMANDS)).into()
}

fn cfg_for_bits(args: &Args) -> Result<TanhConfig, Box<dyn std::error::Error>> {
    Ok(match args.u64_or("bits", 16)? {
        8 => TanhConfig::s3_5(),
        16 => TanhConfig::s3_12(),
        other => return Err(format!("--bits {other}: use 8 or 16").into()),
    })
}

fn cmd_eval(args: &Args) -> R {
    let cfg = cfg_for_bits(args)?;
    let x = args.f64_or("x", 1.0)?;
    let unit = TanhUnit::new(cfg)?;
    let y = unit.eval_f64(x);
    println!("config : {}", cfg.describe());
    println!(
        "tanh({x}) = {y:.8}  (true {:.8}, err {:.3e})",
        x.tanh(),
        (y - x.tanh()).abs()
    );
    Ok(())
}

fn cmd_table1() -> R {
    println!("Table I — multi-bit lookup for velocity factors (2-bit groups, s3.12)\n");
    let mut t = Table::new(&["entry", "word (u0.18)", "value"]);
    for (name, word, value) in table1_rows(&TanhConfig::s3_12()) {
        t.row(&[name, format!("{word}"), format!("{value:.9}")]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_table2() -> R {
    println!("Table II — error analysis for arithmetic approximations");
    println!("(s3.12 input, s.15 output; exhaustive over 2^16 words)\n");
    let mut t = Table::new(&[
        "NR stages", "Subtractor", "Max Error", "(lsb)", "Paper",
    ]);
    let rows: &[(u32, Subtractor, &str)] = &[
        (0, Subtractor::Twos, "4.44e-5 (fp divider ref)"),
        (2, Subtractor::Ones, "2.77e-4"),
        (2, Subtractor::Twos, "2.56e-4"),
        (3, Subtractor::Ones, "4.32e-5"),
        (3, Subtractor::Twos, "4.44e-5"),
    ];
    for &(nr, sub, paper) in rows {
        let cfg = TanhConfig::s3_12().with_nr(nr).with_subtractor(sub);
        let unit = TanhUnit::new(cfg)?;
        let stats = exhaustive_error(&unit);
        t.row(&[
            if nr == 0 { "0 (fp ref)".into() } else { format!("{nr}") },
            sub.name().to_string(),
            sci(stats.max_abs),
            format!("{:.2}", stats.max_lsb(cfg.out_format())),
            paper.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_ppa(cfg: TanhConfig, title: &str) -> R {
    println!("{title} — modelled synthesis (see DESIGN.md §6 for the calibration stance)\n");
    let mut t = Table::new(&[
        "Cells", "Latency (clk)", "Area (um2)", "Leakage (uW)",
        "Fmax (MHz)", "Logic Levels",
    ]);
    for r in table_rows(&cfg) {
        t.row(&r.row());
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_fig1(args: &Args) -> R {
    let segments = args.usize_or("segments", 8)?;
    let points = args.usize_or("points", 33)?;
    println!("fig. 1 — tanh and its piecewise-linear approximation ({segments} segments)\n");
    let mut t = Table::new(&["x", "tanh(x)", "PWL(x)", "err"]);
    for (x, tanh, pwl) in baselines::pwl::fig1_series(segments, points) {
        t.row(&[
            format!("{x:+.3}"),
            format!("{tanh:+.5}"),
            format!("{pwl:+.5}"),
            format!("{:.4}", (tanh - pwl).abs()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_baselines() -> R {
    println!("Baseline comparison (16-bit operating point, exhaustive error)\n");
    let mut t = Table::new(&[
        "Implementation", "Max Error", "LUT bits", "Multipliers", "Adders",
    ]);
    let unit = TanhUnit::new(TanhConfig::s3_12())?;
    let mut impls: Vec<Box<dyn TanhImpl>> = baselines::suite16();
    impls.insert(0, Box::new(unit));
    for imp in &impls {
        let e = exhaustive_error(imp.as_ref());
        let c = imp.cost();
        t.row(&[
            imp.name(),
            sci(e.max_abs),
            format!("{}", c.lut_bits),
            format!("{}", c.multipliers),
            format!("{}", c.adders),
        ]);
    }
    println!("{}", t.render());
    let pc = PublishedConfig::default();
    println!(
        "published method (fig. 3, eq. 3 tail, {} registers): max error {}",
        pc.register_count(),
        sci(published_max_error(&pc))
    );
    Ok(())
}

fn cmd_codegen(args: &Args) -> R {
    let cfg = cfg_for_bits(args)?;
    let stages = args.u64_or("stages", 2)? as u32;
    let out = args.str_or("out", "target/verilog").to_string();
    let gen = tanh_vf::verilog::generate(&cfg, stages, 256);
    std::fs::create_dir_all(&out)?;
    let vpath = format!("{out}/{}.v", gen.module_name);
    let tpath = format!("{out}/{}_tb.v", gen.module_name);
    std::fs::write(&vpath, &gen.module)?;
    std::fs::write(&tpath, &gen.testbench)?;
    println!("wrote {vpath}\nwrote {tpath}");
    let r = ppa_for(&cfg, CellClass::Svt, stages);
    println!(
        "modelled PPA (SVT): {:.0} um2, {:.2} uW leakage, {:.0} MHz, {} levels",
        r.area_um2, r.leakage_uw, r.fmax_mhz, r.logic_levels
    );
    Ok(())
}

fn cmd_sweep() -> R {
    println!("Scalability sweep — one datapath generator, any precision\n");
    let mut t = Table::new(&[
        "Config", "Max Error", "(lsb)", "Area um2 (SVT,2st)", "Fmax MHz",
    ]);
    let points = [
        TanhConfig {
            in_int: 2, in_frac: 5, out_frac: 7, lut_bits: 10, mult_bits: 9,
            lut_group: 3, shuffle: true, nr_stages: 3,
            subtractor: Subtractor::Twos,
        },
        TanhConfig::s3_5(),
        TanhConfig {
            in_int: 3, in_frac: 9, out_frac: 11, lut_bits: 14, mult_bits: 12,
            lut_group: 4, shuffle: true, nr_stages: 3,
            subtractor: Subtractor::Twos,
        },
        TanhConfig::s3_12(),
        TanhConfig {
            in_int: 4, in_frac: 13, out_frac: 17, lut_bits: 20, mult_bits: 18,
            lut_group: 4, shuffle: true, nr_stages: 3,
            subtractor: Subtractor::Twos,
        },
    ];
    for cfg in points {
        let unit = TanhUnit::new(cfg)?;
        let e = exhaustive_error(&unit);
        let r = ppa_for(&cfg, CellClass::Svt, 2);
        t.row(&[
            cfg.describe(),
            sci(e.max_abs),
            format!("{:.2}", e.max_lsb(cfg.out_format())),
            format!("{:.0}", r.area_um2),
            format!("{:.0}", r.fmax_mhz),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_serve(args: &Args) -> R {
    let backend = args.str_or("backend", "native").to_string();
    let n = args.usize_or("requests", 1000)?;
    // Same validation as `serve-http --routes` (server::parse_routes).
    tanh_vf::server::validate_backend(&backend)
        .map_err(|e| usage_err(format!("--backend {backend}: {e}")))?;
    let factory = match backend.as_str() {
        "native" => native_factory(TanhConfig::s3_12(), true),
        _ => pjrt_factory(
            tanh_vf::runtime::artifacts_dir(),
            "tanh_s3_12".to_string(),
        ),
    };
    let c = Coordinator::start(
        Config {
            batch_capacity: 1024,
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_limit: 8192,
        },
        factory,
    );
    let mut rng = Rng::new(1);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n)
        .map(|_| {
            let len = 1 + rng.below(256) as usize;
            let words: Vec<i32> = (0..len)
                .map(|_| rng.range_i64(-32768, 32768) as i32)
                .collect();
            c.submit(words)
        })
        .collect();
    let mut words_total = 0usize;
    for h in handles {
        let out = h.recv().ok_or("dropped")?.map_err(|e| e.to_string())?;
        words_total += out.len();
    }
    let dt = t0.elapsed();
    let s = c.snapshot();
    println!("backend={backend} requests={n} words={words_total}");
    println!(
        "wall={:?}  throughput={:.0} req/s  ({:.2e} words/s)",
        dt,
        n as f64 / dt.as_secs_f64(),
        words_total as f64 / dt.as_secs_f64()
    );
    println!(
        "batches={} mean_fill={:.2} p50={}us p99={}us max={}us",
        s.batches, s.mean_batch_fill, s.p50_latency_us, s.p99_latency_us,
        s.max_latency_us
    );
    Ok(())
}

/// Flags shared by `serve-http` and `serve-cluster`: server config,
/// parsed route table, and the run duration.
fn http_server_setup(
    args: &Args,
) -> Result<
    (tanh_vf::server::ServerConfig, Vec<tanh_vf::coordinator::router::Route>, u64),
    Box<dyn std::error::Error>,
> {
    let addr = args.str_or("addr", "127.0.0.1:8787").to_string();
    let routes_spec =
        args.str_or("routes", "native:s3_12,native:s3_5").to_string();
    let workers = args.usize_or("workers", 8)?;
    // With the reactor backend the connection capacity is no longer tied
    // to the worker count, so --max-conns stands on its own.
    let max_conns = args.usize_or("max-conns", 64)?;
    let duration_secs = args.u64_or("duration-secs", 0)?;
    let default_cfg = tanh_vf::server::ServerConfig::default();
    let event_loop = match args.str_or("event-loop", "") {
        "" => default_cfg.event_loop,
        "reactor" => true,
        "threaded" => false,
        other => {
            return Err(usage_err(format!(
                "--event-loop {other}: use reactor or threaded"
            )))
        }
    };
    // The reactor needs epoll/poll fds; off unix the server falls back
    // to the threaded backend, so report what actually runs.
    let event_loop = event_loop && cfg!(unix);
    let routes = tanh_vf::server::parse_routes(&routes_spec)
        .map_err(|e| usage_err(format!("--routes {routes_spec}: {e}")))?;
    Ok((
        tanh_vf::server::ServerConfig {
            addr,
            workers,
            max_connections: max_conns,
            event_loop,
            ..default_cfg
        },
        routes,
        duration_secs,
    ))
}

/// Banner + serve loop shared by both HTTP subcommands.
fn run_http_server(
    mut srv: tanh_vf::server::Server,
    event_loop: bool,
    duration_secs: u64,
) -> R {
    println!(
        "tanh-vf http listening on http://{} ({} backend)",
        srv.local_addr(),
        if event_loop { "reactor" } else { "threaded" }
    );
    println!("endpoints: /health /v1/models /v1/eval /v1/batch /metrics");
    if let Some(cl) = srv.cluster() {
        println!(
            "cluster: self={} nodes={} virtual-nodes={} replicas={} \
             pool-idle={}",
            cl.self_name(),
            cl.ring().nodes().len(),
            cl.config().virtual_nodes,
            cl.config().replicas,
            cl.pool.idle_per_peer()
        );
        for seed in &cl.config().join {
            println!("join seed: {seed}");
        }
        for peer in cl.peer_health().keys() {
            println!("peer: {peer}");
        }
        for (name, _) in srv.snapshots() {
            let owner = cl.owner_name(&name).unwrap_or_else(|| "none".into());
            println!("route: {name} (owner {owner})");
        }
    } else {
        for (name, _) in srv.snapshots() {
            println!("route: {name}");
        }
    }
    if duration_secs == 0 {
        // Serve until killed.
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration_secs));
    srv.shutdown();
    println!("\n--- final metrics ---\n{}", srv.metrics_text());
    Ok(())
}

fn cmd_serve_http(args: &Args) -> R {
    let (cfg, routes, duration_secs) = http_server_setup(args)?;
    let event_loop = cfg.event_loop;
    let srv = tanh_vf::server::Server::start(cfg, routes)?;
    run_http_server(srv, event_loop, duration_secs)
}

/// Split a comma-separated list flag (addresses, model names, …).
fn csv_list(args: &Args, key: &str, default: &str) -> Vec<String> {
    args.str_or(key, default)
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

fn cmd_serve_cluster(args: &Args) -> R {
    let (cfg, routes, duration_secs) = http_server_setup(args)?;
    // Membership sources: --peers are static bootstrap members (part
    // of the ring immediately), --join are gossip seeds (ring members
    // only once they answer). Neither given = a seed node that waits
    // for others to join it.
    let peers = csv_list(args, "peers", "");
    let join = csv_list(args, "join", "");
    if peers.is_empty() && join.is_empty() {
        println!(
            "no --peers/--join: starting as a gossip seed node \
             (others join via --join {})",
            args.str_or("advertise", &cfg.addr)
        );
    }
    // The identity this node hashes itself under; must match what the
    // other fronts know it by (their --peers entries, or what gossip
    // spreads). Defaults to the bind address.
    let advertise = args.str_or("advertise", &cfg.addr).to_string();
    let ccfg = tanh_vf::server::cluster::ClusterConfig {
        advertise,
        peers,
        join,
        replicas: args.usize_or("replicas", 1)?,
        pool_idle_per_peer: args.usize_or("pool-idle", 4)?,
        virtual_nodes: args.usize_or("virtual-nodes", 64)?,
        probe_interval: Duration::from_millis(
            args.u64_or("probe-interval-ms", 500)?,
        ),
        failure_threshold: args.u64_or("failure-threshold", 3)? as u32,
        recovery_threshold: args.u64_or("recovery-threshold", 2)? as u32,
        load_adaptive: match args.str_or("load-adaptive", "on") {
            "on" => true,
            "off" => false,
            v => {
                return Err(usage_err(format!(
                    "--load-adaptive: expected on|off, got {v}"
                )))
            }
        },
        ..Default::default()
    };
    let event_loop = cfg.event_loop;
    let srv = tanh_vf::server::Server::start_cluster(cfg, routes, ccfg)?;
    run_http_server(srv, event_loop, duration_secs)
}

/// Drive one front (or a whole cluster of fronts) with the closed-loop
/// generator and print both the human line and the JSON report.
fn cmd_loadgen(args: &Args) -> R {
    let addrs = csv_list(args, "addrs", "");
    if addrs.is_empty() {
        return Err(usage_err("--addrs: need at least one host:port"));
    }
    let models = csv_list(args, "models", "s3_12,s3_5");
    let cfg = tanh_vf::server::loadgen::LoadgenConfig {
        addrs,
        connections: args.usize_or("connections", 4)?,
        requests_per_connection: args.usize_or("requests", 100)?,
        words_per_request: args.usize_or("words", 64)?,
        models,
        word_range: args.i64_or("word-range", 128)?,
        seed: args.u64_or("seed", 42)?,
        trace_sample: args.usize_or("trace-sample", 0)?,
        zipf_s: args.f64_or("zipf", 0.0)?,
    };
    let report = tanh_vf::server::loadgen::run(&cfg)?;
    println!("{}", report.render());
    println!("{}", tanh_vf::util::json::write(&report.to_json()));
    Ok(())
}

/// Static datapath verification (`verify-datapath`): run the abstract
/// interpreter over the selected configs, cross-check the static error
/// bound against the exhaustive empirical sweep where the input domain
/// is small enough, and fail loudly on any UNPROVEN obligation.
fn cmd_verify_datapath(args: &Args) -> R {
    use tanh_vf::analysis::verify::{all_preset_names, verify};
    use tanh_vf::server::named_config;
    use tanh_vf::util::json::{write as json_write, Json};

    let as_json = args.bool("json");
    let show_stages = args.bool("stages");
    let skip_empirical = args.bool("no-empirical");

    let names: Vec<String> = if args.bool("all-presets") {
        all_preset_names().iter().map(|s| s.to_string()).collect()
    } else if let Some(name) = args.str_opt("config") {
        vec![name.to_string()]
    } else if args.str_opt("bits").is_some() {
        let cfg = cfg_for_bits(args)?;
        vec![if cfg == TanhConfig::s3_5() { "s3_5" } else { "s3_12" }
            .to_string()]
    } else {
        all_preset_names().iter().map(|s| s.to_string()).collect()
    };

    let mut items = Vec::new();
    let mut all_proven = true;
    let mut all_dominated = true;
    for name in &names {
        let cfg = named_config(name).map_err(usage_err)?;
        let rep = verify(&cfg);
        // Exhaustive empirical sweep (2^(mag+1) words) stays cheap up
        // to 16 magnitude bits — every shipped preset qualifies.
        let empirical = if !skip_empirical && cfg.mag_bits() <= 16 {
            let unit = TanhUnit::new(cfg)?;
            let stats = exhaustive_error(&unit);
            Some(stats.max_lsb(cfg.out_format()))
        } else {
            None
        };
        let dominated = match (rep.static_max_ulp, empirical) {
            (Some(s), Some(e)) => Some(s >= e),
            _ => None,
        };
        all_proven &= rep.proven();
        all_dominated &= dominated != Some(false);
        items.push((name.clone(), cfg, rep, empirical, dominated));
    }

    if as_json {
        let configs = items
            .iter()
            .map(|(name, _, rep, empirical, dominated)| {
                let mut j = rep.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("name".into(), Json::Str(name.clone()));
                    m.insert(
                        "empirical_max_ulp".into(),
                        empirical.map(Json::Num).unwrap_or(Json::Null),
                    );
                    m.insert(
                        "bound_dominates".into(),
                        dominated.map(Json::Bool).unwrap_or(Json::Null),
                    );
                }
                j
            })
            .collect();
        let mut top = std::collections::BTreeMap::new();
        top.insert(
            "schema".into(),
            Json::Str("tanhvf-verify-v1".into()),
        );
        top.insert("configs".into(), Json::Arr(configs));
        top.insert("all_proven".into(), Json::Bool(all_proven));
        top.insert(
            "all_bounds_dominate".into(),
            Json::Bool(all_dominated),
        );
        println!("{}", json_write(&Json::Obj(top)));
    } else {
        let mut t = Table::new(&[
            "config", "proven", "simd", "nr residual", "static (lsb)",
            "empirical", "dominates",
        ]);
        for (name, _, rep, empirical, dominated) in &items {
            t.row(&[
                format!("{name} [{}]", rep.config.describe()),
                if rep.proven() { "PROVEN".into() } else { "UNPROVEN".into() },
                match (rep.simd_admitted, rep.simd_provable) {
                    (true, true) => "admitted+proved".into(),
                    (true, false) => "ADMITTED UNPROVED".into(),
                    (false, true) => "provable (gated off)".into(),
                    (false, false) => "scalar only".into(),
                },
                rep.nr_residual
                    .map(|e| format!("{e:.2e}"))
                    .unwrap_or_else(|| "-".into()),
                rep.static_max_ulp
                    .map(|u| format!("{u:.3}"))
                    .unwrap_or_else(|| "-".into()),
                empirical
                    .map(|u| format!("{u:.3}"))
                    .unwrap_or_else(|| "-".into()),
                match dominated {
                    Some(true) => "yes".into(),
                    Some(false) => "NO".into(),
                    None => "-".into(),
                },
            ]);
        }
        println!("Static datapath verification\n");
        println!("{}", t.render());
        for (name, _, rep, _, _) in &items {
            for o in rep.failed() {
                println!("UNPROVEN {name}: {} — {}", o.name, o.detail);
            }
            if show_stages {
                println!("\n{name} stage intervals:");
                let mut st = Table::new(&["stage", "lo", "hi", "low zeros"]);
                for s in &rep.stages {
                    st.row(&[
                        s.stage.clone(),
                        format!("{}", s.lo),
                        format!("{}", s.hi),
                        format!("{}", s.low_zeros),
                    ]);
                }
                println!("{}", st.render());
            }
        }
    }

    if !all_proven {
        return Err("verification FAILED: unproven obligations".into());
    }
    if !all_dominated {
        return Err(
            "verification FAILED: static bound below empirical max error"
                .into(),
        );
    }
    Ok(())
}

fn cmd_info() -> R {
    let dir = tanh_vf::runtime::artifacts_dir();
    let man = tanh_vf::runtime::Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    for (name, e) in &man.entries {
        let ins: Vec<String> = e
            .inputs
            .iter()
            .map(|s| format!("{}{:?}", s.name, s.shape))
            .collect();
        println!("  {name}: {} <- {}", e.file, ins.join(", "));
    }
    Ok(())
}
