//! Fixed-point neural-accelerator simulator.
//!
//! The paper's motivation (§I): the tanh unit sits next to the MAC array
//! in DNN/RNN accelerators, and the *accuracy of the activation function
//! impacts the accuracy of the network*. This module provides the
//! substrate to measure that end to end:
//!
//! * [`MacArray`]   — integer multiply-accumulate with configurable
//!   weight/activation precision (the accelerator datapath).
//! * [`DenseNet`]   — quantized-inference MLP whose activations route
//!   through any [`crate::analysis::TanhImpl`].
//! * [`LstmCellFx`] — fixed-point LSTM step (tanh + sigmoid via the
//!   same unit, 1-bit pre-shift).
//! * [`trainer`]    — a small float MLP trainer (SGD + backprop) so
//!   accuracy experiments run on an actually-trained network, not random
//!   weights.

pub mod trainer;

use crate::analysis::TanhImpl;
use crate::fixed::{QFormat, Round};

/// Integer MAC array: y = W·x + b with product accumulation in i64.
///
/// Weights are quantized to `w_fmt`, activations arrive as `a_fmt`
/// words; the accumulator carries `w_frac + a_frac` fractional bits and
/// is rescaled to `a_fmt` on the way out (the accelerator's requantize).
pub struct MacArray {
    pub w_fmt: QFormat,
    pub a_fmt: QFormat,
}

impl MacArray {
    pub fn new(w_fmt: QFormat, a_fmt: QFormat) -> Self {
        MacArray { w_fmt, a_fmt }
    }

    /// One output row: dot(w_row, x) + b, requantized to `a_fmt`.
    pub fn mac_row(&self, w_row: &[i64], x: &[i64], b: i64) -> i64 {
        debug_assert_eq!(w_row.len(), x.len());
        let mut acc: i64 = 0;
        for (&w, &a) in w_row.iter().zip(x) {
            acc += w * a;
        }
        // b arrives in a_fmt; align to the accumulator scale.
        acc += b << self.w_fmt.frac_bits;
        // Requantize: round from (w_frac + a_frac) down to a_frac.
        let shift = self.w_fmt.frac_bits;
        let y = (acc + (1i64 << (shift - 1))) >> shift;
        y.clamp(self.a_fmt.min_word(), self.a_fmt.max_word())
    }

    /// Full layer: `w` is row-major `[out][in]`.
    pub fn matvec(&self, w: &[Vec<i64>], x: &[i64], b: &[i64]) -> Vec<i64> {
        w.iter()
            .zip(b)
            .map(|(row, &bb)| self.mac_row(row, x, bb))
            .collect()
    }
}

/// A quantized dense network with pluggable activation hardware.
pub struct DenseNet<'a> {
    pub mac: MacArray,
    /// Per-layer quantized weights `[out][in]` and biases (a_fmt words).
    pub weights: Vec<Vec<Vec<i64>>>,
    pub biases: Vec<Vec<i64>>,
    /// Activation unit used between layers (not after the last).
    pub act: &'a dyn TanhImpl,
}

impl<'a> DenseNet<'a> {
    /// Quantize a float network for this accelerator.
    pub fn from_float(
        layers: &[(Vec<Vec<f64>>, Vec<f64>)],
        w_fmt: QFormat,
        a_fmt: QFormat,
        act: &'a dyn TanhImpl,
    ) -> Self {
        let weights = layers
            .iter()
            .map(|(w, _)| {
                w.iter()
                    .map(|row| {
                        row.iter()
                            .map(|&v| w_fmt.quantize(v, Round::Nearest))
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let biases = layers
            .iter()
            .map(|(_, b)| {
                b.iter().map(|&v| a_fmt.quantize(v, Round::Nearest)).collect()
            })
            .collect();
        DenseNet { mac: MacArray::new(w_fmt, a_fmt), weights, biases, act }
    }

    /// Forward one input vector (float in, float logits out).
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let a_fmt = self.mac.a_fmt;
        let mut act_words: Vec<i64> = x
            .iter()
            .map(|&v| a_fmt.quantize(v, Round::Nearest))
            .collect();
        let last = self.weights.len() - 1;
        for (li, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let z = self.mac.matvec(w, &act_words, b);
            if li == last {
                return z.iter().map(|&v| a_fmt.dequantize(v)).collect();
            }
            // Activation hardware: a_fmt word in, out_format word out,
            // then realign to a_fmt for the next MAC.
            act_words = z
                .iter()
                .map(|&v| {
                    let t = self.act.eval_word(self.to_act_in(v));
                    self.from_act_out(t)
                })
                .collect();
        }
        unreachable!()
    }

    fn to_act_in(&self, v: i64) -> i64 {
        let a = self.mac.a_fmt;
        let i = self.act.in_format();
        let d = i.frac_bits as i32 - a.frac_bits as i32;
        let w = if d >= 0 { v << d } else { v >> -d };
        w.clamp(i.min_word(), i.max_word())
    }

    fn from_act_out(&self, t: i64) -> i64 {
        let o = self.act.out_format();
        let a = self.mac.a_fmt;
        let d = o.frac_bits as i32 - a.frac_bits as i32;
        if d >= 0 {
            t >> d
        } else {
            t << -d
        }
    }

    /// Classification accuracy over a dataset.
    pub fn accuracy(&self, xs: &[Vec<f64>], labels: &[usize]) -> f64 {
        let mut correct = 0usize;
        for (x, &l) in xs.iter().zip(labels) {
            let logits = self.forward(x);
            let pred = argmax(&logits);
            if pred == l {
                correct += 1;
            }
        }
        correct as f64 / xs.len() as f64
    }
}

pub fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// Fixed-point LSTM cell using the tanh unit for all nonlinearities.
pub struct LstmCellFx<'a> {
    pub mac: MacArray,
    /// `[4H][I]` input kernel, gate order (i, f, g, o).
    pub wx: Vec<Vec<i64>>,
    /// `[4H][H]` recurrent kernel.
    pub wh: Vec<Vec<i64>>,
    pub b: Vec<i64>,
    pub act: &'a dyn TanhImpl,
    pub hidden: usize,
}

impl<'a> LstmCellFx<'a> {
    /// One step. `x`, `h`, `c` are a_fmt word vectors; returns (h', c').
    pub fn step(&self, x: &[i64], h: &[i64], c: &[i64]) -> (Vec<i64>, Vec<i64>) {
        let hid = self.hidden;
        let a_fmt = self.mac.a_fmt;
        let zx = self.mac.matvec(&self.wx, x, &vec![0; 4 * hid]);
        let zh = self.mac.matvec(&self.wh, h, &self.b);
        let z: Vec<i64> = zx.iter().zip(&zh).map(|(a, b)| a + b).collect();

        let sig = |v: i64| -> i64 {
            // sigma(z) = (1 + tanh(z/2)) / 2 : pre-shift 1 bit, post
            // average with 1.0 — all shifts in hardware.
            let t = self.act_eval(v >> 1);
            ((1i64 << a_fmt.frac_bits) + t) >> 1
        };
        let mut h_new = Vec::with_capacity(hid);
        let mut c_new = Vec::with_capacity(hid);
        for j in 0..hid {
            let i_g = sig(z[j]);
            let f_g = sig(z[hid + j]);
            let g_g = self.act_eval(z[2 * hid + j]);
            let o_g = sig(z[3 * hid + j]);
            let f_frac = a_fmt.frac_bits;
            let c1 = (f_g * c[j] + (1 << (f_frac - 1))) >> f_frac;
            let c2 = (i_g * g_g + (1 << (f_frac - 1))) >> f_frac;
            let cj = (c1 + c2).clamp(a_fmt.min_word(), a_fmt.max_word());
            let hj = (o_g * self.act_eval(cj) + (1 << (f_frac - 1))) >> f_frac;
            c_new.push(cj);
            h_new.push(hj.clamp(a_fmt.min_word(), a_fmt.max_word()));
        }
        (h_new, c_new)
    }

    /// Activation through the hardware unit, realigned to a_fmt.
    fn act_eval(&self, v: i64) -> i64 {
        let a = self.mac.a_fmt;
        let i = self.act.in_format();
        let o = self.act.out_format();
        let di = i.frac_bits as i32 - a.frac_bits as i32;
        let w = if di >= 0 { v << di } else { v >> -di };
        let t = self.act.eval_word(w.clamp(i.min_word(), i.max_word()));
        let do_ = o.frac_bits as i32 - a.frac_bits as i32;
        if do_ >= 0 {
            t >> do_
        } else {
            t << -do_
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::{TanhConfig, TanhUnit};

    fn unit() -> TanhUnit {
        TanhUnit::new(TanhConfig::s3_12()).unwrap()
    }

    #[test]
    fn mac_row_basic() {
        let mac = MacArray::new(QFormat::new(1, 8), QFormat::new(3, 12));
        // w = 0.5 (128 at Q1.8), x = 1.0 (4096 at Q3.12), b = 0.25.
        let y = mac.mac_row(&[128], &[4096], 1024);
        // 0.5*1.0 + 0.25 = 0.75 -> 3072.
        assert_eq!(y, 3072);
    }

    #[test]
    fn mac_saturates() {
        let mac = MacArray::new(QFormat::new(1, 8), QFormat::new(3, 12));
        let big = vec![256i64; 64]; // 1.0 each
        let x = vec![32767i64; 64]; // ~8.0 each
        let y = mac.mac_row(&big, &x, 0);
        assert_eq!(y, QFormat::new(3, 12).max_word());
    }

    #[test]
    fn dense_net_matches_float_closely() {
        // A hand-built 2-2-2 float net; quantized inference must track it.
        let u = unit();
        let layers = vec![
            (
                vec![vec![0.5, -0.25], vec![0.75, 0.5]],
                vec![0.1, -0.1],
            ),
            (
                vec![vec![1.0, -0.5], vec![0.25, 0.75]],
                vec![0.0, 0.2],
            ),
        ];
        let net = DenseNet::from_float(
            &layers,
            QFormat::new(1, 10),
            QFormat::new(3, 12),
            &u,
        );
        let x = [0.3, -0.7];
        let got = net.forward(&x);
        // float reference
        let h0 = (0.5f64 * 0.3 - 0.25 * -0.7 + 0.1).tanh();
        let h1 = (0.75f64 * 0.3 + 0.5 * -0.7 - 0.1).tanh();
        let want = [h0 - 0.5 * h1, 0.25 * h0 + 0.75 * h1 + 0.2];
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 2e-3, "{g} vs {w}");
        }
    }

    #[test]
    fn lstm_cell_tracks_float() {
        let u = unit();
        let hid = 4usize;
        let input = 3usize;
        let mut rng = crate::util::rng::Rng::new(99);
        let wfmt = QFormat::new(1, 10);
        let afmt = QFormat::new(3, 12);
        let fw =
            |r: &mut crate::util::rng::Rng| r.normal() * 0.3;
        let wx_f: Vec<Vec<f64>> = (0..4 * hid)
            .map(|_| (0..input).map(|_| fw(&mut rng)).collect())
            .collect();
        let wh_f: Vec<Vec<f64>> = (0..4 * hid)
            .map(|_| (0..hid).map(|_| fw(&mut rng)).collect())
            .collect();
        let b_f: Vec<f64> = (0..4 * hid).map(|_| fw(&mut rng)).collect();

        let q = |m: &Vec<Vec<f64>>| -> Vec<Vec<i64>> {
            m.iter()
                .map(|r| r.iter().map(|&v| wfmt.quantize(v, Round::Nearest)).collect())
                .collect()
        };
        let cell = LstmCellFx {
            mac: MacArray::new(wfmt, afmt),
            wx: q(&wx_f),
            wh: q(&wh_f),
            b: b_f.iter().map(|&v| afmt.quantize(v, Round::Nearest)).collect(),
            act: &u,
            hidden: hid,
        };
        let x_f: Vec<f64> = (0..input).map(|_| rng.normal() * 0.5).collect();
        let h_f = vec![0.0; hid];
        let c_f = vec![0.0; hid];
        let x_w: Vec<i64> =
            x_f.iter().map(|&v| afmt.quantize(v, Round::Nearest)).collect();
        let (h_new, c_new) =
            cell.step(&x_w, &vec![0; hid], &vec![0; hid]);

        // Float reference.
        let sig = |v: f64| 1.0 / (1.0 + (-v).exp());
        for j in 0..hid {
            let zi: f64 = (0..input).map(|k| wx_f[j][k] * x_f[k]).sum::<f64>() + b_f[j];
            let zf: f64 =
                (0..input).map(|k| wx_f[hid + j][k] * x_f[k]).sum::<f64>() + b_f[hid + j];
            let zg: f64 =
                (0..input).map(|k| wx_f[2 * hid + j][k] * x_f[k]).sum::<f64>() + b_f[2 * hid + j];
            let zo: f64 =
                (0..input).map(|k| wx_f[3 * hid + j][k] * x_f[k]).sum::<f64>() + b_f[3 * hid + j];
            let c_ref = sig(zf) * c_f[j] + sig(zi) * zg.tanh();
            let h_ref = sig(zo) * c_ref.tanh();
            let _ = h_f;
            assert!(
                (afmt.dequantize(c_new[j]) - c_ref).abs() < 5e-3,
                "c[{j}]"
            );
            assert!(
                (afmt.dequantize(h_new[j]) - h_ref).abs() < 5e-3,
                "h[{j}]"
            );
        }
    }

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
    }
}
