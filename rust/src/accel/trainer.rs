//! Minimal float MLP trainer (SGD + backprop) and synthetic datasets.
//!
//! Accuracy experiments need a *trained* network: the paper's §I claim
//! ("the accuracy of the activation function impacts the performance
//! ... of the neural networks") only shows up when the weights encode a
//! real decision boundary. No ML framework is available offline, so this
//! is a small, dependency-free trainer for tanh MLP classifiers.

use crate::util::rng::Rng;

/// A float MLP: weights `[layer][out][in]`, biases `[layer][out]`.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub weights: Vec<Vec<Vec<f64>>>,
    pub biases: Vec<Vec<f64>>,
}

impl Mlp {
    /// He/Xavier-ish init for `sizes = [in, h1, ..., out]`.
    pub fn new(sizes: &[usize], rng: &mut Rng) -> Mlp {
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for w in sizes.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let scale = (1.0 / fan_in as f64).sqrt();
            weights.push(
                (0..fan_out)
                    .map(|_| (0..fan_in).map(|_| rng.normal() * scale).collect())
                    .collect(),
            );
            biases.push(vec![0.0; fan_out]);
        }
        Mlp { weights, biases }
    }

    /// Forward pass storing post-activation values per layer.
    fn forward_trace(&self, x: &[f64]) -> Vec<Vec<f64>> {
        let mut acts = vec![x.to_vec()];
        let last = self.weights.len() - 1;
        for (li, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let prev = acts.last().unwrap();
            let mut z: Vec<f64> = w
                .iter()
                .zip(b)
                .map(|(row, &bb)| {
                    row.iter().zip(prev).map(|(a, b)| a * b).sum::<f64>() + bb
                })
                .collect();
            if li != last {
                for v in z.iter_mut() {
                    *v = v.tanh();
                }
            }
            acts.push(z);
        }
        acts
    }

    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        self.forward_trace(x).pop().unwrap()
    }

    /// One SGD step on a single example (cross-entropy over softmax).
    /// Returns the loss.
    pub fn sgd_step(&mut self, x: &[f64], label: usize, lr: f64) -> f64 {
        let acts = self.forward_trace(x);
        let logits = acts.last().unwrap();
        let probs = softmax(logits);
        let loss = -(probs[label].max(1e-12)).ln();

        // dL/dz for the output layer.
        let mut delta: Vec<f64> = probs
            .iter()
            .enumerate()
            .map(|(i, &p)| p - if i == label { 1.0 } else { 0.0 })
            .collect();

        for li in (0..self.weights.len()).rev() {
            let a_prev = &acts[li];
            // Gradients + next delta (before this layer's activation).
            let mut delta_prev = vec![0.0; a_prev.len()];
            for (o, d) in delta.iter().enumerate() {
                for (i, &a) in a_prev.iter().enumerate() {
                    delta_prev[i] += self.weights[li][o][i] * d;
                    self.weights[li][o][i] -= lr * d * a;
                }
                self.biases[li][o] -= lr * d;
            }
            if li > 0 {
                // Backprop through tanh of the previous layer's output.
                for (i, dp) in delta_prev.iter_mut().enumerate() {
                    let a = acts[li][i];
                    *dp *= 1.0 - a * a;
                }
                delta = delta_prev;
            }
        }
        loss
    }

    /// Train for `epochs` passes; returns final train accuracy.
    pub fn train(
        &mut self,
        xs: &[Vec<f64>],
        labels: &[usize],
        epochs: usize,
        lr: f64,
        rng: &mut Rng,
    ) -> f64 {
        let mut order: Vec<usize> = (0..xs.len()).collect();
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                self.sgd_step(&xs[i], labels[i], lr);
            }
        }
        self.accuracy(xs, labels)
    }

    pub fn accuracy(&self, xs: &[Vec<f64>], labels: &[usize]) -> f64 {
        let mut ok = 0;
        for (x, &l) in xs.iter().zip(labels) {
            if super::argmax(&self.forward(x)) == l {
                ok += 1;
            }
        }
        ok as f64 / xs.len() as f64
    }

    /// Export as the layer list `DenseNet::from_float` consumes.
    pub fn layers(&self) -> Vec<(Vec<Vec<f64>>, Vec<f64>)> {
        self.weights
            .iter()
            .cloned()
            .zip(self.biases.iter().cloned())
            .collect()
    }
}

fn softmax(v: &[f64]) -> Vec<f64> {
    let m = v.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = v.iter().map(|&x| (x - m).exp()).collect();
    let s: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / s).collect()
}

/// Synthetic two-spiral dataset (the classic nonlinear benchmark).
pub fn spirals(n_per_class: usize, noise: f64, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<usize>) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for class in 0..2usize {
        for i in 0..n_per_class {
            let t = 0.5 + 3.0 * i as f64 / n_per_class as f64; // radius-ish
            let ang = t * 2.6 + class as f64 * std::f64::consts::PI;
            xs.push(vec![
                t * ang.cos() * 0.5 + rng.normal() * noise,
                t * ang.sin() * 0.5 + rng.normal() * noise,
            ]);
            ys.push(class);
        }
    }
    (xs, ys)
}

/// Gaussian blobs, `k` classes in 2D.
pub fn blobs(k: usize, n_per_class: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<usize>) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for class in 0..k {
        let ang = class as f64 / k as f64 * std::f64::consts::TAU;
        let (cx, cy) = (1.4 * ang.cos(), 1.4 * ang.sin());
        for _ in 0..n_per_class {
            xs.push(vec![cx + rng.normal() * 0.35, cy + rng.normal() * 0.35]);
            ys.push(class);
        }
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trains_blobs_to_high_accuracy() {
        let mut rng = Rng::new(7);
        let (xs, ys) = blobs(3, 60, &mut rng);
        let mut net = Mlp::new(&[2, 16, 3], &mut rng);
        let acc = net.train(&xs, &ys, 30, 0.05, &mut rng);
        assert!(acc > 0.95, "train accuracy {acc}");
    }

    #[test]
    fn trains_spirals_above_chance() {
        let mut rng = Rng::new(8);
        let (xs, ys) = spirals(120, 0.03, &mut rng);
        let mut net = Mlp::new(&[2, 24, 2], &mut rng);
        let acc = net.train(&xs, &ys, 80, 0.03, &mut rng);
        assert!(acc > 0.85, "train accuracy {acc}");
    }

    #[test]
    fn loss_decreases() {
        let mut rng = Rng::new(9);
        let (xs, ys) = blobs(2, 40, &mut rng);
        let mut net = Mlp::new(&[2, 8, 2], &mut rng);
        let mut first = 0.0;
        let mut last = 0.0;
        for e in 0..20 {
            let mut total = 0.0;
            for (x, &y) in xs.iter().zip(&ys) {
                total += net.sgd_step(x, y, 0.05);
            }
            if e == 0 {
                first = total;
            }
            last = total;
        }
        assert!(last < first * 0.5, "loss {first} -> {last}");
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }
}
