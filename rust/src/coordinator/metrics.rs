//! Serving metrics: counters, latency histograms, and latency/
//! batch-fill statistics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::{percentile, Reservoir};

/// Newest latency samples kept per metrics sink — a hard memory bound
/// for long-running servers (the ring overwrites in place, so sustained
/// traffic can never grow the allocation past this).
const LATENCY_RESERVOIR: usize = 100_000;

/// Upper bounds (µs, inclusive) of the log-spaced latency buckets
/// shared by every histogram family the service exposes — per-route
/// request latency and the cluster client legs (forward, fan-out
/// shard, pool dial, gossip round). One shared scheme keeps `/metrics`
/// families directly comparable; the implicit `+Inf` terminal bucket
/// is tracked separately in [`Histogram`].
///
/// 100µs … 10s in 1–2.5–5 steps: wide enough for a local LUT hit at
/// the bottom and a cross-node failover chain at the top.
pub const HIST_BOUNDS_US: [u64; 16] = [
    100,
    250,
    500,
    1_000,
    2_500,
    5_000,
    10_000,
    25_000,
    50_000,
    100_000,
    250_000,
    500_000,
    1_000_000,
    2_500_000,
    5_000_000,
    10_000_000,
];

/// Lock-free fixed-bucket latency histogram (Prometheus `histogram`
/// semantics: rendered as cumulative `_bucket{le=...}` lines plus
/// `_sum`/`_count`). Buckets here store *per-bucket* counts; the
/// cumulative sum is computed at render time so the hot path is one
/// `fetch_add` per observation.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BOUNDS_US.len()],
    /// Observations above the last finite bound (`+Inf` residue).
    inf: AtomicU64,
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            inf: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe_us(&self, us: u64) {
        match HIST_BOUNDS_US.iter().position(|&b| us <= b) {
            Some(i) => self.buckets[i].fetch_add(1, Ordering::Relaxed),
            None => self.inf.fetch_add(1, Ordering::Relaxed),
        };
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe(&self, d: Duration) {
        self.observe_us(d.as_micros() as u64);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].load(Ordering::Relaxed)
            }),
            inf: self.inf.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Point-in-time histogram state (per-bucket counts, not cumulative).
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub buckets: [u64; HIST_BOUNDS_US.len()],
    pub inf: u64,
    pub sum_us: u64,
    pub count: u64,
}

/// Lock-light metrics sink shared by the coordinator's threads.
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of words packed into batches (for mean fill).
    pub batched_words: AtomicU64,
    /// Sum of padded capacity across batches.
    pub batch_capacity: AtomicU64,
    latencies_us: Mutex<Reservoir>,
    /// Full-distribution latency histogram (the reservoir above keeps
    /// only a recent window for the quantile gauges; the histogram is
    /// cumulative over the process lifetime, as Prometheus expects).
    pub latency_hist: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_words: AtomicU64::new(0),
            batch_capacity: AtomicU64::new(0),
            latencies_us: Mutex::new(Reservoir::new(LATENCY_RESERVOIR)),
            latency_hist: Histogram::new(),
        }
    }
}

/// A point-in-time summary.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub p50_latency_us: u64,
    pub p95_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
    pub latency_hist: HistSnapshot,
}

impl Metrics {
    pub fn record_latency(&self, d: Duration) {
        self.latencies_us.lock().unwrap().push(d.as_micros() as u64);
        self.latency_hist.observe(d);
    }

    pub fn record_batch(&self, words: u64, capacity: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_words.fetch_add(words, Ordering::Relaxed);
        self.batch_capacity.fetch_add(capacity, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        // Clone under the lock, sort outside it: an O(n log n) sort of
        // a full reservoir inside the guard would stall every
        // record_latency on the request hot path for milliseconds.
        let mut lats = self.latencies_us.lock().unwrap().samples();
        lats.sort_unstable();
        let cap = self.batch_capacity.load(Ordering::Relaxed);
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_batch_fill: if cap == 0 {
                0.0
            } else {
                self.batched_words.load(Ordering::Relaxed) as f64 / cap as f64
            },
            p50_latency_us: percentile(&lats, 0.50),
            p95_latency_us: percentile(&lats, 0.95),
            p99_latency_us: percentile(&lats, 0.99),
            max_latency_us: lats.last().copied().unwrap_or(0),
            latency_hist: self.latency_hist.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::default();
        for us in [10u64, 20, 30, 40, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        let s = m.snapshot();
        assert_eq!(s.p50_latency_us, 30);
        assert_eq!(s.max_latency_us, 1000);
        // Nearest-rank: the upper quantiles of five samples are the
        // maximum, not the second-largest the truncating picker chose.
        assert_eq!(s.p95_latency_us, 1000);
        assert_eq!(s.p99_latency_us, 1000);
    }

    #[test]
    fn histogram_buckets_sum_count() {
        let h = Histogram::new();
        h.observe_us(50); // <= 100 -> bucket 0
        h.observe_us(100); // inclusive bound -> bucket 0
        h.observe_us(101); // -> bucket 1 (250)
        h.observe_us(9_999_999); // -> last finite bucket (10s)
        h.observe_us(10_000_001); // -> +Inf
        let s = h.snapshot();
        assert_eq!(s.buckets[0], 2);
        assert_eq!(s.buckets[1], 1);
        assert_eq!(s.buckets[HIST_BOUNDS_US.len() - 1], 1);
        assert_eq!(s.inf, 1);
        assert_eq!(s.count, 5);
        assert_eq!(s.sum_us, 50 + 100 + 101 + 9_999_999 + 10_000_001);
        // The bounds themselves must be strictly increasing — the
        // `/metrics` lint checks the rendered form, this checks the
        // source of truth.
        for w in HIST_BOUNDS_US.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn record_latency_feeds_histogram() {
        let m = Metrics::default();
        m.record_latency(Duration::from_micros(300));
        let s = m.snapshot();
        assert_eq!(s.latency_hist.count, 1);
        assert_eq!(s.latency_hist.buckets[2], 1); // 300µs -> le=500µs
    }

    #[test]
    fn batch_fill() {
        let m = Metrics::default();
        m.record_batch(512, 1024);
        m.record_batch(1024, 1024);
        let s = m.snapshot();
        assert!((s.mean_batch_fill - 0.75).abs() < 1e-9);
        assert_eq!(s.batches, 2);
    }

    #[test]
    fn reservoir_bounded_and_snapshot_sane_past_cap() {
        let m = Metrics::default();
        // Push well past the reservoir bound; only the newest samples
        // survive, so every statistic must reflect the recent window.
        for i in 0..(LATENCY_RESERVOIR as u64 + 20_000) {
            m.record_latency(Duration::from_micros(i % 997));
        }
        let held = m.latencies_us.lock().unwrap().len();
        assert_eq!(held, LATENCY_RESERVOIR, "ring must not grow past cap");
        let s = m.snapshot();
        assert!(s.max_latency_us <= 996);
        assert!(s.p50_latency_us <= s.p95_latency_us);
        assert!(s.p95_latency_us <= s.p99_latency_us);
        assert!(s.p99_latency_us <= s.max_latency_us);
        assert!(s.p50_latency_us > 0, "recent window must dominate");
    }
}
