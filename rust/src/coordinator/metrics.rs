//! Serving metrics: counters + latency/batch-fill statistics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::{percentile, Reservoir};

/// Newest latency samples kept per metrics sink — a hard memory bound
/// for long-running servers (the ring overwrites in place, so sustained
/// traffic can never grow the allocation past this).
const LATENCY_RESERVOIR: usize = 100_000;

/// Lock-light metrics sink shared by the coordinator's threads.
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of words packed into batches (for mean fill).
    pub batched_words: AtomicU64,
    /// Sum of padded capacity across batches.
    pub batch_capacity: AtomicU64,
    latencies_us: Mutex<Reservoir>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_words: AtomicU64::new(0),
            batch_capacity: AtomicU64::new(0),
            latencies_us: Mutex::new(Reservoir::new(LATENCY_RESERVOIR)),
        }
    }
}

/// A point-in-time summary.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub p50_latency_us: u64,
    pub p95_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
}

impl Metrics {
    pub fn record_latency(&self, d: Duration) {
        self.latencies_us.lock().unwrap().push(d.as_micros() as u64);
    }

    pub fn record_batch(&self, words: u64, capacity: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_words.fetch_add(words, Ordering::Relaxed);
        self.batch_capacity.fetch_add(capacity, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        // Clone under the lock, sort outside it: an O(n log n) sort of
        // a full reservoir inside the guard would stall every
        // record_latency on the request hot path for milliseconds.
        let mut lats = self.latencies_us.lock().unwrap().samples();
        lats.sort_unstable();
        let cap = self.batch_capacity.load(Ordering::Relaxed);
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_batch_fill: if cap == 0 {
                0.0
            } else {
                self.batched_words.load(Ordering::Relaxed) as f64 / cap as f64
            },
            p50_latency_us: percentile(&lats, 0.50),
            p95_latency_us: percentile(&lats, 0.95),
            p99_latency_us: percentile(&lats, 0.99),
            max_latency_us: lats.last().copied().unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::default();
        for us in [10u64, 20, 30, 40, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        let s = m.snapshot();
        assert_eq!(s.p50_latency_us, 30);
        assert_eq!(s.max_latency_us, 1000);
        // Nearest-rank: the upper quantiles of five samples are the
        // maximum, not the second-largest the truncating picker chose.
        assert_eq!(s.p95_latency_us, 1000);
        assert_eq!(s.p99_latency_us, 1000);
    }

    #[test]
    fn batch_fill() {
        let m = Metrics::default();
        m.record_batch(512, 1024);
        m.record_batch(1024, 1024);
        let s = m.snapshot();
        assert!((s.mean_batch_fill - 0.75).abs() < 1e-9);
        assert_eq!(s.batches, 2);
    }

    #[test]
    fn reservoir_bounded_and_snapshot_sane_past_cap() {
        let m = Metrics::default();
        // Push well past the reservoir bound; only the newest samples
        // survive, so every statistic must reflect the recent window.
        for i in 0..(LATENCY_RESERVOIR as u64 + 20_000) {
            m.record_latency(Duration::from_micros(i % 997));
        }
        let held = m.latencies_us.lock().unwrap().len();
        assert_eq!(held, LATENCY_RESERVOIR, "ring must not grow past cap");
        let s = m.snapshot();
        assert!(s.max_latency_us <= 996);
        assert!(s.p50_latency_us <= s.p95_latency_us);
        assert!(s.p95_latency_us <= s.p99_latency_us);
        assert!(s.p99_latency_us <= s.max_latency_us);
        assert!(s.p50_latency_us > 0, "recent window must dominate");
    }
}
