//! Serving metrics: counters + latency/batch-fill statistics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Lock-light metrics sink shared by the coordinator's threads.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of words packed into batches (for mean fill).
    pub batched_words: AtomicU64,
    /// Sum of padded capacity across batches.
    pub batch_capacity: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

/// A point-in-time summary.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
}

impl Metrics {
    pub fn record_latency(&self, d: Duration) {
        let mut v = self.latencies_us.lock().unwrap();
        // Bounded reservoir: keep the newest 100k samples.
        if v.len() >= 100_000 {
            v.drain(..50_000);
        }
        v.push(d.as_micros() as u64);
    }

    pub fn record_batch(&self, words: u64, capacity: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_words.fetch_add(words, Ordering::Relaxed);
        self.batch_capacity.fetch_add(capacity, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut lats = self.latencies_us.lock().unwrap().clone();
        lats.sort_unstable();
        let pick = |q: f64| -> u64 {
            if lats.is_empty() {
                0
            } else {
                lats[((lats.len() - 1) as f64 * q) as usize]
            }
        };
        let cap = self.batch_capacity.load(Ordering::Relaxed);
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_batch_fill: if cap == 0 {
                0.0
            } else {
                self.batched_words.load(Ordering::Relaxed) as f64 / cap as f64
            },
            p50_latency_us: pick(0.50),
            p99_latency_us: pick(0.99),
            max_latency_us: lats.last().copied().unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::default();
        for us in [10u64, 20, 30, 40, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        let s = m.snapshot();
        assert_eq!(s.p50_latency_us, 30);
        assert_eq!(s.max_latency_us, 1000);
        assert!(s.p99_latency_us >= 40);
    }

    #[test]
    fn batch_fill() {
        let m = Metrics::default();
        m.record_batch(512, 1024);
        m.record_batch(1024, 1024);
        let s = m.snapshot();
        assert!((s.mean_batch_fill - 0.75).abs() < 1e-9);
        assert_eq!(s.batches, 2);
    }

    #[test]
    fn reservoir_bounded() {
        let m = Metrics::default();
        for i in 0..120_000u64 {
            m.record_latency(Duration::from_micros(i % 997));
        }
        // Should not blow past the bound.
        let s = m.snapshot();
        assert!(s.max_latency_us <= 996);
    }
}
