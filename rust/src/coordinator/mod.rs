//! Layer-3 serving coordinator.
//!
//! An activation/inference service in the shape of a serving-system
//! router: clients submit variable-size tanh requests; a leader thread
//! packs them into the fixed batch shapes of the compiled backends and
//! hands batches to worker threads; each worker owns a private backend
//! instance (PJRT executables are thread-affine) and scatters results
//! back to per-request completion handles. Python is never on this path.
//!
//! Components:
//! * [`batcher`] — pure batch packing/scattering logic.
//! * [`metrics`] — counters + latency percentiles + batch fill.
//! * [`Coordinator`] — request queue, leader loop, worker pool,
//!   backpressure, lifecycle.

pub mod batcher;
pub mod metrics;
pub mod router;

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::exec::{oneshot, Receiver, Sender};
use crate::runtime::{Runtime, Tensor};
use crate::tanh::{TanhConfig, TanhUnit};
use crate::util::log;

pub use metrics::{HistSnapshot, Histogram, Metrics, Snapshot, HIST_BOUNDS_US};

/// A per-worker execution engine for packed tanh batches.
pub enum Backend {
    /// The optimized native unit (bit-identical to the artifacts).
    Native(TanhUnit),
    /// A PJRT executable by artifact entry name (one client per worker:
    /// `xla::PjRtClient` is thread-affine).
    Pjrt { runtime: Runtime, entry: String },
}

impl Backend {
    fn run(&self, batch: &[i32]) -> Result<Vec<i32>, String> {
        match self {
            Backend::Native(unit) => Ok(unit.eval_batch_i32(batch)),
            Backend::Pjrt { runtime, entry } => {
                let out = runtime
                    .execute(entry, &[Tensor::I32(batch.to_vec())])
                    .map_err(|e| format!("pjrt: {e:#}"))?;
                out[0]
                    .as_i32()
                    .map(<[i32]>::to_vec)
                    .ok_or_else(|| "pjrt: wrong output dtype".to_string())
            }
        }
    }
}

/// Constructs a worker's backend on the worker's own thread.
pub type BackendFactory = Arc<dyn Fn() -> Result<Backend, String> + Send + Sync>;

/// Factory for the native bit-accurate unit (optionally fully memoized).
pub fn native_factory(cfg: TanhConfig, memoize: bool) -> BackendFactory {
    Arc::new(move || {
        let mut unit = TanhUnit::new(cfg).map_err(|e| e.to_string())?;
        if memoize {
            unit.precompute_all();
        }
        Ok(Backend::Native(unit))
    })
}

/// Factory for a PJRT-backed worker executing `entry` from `dir`.
pub fn pjrt_factory(dir: PathBuf, entry: String) -> BackendFactory {
    Arc::new(move || {
        let runtime = Runtime::new(&dir).map_err(|e| format!("{e:#}"))?;
        runtime.ensure_compiled(&entry).map_err(|e| format!("{e:#}"))?;
        Ok(Backend::Pjrt { runtime, entry: entry.clone() })
    })
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct Config {
    /// Fixed batch capacity (must match the artifact's shape for PJRT).
    pub batch_capacity: usize,
    /// Max time a request may wait for co-batching.
    pub max_wait: Duration,
    /// Worker threads executing batches (each owns a backend instance).
    pub workers: usize,
    /// Bound on queued requests before rejection (backpressure).
    pub queue_limit: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            batch_capacity: 1024,
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_limit: 4096,
        }
    }
}

struct PendingReq {
    words: Vec<i32>,
    resp: Sender<Result<Vec<i32>, String>>,
    enqueued: Instant,
}

/// A packed batch travelling from the leader to a worker.
struct Batch {
    packed: batcher::Packed,
    reqs: Vec<Option<PendingReq>>,
}

#[derive(Default)]
struct Queues {
    requests: VecDeque<PendingReq>,
    batches: VecDeque<Batch>,
}

struct Shared {
    q: Mutex<Queues>,
    req_ready: Condvar,
    batch_ready: Condvar,
    shutdown: AtomicBool,
}

/// The serving coordinator. Dropping it drains in-flight work and joins
/// every thread.
pub struct Coordinator {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    threads: Vec<std::thread::JoinHandle<()>>,
    cfg: Config,
}

impl Coordinator {
    /// Start the leader loop + `cfg.workers` backend workers.
    pub fn start(cfg: Config, factory: BackendFactory) -> Coordinator {
        let shared = Arc::new(Shared {
            q: Mutex::new(Queues::default()),
            req_ready: Condvar::new(),
            batch_ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(Metrics::default());
        let mut threads = Vec::new();

        // Leader: packs requests into batches.
        {
            let s = shared.clone();
            let m = metrics.clone();
            let c = cfg.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("tanhvf-leader".into())
                    .spawn(move || leader_loop(&s, &m, &c))
                    .expect("spawn leader"),
            );
        }
        // Workers: execute batches on private backends.
        for i in 0..cfg.workers.max(1) {
            let s = shared.clone();
            let m = metrics.clone();
            let f = factory.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("tanhvf-worker-{i}"))
                    .spawn(move || worker_loop(&s, &m, &f))
                    .expect("spawn worker"),
            );
        }

        Coordinator { shared, metrics, threads, cfg }
    }

    /// Submit a tanh request (input fixed-point words). Returns a
    /// completion handle resolving to the output words.
    pub fn submit(&self, words: Vec<i32>) -> Receiver<Result<Vec<i32>, String>> {
        let (tx, rx) = oneshot();
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        if words.is_empty() || words.len() > self.cfg.batch_capacity {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            tx.send(Err(format!(
                "request size {} outside 1..={}",
                words.len(),
                self.cfg.batch_capacity
            )));
            return rx;
        }
        {
            let mut q = self.shared.q.lock().unwrap();
            if q.requests.len() >= self.cfg.queue_limit {
                drop(q);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                tx.send(Err("queue full (backpressure)".into()));
                return rx;
            }
            q.requests.push_back(PendingReq {
                words,
                resp: tx,
                enqueued: Instant::now(),
            });
        }
        self.shared.req_ready.notify_one();
        rx
    }

    /// Convenience: blocking evaluation through the service.
    pub fn eval_blocking(&self, words: Vec<i32>) -> Result<Vec<i32>, String> {
        self.submit(words)
            .recv()
            .unwrap_or_else(|| Err("coordinator dropped".into()))
    }

    pub fn snapshot(&self) -> Snapshot {
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.req_ready.notify_all();
        self.shared.batch_ready.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn leader_loop(shared: &Arc<Shared>, metrics: &Arc<Metrics>, cfg: &Config) {
    let capacity = cfg.batch_capacity;
    loop {
        let taken: Vec<PendingReq> = {
            let mut q = shared.q.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst)
                    && q.requests.is_empty()
                {
                    return;
                }
                if let Some(front) = q.requests.front() {
                    let filled: usize =
                        q.requests.iter().map(|r| r.words.len()).sum();
                    let deadline_hit =
                        front.enqueued.elapsed() >= cfg.max_wait;
                    if filled >= capacity
                        || deadline_hit
                        || shared.shutdown.load(Ordering::SeqCst)
                    {
                        let mut used = 0usize;
                        let mut out = Vec::new();
                        while let Some(r) = q.requests.front() {
                            if used + r.words.len() > capacity {
                                break;
                            }
                            used += r.words.len();
                            out.push(q.requests.pop_front().unwrap());
                        }
                        break out;
                    }
                    let wait = cfg.max_wait.saturating_sub(front.enqueued.elapsed());
                    let (guard, _) = shared
                        .req_ready
                        .wait_timeout(q, wait.max(Duration::from_micros(50)))
                        .unwrap();
                    q = guard;
                } else {
                    let (guard, _) = shared
                        .req_ready
                        .wait_timeout(q, Duration::from_millis(20))
                        .unwrap();
                    q = guard;
                }
            }
        };
        if taken.is_empty() {
            continue;
        }

        let words: Vec<Vec<i32>> =
            taken.iter().map(|r| r.words.clone()).collect();
        let (packed, n) = batcher::pack(&words, capacity, 0);
        debug_assert_eq!(n, words.len());
        metrics.record_batch(packed.used as u64, capacity as u64);

        {
            let mut q = shared.q.lock().unwrap();
            q.batches.push_back(Batch {
                packed,
                reqs: taken.into_iter().map(Some).collect(),
            });
        }
        shared.batch_ready.notify_one();
    }
}

fn worker_loop(
    shared: &Arc<Shared>,
    metrics: &Arc<Metrics>,
    factory: &BackendFactory,
) {
    let backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            // Stay alive in failing mode: drain batches with an error so
            // no request is ever stranded (other workers may be healthy
            // and will race us for batches; liveness is preserved either
            // way).
            log::error(
                "coordinator",
                "backend construction failed; worker draining with errors",
                &[("error", e.clone())],
            );
            loop {
                let batch = {
                    let mut q = shared.q.lock().unwrap();
                    loop {
                        if let Some(b) = q.batches.pop_front() {
                            break Some(b);
                        }
                        if shared.shutdown.load(Ordering::SeqCst) {
                            break None;
                        }
                        let (guard, _) = shared
                            .batch_ready
                            .wait_timeout(q, Duration::from_millis(20))
                            .unwrap();
                        q = guard;
                    }
                };
                let Some(Batch { mut reqs, .. }) = batch else { return };
                for slot in reqs.iter_mut() {
                    if let Some(req) = slot.take() {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        req.resp.send(Err(format!("backend unavailable: {e}")));
                    }
                }
            }
        }
    };
    loop {
        let batch = {
            let mut q = shared.q.lock().unwrap();
            loop {
                if let Some(b) = q.batches.pop_front() {
                    break b;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let (guard, _) = shared
                    .batch_ready
                    .wait_timeout(q, Duration::from_millis(20))
                    .unwrap();
                q = guard;
            }
        };
        let Batch { packed, mut reqs } = batch;
        match backend.run(&packed.batch) {
            Ok(out) => {
                for (idx, words) in batcher::unpack(&packed, &out) {
                    let req = reqs[idx].take().expect("slot used once");
                    metrics.record_latency(req.enqueued.elapsed());
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    req.resp.send(Ok(words));
                }
            }
            Err(e) => {
                for slot in reqs.iter_mut() {
                    if let Some(req) = slot.take() {
                        req.resp.send(Err(e.clone()));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::golden::tanh_golden_batch;

    fn native_coordinator(capacity: usize) -> Coordinator {
        Coordinator::start(
            Config {
                batch_capacity: capacity,
                max_wait: Duration::from_millis(1),
                workers: 2,
                queue_limit: 64,
            },
            native_factory(TanhConfig::s3_12(), true),
        )
    }

    #[test]
    fn serves_single_request_correctly() {
        let c = native_coordinator(256);
        let words: Vec<i32> = (-50..50).map(|i| i * 100).collect();
        let got = c.eval_blocking(words.clone()).unwrap();
        let want = tanh_golden_batch(
            &words.iter().map(|&w| w as i64).collect::<Vec<_>>(),
            &TanhConfig::s3_12(),
        );
        assert_eq!(got.iter().map(|&v| v as i64).collect::<Vec<_>>(), want);
    }

    #[test]
    fn batches_multiple_concurrent_requests() {
        let c = native_coordinator(1024);
        let handles: Vec<_> = (0..16)
            .map(|k| c.submit(vec![k as i32 * 37; 57]))
            .collect();
        for (k, h) in handles.into_iter().enumerate() {
            let out = h.recv().unwrap().unwrap();
            assert_eq!(out.len(), 57);
            let want = crate::tanh::tanh_golden(
                (k as i64) * 37,
                &TanhConfig::s3_12(),
            );
            assert!(out.iter().all(|&v| v as i64 == want));
        }
        let s = c.snapshot();
        assert_eq!(s.completed, 16);
        // Co-batching must have happened (fewer batches than requests).
        assert!(s.batches < 16, "batches {}", s.batches);
        assert!(s.mean_batch_fill > 0.0);
    }

    #[test]
    fn rejects_oversize_and_empty() {
        let c = native_coordinator(128);
        assert!(c.eval_blocking(vec![0; 129]).is_err());
        assert!(c.eval_blocking(vec![]).is_err());
        assert_eq!(c.snapshot().rejected, 2);
    }

    #[test]
    fn order_and_values_preserved_under_flood() {
        let c = native_coordinator(512);
        let reqs: Vec<Vec<i32>> = (0..40)
            .map(|k| (0..11).map(|j| (k * 991 + j * 7) as i32 % 30000).collect())
            .collect();
        let handles: Vec<_> =
            reqs.iter().map(|r| c.submit(r.clone())).collect();
        for (r, h) in reqs.iter().zip(handles) {
            let got = h.recv().unwrap().unwrap();
            let want = tanh_golden_batch(
                &r.iter().map(|&w| w as i64).collect::<Vec<_>>(),
                &TanhConfig::s3_12(),
            );
            assert_eq!(
                got.iter().map(|&v| v as i64).collect::<Vec<_>>(),
                want
            );
        }
    }

    #[test]
    fn clean_shutdown_under_load() {
        let c = native_coordinator(256);
        let mut handles = Vec::new();
        for k in 0..32 {
            handles.push(c.submit(vec![k; 16]));
        }
        drop(c); // must not hang; pending handles resolve or close
        for h in handles {
            let _ = h.recv_timeout(Duration::from_secs(2));
        }
    }

    #[test]
    fn backpressure_rejects_when_flooded() {
        // Tiny queue limit, long batching window -> floods reject.
        let c = Coordinator::start(
            Config {
                batch_capacity: 1024,
                max_wait: Duration::from_millis(50),
                workers: 1,
                queue_limit: 4,
            },
            native_factory(TanhConfig::s3_12(), false),
        );
        let handles: Vec<_> = (0..64).map(|_| c.submit(vec![1; 8])).collect();
        let mut rejected = 0;
        for h in handles {
            if h.recv().unwrap().is_err() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
    }
}
