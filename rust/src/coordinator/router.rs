//! Multi-model router: one coordinator instance per served model /
//! precision, with name-based routing — the front door of the
//! activation service (a vLLM-router-shaped shim over [`Coordinator`]).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use crate::exec::Receiver;
use crate::tanh::TanhConfig;

use super::{native_factory, pjrt_factory, BackendFactory, Config, Coordinator,
            Snapshot};

/// Which engine a route uses.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouteBackend {
    /// Native bit-accurate unit for `cfg` (memoized if `memo`).
    Native { cfg: TanhConfig, memo: bool },
    /// A PJRT artifact entry from `dir`.
    Pjrt { dir: PathBuf, entry: String },
}

impl RouteBackend {
    /// Short backend kind for route tables and metrics labels.
    pub fn kind(&self) -> &'static str {
        match self {
            RouteBackend::Native { .. } => "native",
            RouteBackend::Pjrt { .. } => "pjrt",
        }
    }

    /// One-line human description (the `/v1/models` detail field).
    pub fn describe(&self) -> String {
        match self {
            RouteBackend::Native { cfg, memo } => {
                format!("{}{}", cfg.describe(), if *memo { " memo" } else { "" })
            }
            RouteBackend::Pjrt { dir, entry } => {
                format!("{}:{entry}", dir.display())
            }
        }
    }

    /// The datapath config, when statically known (native routes).
    pub fn native_cfg(&self) -> Option<TanhConfig> {
        match self {
            RouteBackend::Native { cfg, .. } => Some(*cfg),
            RouteBackend::Pjrt { .. } => None,
        }
    }
}

/// Declarative route table entry.
#[derive(Clone, Debug)]
pub struct Route {
    pub name: String,
    pub backend: RouteBackend,
    pub batch_capacity: usize,
    pub max_wait: Duration,
    pub workers: usize,
    /// Bound on queued requests before rejection (backpressure; the
    /// HTTP front-end maps rejections to 503).
    pub queue_limit: usize,
}

impl Route {
    pub fn native(name: &str, cfg: TanhConfig) -> Route {
        Route {
            name: name.to_string(),
            backend: RouteBackend::Native { cfg, memo: true },
            batch_capacity: 1024,
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_limit: 8192,
        }
    }

    pub fn pjrt(name: &str, dir: PathBuf, entry: &str, capacity: usize) -> Route {
        Route {
            name: name.to_string(),
            backend: RouteBackend::Pjrt { dir, entry: entry.to_string() },
            batch_capacity: capacity,
            max_wait: Duration::from_millis(2),
            workers: 1,
            queue_limit: 8192,
        }
    }

    pub fn with_queue_limit(mut self, n: usize) -> Route {
        self.queue_limit = n;
        self
    }

    pub fn with_batch(mut self, capacity: usize, max_wait: Duration) -> Route {
        self.batch_capacity = capacity;
        self.max_wait = max_wait;
        self
    }

    pub fn with_workers(mut self, n: usize) -> Route {
        self.workers = n;
        self
    }

    fn factory(&self) -> BackendFactory {
        match &self.backend {
            RouteBackend::Native { cfg, memo } => native_factory(*cfg, *memo),
            RouteBackend::Pjrt { dir, entry } => {
                pjrt_factory(dir.clone(), entry.clone())
            }
        }
    }
}

/// Static description of a started route — everything the serving
/// front-end needs for `/v1/models`, request validation, and metrics
/// labels.
#[derive(Clone, Debug)]
pub struct RouteInfo {
    pub name: String,
    pub kind: &'static str,
    pub detail: String,
    pub native_cfg: Option<TanhConfig>,
    pub batch_capacity: usize,
    pub workers: usize,
    pub queue_limit: usize,
}

struct RouteEntry {
    info: RouteInfo,
    coord: Coordinator,
}

/// The router: owns one coordinator per route.
pub struct Router {
    routes: BTreeMap<String, RouteEntry>,
}

impl Router {
    /// Start coordinators for every route. Duplicate names are an error.
    pub fn start(routes: Vec<Route>) -> Result<Router, String> {
        let mut map = BTreeMap::new();
        for r in routes {
            if map.contains_key(&r.name) {
                return Err(format!("duplicate route '{}'", r.name));
            }
            let info = RouteInfo {
                name: r.name.clone(),
                kind: r.backend.kind(),
                detail: r.backend.describe(),
                native_cfg: r.backend.native_cfg(),
                batch_capacity: r.batch_capacity,
                workers: r.workers,
                queue_limit: r.queue_limit,
            };
            let coord = Coordinator::start(
                Config {
                    batch_capacity: r.batch_capacity,
                    max_wait: r.max_wait,
                    workers: r.workers,
                    queue_limit: r.queue_limit,
                },
                r.factory(),
            );
            map.insert(r.name.clone(), RouteEntry { info, coord });
        }
        Ok(Router { routes: map })
    }

    pub fn route_names(&self) -> Vec<&str> {
        self.routes.keys().map(String::as_str).collect()
    }

    /// Static metadata for every route, in name order.
    pub fn route_infos(&self) -> Vec<RouteInfo> {
        self.routes.values().map(|e| e.info.clone()).collect()
    }

    /// Static metadata for one route.
    pub fn route_info(&self, route: &str) -> Option<RouteInfo> {
        self.routes.get(route).map(|e| e.info.clone())
    }

    /// Submit to a named route.
    pub fn submit(
        &self,
        route: &str,
        words: Vec<i32>,
    ) -> Result<Receiver<Result<Vec<i32>, String>>, String> {
        self.routes
            .get(route)
            .map(|e| e.coord.submit(words))
            .ok_or_else(|| format!("unknown route '{route}'"))
    }

    /// Blocking convenience.
    pub fn eval_blocking(
        &self,
        route: &str,
        words: Vec<i32>,
    ) -> Result<Vec<i32>, String> {
        self.submit(route, words)?
            .recv()
            .unwrap_or_else(|| Err("router dropped".into()))
    }

    /// Per-route metrics.
    pub fn snapshots(&self) -> BTreeMap<String, Snapshot> {
        self.routes
            .iter()
            .map(|(k, e)| (k.clone(), e.coord.snapshot()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::golden::tanh_golden_batch;

    fn two_precision_router() -> Router {
        Router::start(vec![
            Route::native("tanh16", TanhConfig::s3_12()),
            Route::native("tanh8", TanhConfig::s3_5()),
        ])
        .unwrap()
    }

    #[test]
    fn routes_by_precision() {
        let r = two_precision_router();
        let w16 = vec![4096i32, -4096, 12000];
        let w8 = vec![32i32, -32, 100];
        let got16 = r.eval_blocking("tanh16", w16.clone()).unwrap();
        let got8 = r.eval_blocking("tanh8", w8.clone()).unwrap();
        let want16 = tanh_golden_batch(
            &w16.iter().map(|&v| v as i64).collect::<Vec<_>>(),
            &TanhConfig::s3_12(),
        );
        let want8 = tanh_golden_batch(
            &w8.iter().map(|&v| v as i64).collect::<Vec<_>>(),
            &TanhConfig::s3_5(),
        );
        assert_eq!(got16.iter().map(|&v| v as i64).collect::<Vec<_>>(), want16);
        assert_eq!(got8.iter().map(|&v| v as i64).collect::<Vec<_>>(), want8);
    }

    #[test]
    fn unknown_route_rejected() {
        let r = two_precision_router();
        assert!(r.eval_blocking("nope", vec![1]).is_err());
    }

    #[test]
    fn duplicate_route_rejected() {
        let err = Router::start(vec![
            Route::native("a", TanhConfig::s3_12()),
            Route::native("a", TanhConfig::s3_5()),
        ]);
        assert!(err.is_err());
    }

    #[test]
    fn per_route_metrics_isolated() {
        let r = two_precision_router();
        for _ in 0..5 {
            r.eval_blocking("tanh16", vec![100; 8]).unwrap();
        }
        let snaps = r.snapshots();
        assert_eq!(snaps["tanh16"].completed, 5);
        assert_eq!(snaps["tanh8"].completed, 0);
    }

    #[test]
    fn route_table_is_deterministic_and_complete() {
        // `/v1/models` depends on infos covering every route, in a
        // stable (name-sorted) order, including idle routes.
        let r = two_precision_router();
        assert_eq!(r.route_names(), vec!["tanh16", "tanh8"]);
        let infos = r.route_infos();
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].name, "tanh16");
        assert_eq!(infos[0].kind, "native");
        assert_eq!(infos[0].native_cfg, Some(TanhConfig::s3_12()));
        assert!(infos[0].detail.contains("s3.12"));
        assert_eq!(infos[1].native_cfg, Some(TanhConfig::s3_5()));
        // Snapshots must also cover idle routes (so `/metrics` never
        // drops a label between scrapes).
        let snaps = r.snapshots();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps["tanh8"].submitted, 0);
    }

    #[test]
    fn route_info_reflects_overrides() {
        let r = Router::start(vec![Route::native("a", TanhConfig::s3_12())
            .with_queue_limit(3)
            .with_workers(1)
            .with_batch(64, Duration::from_millis(1))])
        .unwrap();
        let i = r.route_info("a").unwrap();
        assert_eq!(i.queue_limit, 3);
        assert_eq!(i.workers, 1);
        assert_eq!(i.batch_capacity, 64);
        assert!(r.route_info("nope").is_none());
    }

    #[test]
    fn pjrt_route_info_has_no_native_cfg() {
        let r = Router::start(vec![Route::pjrt(
            "p",
            PathBuf::from("/tmp/artifacts"),
            "tanh_s3_12",
            512,
        )])
        .unwrap();
        let i = r.route_info("p").unwrap();
        assert_eq!(i.kind, "pjrt");
        assert_eq!(i.native_cfg, None);
        assert!(i.detail.contains("tanh_s3_12"));
    }

    #[test]
    fn per_route_queue_limit_backpressure() {
        // A tiny queue with a long batching window must reject floods on
        // that route only — the other route stays unaffected.
        let r = Router::start(vec![
            Route::native("tiny", TanhConfig::s3_12())
                .with_queue_limit(2)
                .with_workers(1)
                .with_batch(1024, Duration::from_millis(100)),
            Route::native("big", TanhConfig::s3_5()),
        ])
        .unwrap();
        let handles: Vec<_> = (0..32)
            .map(|_| r.submit("tiny", vec![1; 4]).unwrap())
            .collect();
        let rejected = handles
            .into_iter()
            .map(|h| h.recv().unwrap())
            .filter(Result::is_err)
            .count();
        assert!(rejected > 0, "expected queue-limit rejections");
        assert!(r.eval_blocking("big", vec![5; 4]).is_ok());
        assert_eq!(r.snapshots()["big"].rejected, 0);
    }

    #[test]
    fn failed_backend_drains_with_errors_not_hangs() {
        // A PJRT route pointing at a nonexistent artifact directory must
        // answer requests with errors (liveness), not strand them.
        let r = Router::start(vec![Route::pjrt(
            "broken",
            PathBuf::from("/nonexistent/artifacts"),
            "tanh_s3_12",
            1024,
        )])
        .unwrap();
        let res = r
            .submit("broken", vec![1, 2, 3])
            .unwrap()
            .recv_timeout(Duration::from_secs(5));
        match res {
            Some(Err(_)) | None => {} // error or closed — both are live
            Some(Ok(_)) => panic!("broken backend returned Ok"),
        }
    }
}
