//! Multi-model router: one coordinator instance per served model /
//! precision, with name-based routing — the front door of the
//! activation service (a vLLM-router-shaped shim over [`Coordinator`]).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use crate::exec::Receiver;
use crate::tanh::TanhConfig;

use super::{native_factory, pjrt_factory, BackendFactory, Config, Coordinator,
            Snapshot};

/// Which engine a route uses.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouteBackend {
    /// Native bit-accurate unit for `cfg` (memoized if `memo`).
    Native { cfg: TanhConfig, memo: bool },
    /// A PJRT artifact entry from `dir`.
    Pjrt { dir: PathBuf, entry: String },
}

/// Declarative route table entry.
#[derive(Clone, Debug)]
pub struct Route {
    pub name: String,
    pub backend: RouteBackend,
    pub batch_capacity: usize,
    pub max_wait: Duration,
    pub workers: usize,
}

impl Route {
    pub fn native(name: &str, cfg: TanhConfig) -> Route {
        Route {
            name: name.to_string(),
            backend: RouteBackend::Native { cfg, memo: true },
            batch_capacity: 1024,
            max_wait: Duration::from_millis(2),
            workers: 2,
        }
    }

    pub fn pjrt(name: &str, dir: PathBuf, entry: &str, capacity: usize) -> Route {
        Route {
            name: name.to_string(),
            backend: RouteBackend::Pjrt { dir, entry: entry.to_string() },
            batch_capacity: capacity,
            max_wait: Duration::from_millis(2),
            workers: 1,
        }
    }

    fn factory(&self) -> BackendFactory {
        match &self.backend {
            RouteBackend::Native { cfg, memo } => native_factory(*cfg, *memo),
            RouteBackend::Pjrt { dir, entry } => {
                pjrt_factory(dir.clone(), entry.clone())
            }
        }
    }
}

/// The router: owns one coordinator per route.
pub struct Router {
    routes: BTreeMap<String, Coordinator>,
}

impl Router {
    /// Start coordinators for every route. Duplicate names are an error.
    pub fn start(routes: Vec<Route>) -> Result<Router, String> {
        let mut map = BTreeMap::new();
        for r in routes {
            if map.contains_key(&r.name) {
                return Err(format!("duplicate route '{}'", r.name));
            }
            let coord = Coordinator::start(
                Config {
                    batch_capacity: r.batch_capacity,
                    max_wait: r.max_wait,
                    workers: r.workers,
                    queue_limit: 8192,
                },
                r.factory(),
            );
            map.insert(r.name.clone(), coord);
        }
        Ok(Router { routes: map })
    }

    pub fn route_names(&self) -> Vec<&str> {
        self.routes.keys().map(String::as_str).collect()
    }

    /// Submit to a named route.
    pub fn submit(
        &self,
        route: &str,
        words: Vec<i32>,
    ) -> Result<Receiver<Result<Vec<i32>, String>>, String> {
        self.routes
            .get(route)
            .map(|c| c.submit(words))
            .ok_or_else(|| format!("unknown route '{route}'"))
    }

    /// Blocking convenience.
    pub fn eval_blocking(
        &self,
        route: &str,
        words: Vec<i32>,
    ) -> Result<Vec<i32>, String> {
        self.submit(route, words)?
            .recv()
            .unwrap_or_else(|| Err("router dropped".into()))
    }

    /// Per-route metrics.
    pub fn snapshots(&self) -> BTreeMap<String, Snapshot> {
        self.routes
            .iter()
            .map(|(k, c)| (k.clone(), c.snapshot()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::golden::tanh_golden_batch;

    fn two_precision_router() -> Router {
        Router::start(vec![
            Route::native("tanh16", TanhConfig::s3_12()),
            Route::native("tanh8", TanhConfig::s3_5()),
        ])
        .unwrap()
    }

    #[test]
    fn routes_by_precision() {
        let r = two_precision_router();
        let w16 = vec![4096i32, -4096, 12000];
        let w8 = vec![32i32, -32, 100];
        let got16 = r.eval_blocking("tanh16", w16.clone()).unwrap();
        let got8 = r.eval_blocking("tanh8", w8.clone()).unwrap();
        let want16 = tanh_golden_batch(
            &w16.iter().map(|&v| v as i64).collect::<Vec<_>>(),
            &TanhConfig::s3_12(),
        );
        let want8 = tanh_golden_batch(
            &w8.iter().map(|&v| v as i64).collect::<Vec<_>>(),
            &TanhConfig::s3_5(),
        );
        assert_eq!(got16.iter().map(|&v| v as i64).collect::<Vec<_>>(), want16);
        assert_eq!(got8.iter().map(|&v| v as i64).collect::<Vec<_>>(), want8);
    }

    #[test]
    fn unknown_route_rejected() {
        let r = two_precision_router();
        assert!(r.eval_blocking("nope", vec![1]).is_err());
    }

    #[test]
    fn duplicate_route_rejected() {
        let err = Router::start(vec![
            Route::native("a", TanhConfig::s3_12()),
            Route::native("a", TanhConfig::s3_5()),
        ]);
        assert!(err.is_err());
    }

    #[test]
    fn per_route_metrics_isolated() {
        let r = two_precision_router();
        for _ in 0..5 {
            r.eval_blocking("tanh16", vec![100; 8]).unwrap();
        }
        let snaps = r.snapshots();
        assert_eq!(snaps["tanh16"].completed, 5);
        assert_eq!(snaps["tanh8"].completed, 0);
    }

    #[test]
    fn failed_backend_drains_with_errors_not_hangs() {
        // A PJRT route pointing at a nonexistent artifact directory must
        // answer requests with errors (liveness), not strand them.
        let r = Router::start(vec![Route::pjrt(
            "broken",
            PathBuf::from("/nonexistent/artifacts"),
            "tanh_s3_12",
            1024,
        )])
        .unwrap();
        let res = r
            .submit("broken", vec![1, 2, 3])
            .unwrap()
            .recv_timeout(Duration::from_secs(5));
        match res {
            Some(Err(_)) | None => {} // error or closed — both are live
            Some(Ok(_)) => panic!("broken backend returned Ok"),
        }
    }
}
