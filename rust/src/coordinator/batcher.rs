//! Dynamic batcher: packs variable-size activation requests into the
//! fixed-shape batches the compiled executables (and the hardware unit)
//! accept, padding the remainder, and scatters results back per request.
//!
//! Pure packing logic lives here (thread-free, fully unit-tested); the
//! serving loop in [`super`] drives it.

/// One pending request's words and its slot in the batch.
#[derive(Clone, Debug)]
pub struct Packed {
    /// (request index, offset in batch, length) per request.
    pub slots: Vec<(usize, usize, usize)>,
    /// The padded batch (len == capacity).
    pub batch: Vec<i32>,
    /// Words actually used.
    pub used: usize,
}

/// Greedy first-fit packer: fills up to `capacity` words from the queue
/// front; requests larger than `capacity` must be pre-split by the
/// caller (the coordinator enforces a max request size).
pub fn pack(requests: &[Vec<i32>], capacity: usize, pad_word: i32) -> (Packed, usize) {
    let mut batch = Vec::with_capacity(capacity);
    let mut slots = Vec::new();
    let mut taken = 0usize;
    for (i, words) in requests.iter().enumerate() {
        assert!(
            words.len() <= capacity,
            "request of {} words exceeds batch capacity {capacity}",
            words.len()
        );
        if batch.len() + words.len() > capacity {
            break;
        }
        slots.push((i, batch.len(), words.len()));
        batch.extend_from_slice(words);
        taken = i + 1;
    }
    let used = batch.len();
    batch.resize(capacity, pad_word);
    (Packed { slots, batch, used }, taken)
}

/// Scatter a batch result back into per-request vectors.
pub fn unpack(packed: &Packed, result: &[i32]) -> Vec<(usize, Vec<i32>)> {
    packed
        .slots
        .iter()
        .map(|&(req, off, len)| (req, result[off..off + len].to_vec()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{self, vec_of, int};

    #[test]
    fn packs_until_capacity() {
        let reqs = vec![vec![1i32; 400], vec![2; 400], vec![3; 400]];
        let (p, taken) = pack(&reqs, 1024, 0);
        assert_eq!(taken, 2);
        assert_eq!(p.used, 800);
        assert_eq!(p.batch.len(), 1024);
        assert_eq!(p.batch[799], 2);
        assert_eq!(p.batch[800], 0); // padding
    }

    #[test]
    fn unpack_restores_requests() {
        let reqs = vec![vec![5i32, 6], vec![7, 8, 9]];
        let (p, taken) = pack(&reqs, 8, 0);
        assert_eq!(taken, 2);
        // Simulate an identity backend.
        let out = unpack(&p, &p.batch);
        assert_eq!(out[0], (0, vec![5, 6]));
        assert_eq!(out[1], (1, vec![7, 8, 9]));
    }

    #[test]
    fn empty_queue() {
        let (p, taken) = pack(&[], 16, 0);
        assert_eq!(taken, 0);
        assert_eq!(p.used, 0);
        assert!(p.slots.is_empty());
    }

    #[test]
    #[should_panic(expected = "exceeds batch capacity")]
    fn oversize_request_rejected() {
        let _ = pack(&[vec![0i32; 2000]], 1024, 0);
    }

    #[test]
    fn zero_length_requests_pack_and_scatter() {
        // A zero-word request occupies a slot but no batch words, and
        // must scatter back as an empty result — including when it
        // lands exactly at the capacity boundary.
        let reqs = vec![vec![], vec![7i32, 8], vec![]];
        let (p, taken) = pack(&reqs, 4, 0);
        assert_eq!(taken, 3);
        assert_eq!(p.used, 2);
        assert_eq!(p.slots, vec![(0, 0, 0), (1, 0, 2), (2, 2, 0)]);
        let out = unpack(&p, &p.batch);
        assert_eq!(out[0], (0, vec![]));
        assert_eq!(out[1], (1, vec![7, 8]));
        assert_eq!(out[2], (2, vec![]));

        // Zero-length request after an exactly-full batch: its slot
        // offset equals capacity, and unpack's `cap..cap` slice must
        // stay in bounds.
        let reqs = vec![vec![1i32; 4], vec![]];
        let (p, taken) = pack(&reqs, 4, 0);
        assert_eq!(taken, 2);
        assert_eq!(p.used, 4);
        assert_eq!(p.slots[1], (1, 4, 0));
        let out = unpack(&p, &p.batch);
        assert_eq!(out[1], (1, vec![]));
    }

    #[test]
    fn exact_capacity_fill_leaves_no_padding() {
        let reqs = vec![vec![1i32; 512], vec![2; 512], vec![3; 1]];
        let (p, taken) = pack(&reqs, 1024, -9);
        assert_eq!(taken, 2, "third request must wait for the next batch");
        assert_eq!(p.used, 1024);
        assert_eq!(p.batch.len(), 1024);
        assert!(!p.batch.contains(&-9), "no pad word in a full batch");
        assert_eq!(p.batch[511], 1);
        assert_eq!(p.batch[512], 2);
    }

    #[test]
    fn pad_words_fill_partial_batches_and_never_leak() {
        let reqs = vec![vec![5i32, 6, 7]];
        let (p, taken) = pack(&reqs, 8, -42);
        assert_eq!(taken, 1);
        assert_eq!(p.used, 3);
        assert_eq!(&p.batch[..3], &[5, 6, 7]);
        assert!(p.batch[3..].iter().all(|&w| w == -42), "{:?}", p.batch);

        // Scatter from a result where pad lanes hold poison: no request
        // may see a pad-lane value.
        let mut result = vec![i32::MIN; 8];
        result[..3].copy_from_slice(&[50, 60, 70]);
        let out = unpack(&p, &result);
        assert_eq!(out, vec![(0, vec![50, 60, 70])]);
    }

    #[test]
    fn property_pack_unpack_roundtrip() {
        // For arbitrary request shapes, packing then unpacking an
        // identity result returns every packed request verbatim.
        let g = vec_of(int(1, 64), 12);
        proptest::assert_prop("pack/unpack", 11, 300, &g, |lens| {
            let reqs: Vec<Vec<i32>> = lens
                .iter()
                .enumerate()
                .map(|(i, &l)| vec![i as i32; l as usize])
                .collect();
            let (p, taken) = pack(&reqs, 128, -1);
            let out = unpack(&p, &p.batch);
            if out.len() != p.slots.len() {
                return Err("slot count".into());
            }
            for (req_idx, words) in out {
                if words != reqs[req_idx] {
                    return Err(format!("request {req_idx} corrupted"));
                }
            }
            if taken < reqs.len() {
                let packed_words: usize =
                    reqs[..taken].iter().map(Vec::len).sum();
                let next = reqs[taken].len();
                if packed_words + next <= 128 {
                    return Err("should have packed more".into());
                }
            }
            Ok(())
        });
    }
}
