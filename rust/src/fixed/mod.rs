//! Fixed-point arithmetic substrate.
//!
//! Signed Q-format words carried in `i64` with an explicit runtime format
//! (`QFormat { int_bits, frac_bits }`). This is the numeric foundation of
//! the golden datapath model, the baselines and the accelerator
//! simulator; every rounding/saturation behaviour here is exactly what
//! the hardware (and the Pallas kernel) does.

use std::fmt;

/// Rounding mode for float -> fixed and precision-reducing ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Round {
    /// Round to nearest, ties away from zero (`rint`-compatible on our
    /// data; hardware implements it as "+half then truncate").
    Nearest,
    /// Truncate toward negative infinity (drop lsbs).
    Floor,
}

/// A signed fixed-point format `s{int_bits}.{frac_bits}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QFormat {
    pub int_bits: u32,
    pub frac_bits: u32,
}

impl QFormat {
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        QFormat { int_bits, frac_bits }
    }

    /// Total width including sign.
    pub const fn width(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Largest representable word.
    pub const fn max_word(&self) -> i64 {
        (1i64 << (self.width() - 1)) - 1
    }

    /// Smallest representable word.
    pub const fn min_word(&self) -> i64 {
        -(1i64 << (self.width() - 1))
    }

    /// Value of one lsb.
    pub fn lsb(&self) -> f64 {
        (self.frac_bits as f64 * -1.0).exp2()
    }

    /// Quantize a float to a word with saturation.
    pub fn quantize(&self, x: f64, mode: Round) -> i64 {
        let scaled = x * (1i64 << self.frac_bits) as f64;
        let w = match mode {
            Round::Nearest => rint(scaled),
            Round::Floor => scaled.floor() as i64,
        };
        w.clamp(self.min_word(), self.max_word())
    }

    /// Word -> float.
    pub fn dequantize(&self, w: i64) -> f64 {
        w as f64 / (1i64 << self.frac_bits) as f64
    }

    /// True if `w` is representable in this format.
    pub fn contains(&self, w: i64) -> bool {
        (self.min_word()..=self.max_word()).contains(&w)
    }
}

impl fmt::Display for QFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.int_bits == 0 {
            write!(f, "s.{}", self.frac_bits)
        } else {
            write!(f, "s{}.{}", self.int_bits, self.frac_bits)
        }
    }
}

/// Round-to-nearest, ties to even — bit-compatible with `numpy.rint`,
/// which the python oracle uses for every float -> word conversion.
#[inline]
pub fn rint(x: f64) -> i64 {
    x.round_ties_even() as i64
}

/// Fixed-point multiply: both operands and result carry `frac` fractional
/// bits; result rounded to nearest (hardware: `+half >> frac`).
#[inline(always)]
pub fn round_mul(a: i64, b: i64, frac: u32) -> i64 {
    (a * b + (1i64 << (frac - 1))) >> frac
}

/// Fixed-point multiply with floor (truncate) rounding.
#[inline(always)]
pub fn floor_mul(a: i64, b: i64, frac: u32) -> i64 {
    (a * b) >> frac
}

/// Saturating clamp of `w` into `fmt`.
#[inline]
pub fn saturate(w: i64, fmt: QFormat) -> i64 {
    w.clamp(fmt.min_word(), fmt.max_word())
}

/// Absolute error statistics between a fixed-point evaluation and a
/// float reference (the paper's Table II metric).
#[derive(Clone, Debug, Default)]
pub struct ErrorStats {
    pub max_abs: f64,
    pub mean_abs: f64,
    pub rms: f64,
    pub argmax: i64,
    pub count: u64,
}

impl ErrorStats {
    pub fn collect(pairs: impl Iterator<Item = (i64, f64, f64)>) -> ErrorStats {
        let mut s = ErrorStats::default();
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for (x, got, want) in pairs {
            let e = (got - want).abs();
            if e > s.max_abs {
                s.max_abs = e;
                s.argmax = x;
            }
            sum += e;
            sq += e * e;
            s.count += 1;
        }
        if s.count > 0 {
            s.mean_abs = sum / s.count as f64;
            s.rms = (sq / s.count as f64).sqrt();
        }
        s
    }

    /// Max error expressed in output lsbs.
    pub fn max_lsb(&self, out: QFormat) -> f64 {
        self.max_abs / out.lsb()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S3_12: QFormat = QFormat::new(3, 12);
    const S0_15: QFormat = QFormat::new(0, 15);

    #[test]
    fn widths_and_ranges() {
        assert_eq!(S3_12.width(), 16);
        assert_eq!(S3_12.max_word(), 32767);
        assert_eq!(S3_12.min_word(), -32768);
        assert_eq!(S0_15.width(), 16);
        assert_eq!(format!("{S3_12}"), "s3.12");
        assert_eq!(format!("{S0_15}"), "s.15");
    }

    #[test]
    fn quantize_roundtrip_exact_values() {
        for w in [-32768i64, -1, 0, 1, 4096, 32767] {
            let x = S3_12.dequantize(w);
            assert_eq!(S3_12.quantize(x, Round::Nearest), w);
        }
    }

    #[test]
    fn quantize_saturates() {
        assert_eq!(S3_12.quantize(100.0, Round::Nearest), 32767);
        assert_eq!(S3_12.quantize(-100.0, Round::Nearest), -32768);
    }

    #[test]
    fn quantize_floor_vs_nearest() {
        // 0.3 * 4096 = 1228.8
        assert_eq!(S3_12.quantize(0.3, Round::Nearest), 1229);
        assert_eq!(S3_12.quantize(0.3, Round::Floor), 1228);
        // negative: floor goes down
        assert_eq!(S3_12.quantize(-0.3, Round::Floor), -1229);
    }

    #[test]
    fn round_mul_matches_definition() {
        // 0.5 * 0.5 = 0.25 at frac=12
        let half = 1 << 11;
        assert_eq!(round_mul(half, half, 12), 1 << 10);
        // rounding: (3 * 3) >> 3 with frac 3: 9/8 = 1.125 -> 1
        assert_eq!(round_mul(3, 3, 3), 1);
        assert_eq!(floor_mul(3, 3, 3), 1);
        // 5*5/8 = 3.125 -> nearest 3; 5*7/8 = 4.375 -> 4; 5*5=25+4>>3=3
        assert_eq!(round_mul(5, 5, 3), 3);
        // 6*6/8 = 4.5 -> +half rounds up to 5, floor gives 4
        assert_eq!(round_mul(6, 6, 3), 5);
        assert_eq!(floor_mul(6, 6, 3), 4);
    }

    #[test]
    fn lsb_value() {
        assert!((S0_15.lsb() - 2f64.powi(-15)).abs() < 1e-18);
    }

    #[test]
    fn error_stats() {
        let pairs = vec![(0i64, 0.0, 0.0), (1, 1.0, 1.5), (2, 2.0, 1.9)];
        let s = ErrorStats::collect(pairs.into_iter());
        assert_eq!(s.count, 3);
        assert!((s.max_abs - 0.5).abs() < 1e-12);
        assert_eq!(s.argmax, 1);
        assert!(s.mean_abs > 0.0 && s.rms >= s.mean_abs);
    }
}
