//! Minimal property-based testing framework.
//!
//! The offline crate set has no `proptest`, so this module provides the
//! subset the test-suite needs: composable generators over a
//! deterministic PRNG, a `forall` runner with failure-case shrinking, and
//! a `prop!` macro for terse invariant checks.
//!
//! Shrinking is value-based: a failing case is re-generated from
//! candidate simplifications (halving integers toward zero, shortening
//! vectors) until a local minimum is reached.

use crate::util::rng::Rng;

/// A generator of values of type `T`, plus a shrinking strategy.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        gen: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen { gen: Box::new(gen), shrink: Box::new(shrink) }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (loses shrinking granularity of the target
    /// domain; shrinks of the source are mapped through).
    pub fn map<U: Clone + 'static>(
        self,
        f: impl Fn(T) -> U + Clone + 'static,
    ) -> Gen<U>
    where
        T: 'static,
    {
        // Keep a paired source value via regeneration: simplest sound
        // approach is to not shrink mapped generators.
        let g = self.gen;
        let f2 = f.clone();
        Gen::new(move |r| f2(g(r)), |_| vec![])
    }
}

/// Integer generator in `[lo, hi]`, shrinking toward `0` (or `lo`).
pub fn int(lo: i64, hi: i64) -> Gen<i64> {
    assert!(lo <= hi);
    let anchor = if lo <= 0 && hi >= 0 { 0 } else { lo };
    Gen::new(
        move |r| r.range_i64(lo, hi + 1),
        move |&v| {
            let mut out = Vec::new();
            if v != anchor {
                out.push(anchor);
                let mid = anchor + (v - anchor) / 2;
                if mid != v && mid != anchor {
                    out.push(mid);
                }
                if (v - anchor).abs() > 1 {
                    out.push(v - (v - anchor).signum());
                }
            }
            out
        },
    )
}

/// Vec generator with length in `[0, max_len]`, shrinking by halving
/// length and shrinking elements.
pub fn vec_of(elem: Gen<i64>, max_len: usize) -> Gen<Vec<i64>> {
    let elem = std::rc::Rc::new(elem);
    let e1 = elem.clone();
    Gen::new(
        move |r| {
            let n = r.below(max_len as u64 + 1) as usize;
            (0..n).map(|_| e1.sample(r)).collect()
        },
        move |v: &Vec<i64>| {
            let mut out = Vec::new();
            if !v.is_empty() {
                out.push(v[..v.len() / 2].to_vec());
                out.push(v[1..].to_vec());
                // shrink one element
                for (i, x) in v.iter().enumerate().take(4) {
                    for s in elem.shrinks(x) {
                        let mut w = v.clone();
                        w[i] = s;
                        out.push(w);
                    }
                }
            }
            out
        },
    )
}

/// Result of a property run.
#[derive(Debug)]
pub enum PropResult<T> {
    Pass { cases: usize },
    Fail { minimal: T, shrinks: usize, message: String },
}

/// Run `check` against `cases` generated values; on failure, shrink.
pub fn forall<T: Clone + std::fmt::Debug + 'static>(
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    check: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    let mut rng = Rng::new(seed);
    for _ in 0..cases {
        let v = gen.sample(&mut rng);
        if let Err(msg) = check(&v) {
            // Shrink to a local minimum (bounded effort).
            let mut cur = v;
            let mut cur_msg = msg;
            let mut shrinks = 0;
            'outer: loop {
                for cand in gen.shrinks(&cur) {
                    if let Err(m) = check(&cand) {
                        cur = cand;
                        cur_msg = m;
                        shrinks += 1;
                        if shrinks < 1000 {
                            continue 'outer;
                        }
                    }
                }
                break;
            }
            return PropResult::Fail { minimal: cur, shrinks, message: cur_msg };
        }
    }
    PropResult::Pass { cases }
}

/// Assert a property holds; panics with the minimal counterexample.
pub fn assert_prop<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    check: impl Fn(&T) -> Result<(), String>,
) {
    match forall(seed, cases, gen, check) {
        PropResult::Pass { .. } => {}
        PropResult::Fail { minimal, shrinks, message } => panic!(
            "property '{name}' failed after {shrinks} shrinks\n  \
             counterexample: {minimal:?}\n  {message}"
        ),
    }
}

/// Terse property check: `prop!(name, gen, |v| condition, cases)`.
#[macro_export]
macro_rules! prop {
    ($name:expr, $gen:expr, $check:expr) => {
        $crate::proptest::assert_prop($name, 0xC0FFEE, 256, &$gen, $check)
    };
    ($name:expr, $gen:expr, $check:expr, $cases:expr) => {
        $crate::proptest::assert_prop($name, 0xC0FFEE, $cases, &$gen, $check)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let g = int(-100, 100);
        match forall(1, 500, &g, |&v| {
            if v >= -100 && v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        }) {
            PropResult::Pass { cases } => assert_eq!(cases, 500),
            f => panic!("{f:?}"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        // Fails for v >= 50; minimal counterexample should shrink to 50.
        let g = int(0, 1000);
        match forall(2, 500, &g, |&v| {
            if v < 50 {
                Ok(())
            } else {
                Err(format!("{v} >= 50"))
            }
        }) {
            PropResult::Fail { minimal, .. } => {
                assert_eq!(minimal, 50, "shrinking should find the boundary")
            }
            _ => panic!("property should fail"),
        }
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let g = vec_of(int(0, 10), 64);
        match forall(3, 500, &g, |v: &Vec<i64>| {
            if v.len() < 3 {
                Ok(())
            } else {
                Err("len >= 3".into())
            }
        }) {
            PropResult::Fail { minimal, .. } => assert_eq!(minimal.len(), 3),
            _ => panic!("should fail"),
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = int(0, 1 << 30);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        for _ in 0..50 {
            assert_eq!(g.sample(&mut r1), g.sample(&mut r2));
        }
    }
}
