//! Criterion-style micro/throughput benchmark harness.
//!
//! The offline crate set has no `criterion`; `cargo bench` targets use
//! this instead (`harness = false`). It provides warmup, calibrated
//! iteration counts, robust statistics (mean/p50/p99), throughput
//! reporting, and a `black_box` to defeat constant folding.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-exported opaque-value barrier.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Nanoseconds per iteration.
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub std_ns: f64,
    pub iters: u64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems: Option<u64>,
}

impl Measurement {
    pub fn throughput(&self) -> Option<f64> {
        self.elems.map(|e| e as f64 / (self.mean_ns * 1e-9))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12} /iter  p50 {:>10}  p99 {:>10}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters
        );
        if let Some(tp) = self.throughput() {
            s.push_str(&format!("  {:.3e} elem/s", tp));
        }
        s
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with shared config.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_samples: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    /// Time `f`, which performs one logical iteration per call.
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Measurement {
        self.run_with_elems(name, None, &mut f)
    }

    /// Time `f` and report elements/second based on `elems` per iter.
    pub fn run_elems<R>(
        &mut self,
        name: &str,
        elems: u64,
        mut f: impl FnMut() -> R,
    ) -> &Measurement {
        self.run_with_elems(name, Some(elems), &mut f)
    }

    fn run_with_elems<R>(
        &mut self,
        name: &str,
        elems: Option<u64>,
        f: &mut dyn FnMut() -> R,
    ) -> &Measurement {
        // Warmup + estimate per-iter cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 3 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;

        // Sample in batches sized for ~1ms per sample.
        let batch = ((1e6 / per_iter.max(1.0)).ceil() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure || samples.len() < self.min_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / n as f64;
        let m = Measurement {
            name: name.to_string(),
            mean_ns: mean,
            p50_ns: samples[n / 2],
            p99_ns: samples[((n * 99) / 100).min(n - 1)],
            std_ns: var.sqrt(),
            iters: n as u64 * batch,
            elems,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Find a measurement by name.
    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.results.iter().find(|m| m.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::quick();
        let m = b.run("noop-ish", || black_box(1u64 + black_box(2))).clone();
        assert!(m.mean_ns > 0.0);
        assert!(m.p50_ns <= m.p99_ns * 1.0001);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::quick();
        let v: Vec<u64> = (0..1024).collect();
        let m = b
            .run_elems("sum-1k", 1024, || v.iter().sum::<u64>())
            .clone();
        let tp = m.throughput().unwrap();
        assert!(tp > 1e6, "sum throughput {tp}");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
