//! # tanh-vf — Scalable VLSI implementation of tanh via velocity factors
//!
//! Production-grade reproduction of *"A Novel Method for Scalable VLSI
//! Implementation of Hyperbolic Tangent Function"* (M. Chandra, 2020):
//! a bit-accurate model of the paper's velocity-factor tanh datapath,
//! the VLSI substrate it was evaluated on (standard-cell library model,
//! structural netlist, synthesis/PPA estimation, cycle-accurate RTL
//! simulation, Verilog emission), the published baselines it compares
//! against, and a rust serving coordinator that executes the
//! JAX/Pallas-authored model artifacts through PJRT.
//!
//! Layer map (see `DESIGN.md`):
//! * L4 ([`server`]): HTTP/1.1 activation service over the precision
//!   router — JSON eval/batch endpoints, model listing, health,
//!   Prometheus metrics, connection + queue backpressure, and a
//!   multi-node cluster tier: consistent-hash model routing across
//!   health-checked peers ([`server::cluster`]), gossip membership
//!   with `--join` seeds ([`server::gossip`]), pooled proxy
//!   connections ([`server::pool`]), and replicated routes with read
//!   fan-out (`--replicas`).
//! * L3 (this crate): coordinator, VLSI substrate, baselines, analysis.
//! * L2 (`python/compile/model.py`): JAX model graphs, AOT-lowered to
//!   `artifacts/*.hlo.txt`.
//! * L1 (`python/compile/kernels/`): Pallas velocity-factor kernel.
//!
//! The datapath semantics are specified once (`python/compile/kernels/
//! config.py`) and implemented bit-identically by the Pallas kernel, the
//! [`tanh::golden`] model, the [`rtl`] simulator and the emitted Verilog.

pub mod accel;
pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod exec;
pub mod fixed;
pub mod gates;
pub mod proptest;
pub mod rtl;
pub mod runtime;
pub mod server;
pub mod synth;
pub mod tanh;
pub mod util;
pub mod verilog;
