//! Word-level structural netlist.
//!
//! Nodes are hardware blocks at the granularity a datapath RTL author
//! writes them (multiplier, complementer, ROM, mux...). Each block knows
//!
//! * its **function** (`eval` — bit-true i64 semantics, identical to the
//!   golden model),
//! * its **structure** (NAND2-equivalent logic levels and gate count,
//!   from standard fast-implementation formulas: Dadda trees with
//!   truncated low partial products, Kogge-Stone-class adders, synthesized
//!   ROM planes),
//! * its **output width** (for pipeline-register costing).

use std::collections::BTreeMap;

/// Index of a node in the netlist.
pub type NodeId = usize;

/// Word-level hardware blocks.
#[derive(Clone, Debug)]
pub enum BlockKind {
    /// Primary input (signed word).
    Input { name: String },
    /// |x| of a signed input.
    SignAbs,
    /// Sign bit of a signed input (wire).
    SignBit,
    /// `in >= k` (unsigned compare against constant), 1-bit out.
    CmpGeConst { k: i64 },
    /// ROM lookup addressed by gathered input bits:
    /// `out = table[ concat_j in[positions[j]] ]`.
    RomGather { positions: Vec<u32>, table: Vec<i64> },
    /// Fixed-point multiply with round-to-nearest at `frac` bits:
    /// `out = (a*b + 2^(frac-1)) >> frac`. Truncated-array hardware.
    MulRound { frac: u32 },
    /// `out = k - in` (two's-complement subtract from constant).
    SubFromConst { k: i64 },
    /// `out = k - 1 - in` implemented as bitwise NOT (one's complement);
    /// valid when in < k and k is a power of two.
    OnesFromConst { k: i64 },
    /// `out = in + k` where the addition is pure bit concatenation
    /// (k = 2^L, in < 2^L): zero hardware.
    ConcatConst { k: i64 },
    /// Arithmetic right shift by a constant (wire).
    ShiftRight { k: u32 },
    /// NR seed: `out = c - 2*in` (one subtractor; c has two set bits).
    SeedSub { c: i64 },
    /// Round-shift: `out = (in + 2^(k-1)) >> k` (one short adder).
    RoundShift { k: u32 },
    /// `out = min(max(in, 0), max)`.
    ClampMax { max: i64 },
    /// Conditional negate: inputs (value, sign) -> `sign ? -v : v`.
    NegIf,
    /// Saturation select: inputs (value, sel) -> `sel ? k : value`.
    MuxConst { k: i64 },
    /// Reference float divider (`nr_stages = 0` analysis configs only;
    /// inputs (num, den)): not a synthesizable block — costed as a
    /// placeholder so analysis configs can still be simulated.
    FloatDivRef { out_frac: u32 },
}

/// One netlist node.
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: BlockKind,
    pub inputs: Vec<NodeId>,
    /// Output width in bits (for pipeline register costing).
    pub width: u32,
}

/// A feed-forward word-level netlist (DAG; nodes in topological order).
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub nodes: Vec<Node>,
    pub outputs: Vec<NodeId>,
    pub names: BTreeMap<String, NodeId>,
}

impl Netlist {
    pub fn add(&mut self, kind: BlockKind, inputs: Vec<NodeId>, width: u32) -> NodeId {
        for &i in &inputs {
            assert!(i < self.nodes.len(), "netlist must stay topological");
        }
        self.nodes.push(Node { kind, inputs, width });
        self.nodes.len() - 1
    }

    pub fn input(&mut self, name: &str, width: u32) -> NodeId {
        let id = self.add(
            BlockKind::Input { name: name.to_string() },
            vec![],
            width,
        );
        self.names.insert(name.to_string(), id);
        id
    }

    pub fn mark_output(&mut self, id: NodeId) {
        self.outputs.push(id);
    }

    /// Evaluate the whole netlist for one set of input values.
    pub fn eval(&self, inputs: &BTreeMap<String, i64>) -> Vec<i64> {
        let mut vals = vec![0i64; self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            vals[id] = eval_node(node, &node_args(&vals, node), inputs);
        }
        self.outputs.iter().map(|&o| vals[o]).collect()
    }

    /// Evaluate one node given the values of its predecessors (for the
    /// cycle-accurate RTL simulator, which computes stage by stage).
    pub fn eval_node_at(
        &self,
        id: NodeId,
        vals: &[i64],
        inputs: &BTreeMap<String, i64>,
    ) -> i64 {
        let node = &self.nodes[id];
        eval_node(node, &node_args(vals, node), inputs)
    }

    /// Evaluate returning every node's value (for the RTL simulator).
    pub fn eval_all(&self, inputs: &BTreeMap<String, i64>) -> Vec<i64> {
        let mut vals = vec![0i64; self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            vals[id] = eval_node(node, &node_args(&vals, node), inputs);
        }
        vals
    }

    /// Total NAND2-equivalent gates.
    pub fn total_gates(&self) -> f64 {
        self.nodes.iter().map(gates_of).sum()
    }

    /// Structural logic levels of each node (levels of the block itself).
    pub fn node_levels(&self) -> Vec<f64> {
        self.nodes.iter().map(levels_of).collect()
    }

    /// Arrival levels: longest path (in levels) from any input to each
    /// node's output. `arrival[id] = levels(id) + max(arrival[preds])`.
    pub fn arrival_levels(&self) -> Vec<f64> {
        let mut arr = vec![0f64; self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            let base = node
                .inputs
                .iter()
                .map(|&i| arr[i])
                .fold(0.0f64, f64::max);
            arr[id] = base + levels_of(node);
        }
        arr
    }

    /// Critical-path depth in levels.
    pub fn critical_levels(&self) -> f64 {
        self.arrival_levels().into_iter().fold(0.0, f64::max)
    }

    /// Verify the DAG is acyclic + topologically ordered (by construction
    /// `add` enforces it; this re-checks after any manual surgery).
    pub fn check(&self) -> Result<(), String> {
        for (id, node) in self.nodes.iter().enumerate() {
            for &i in &node.inputs {
                if i >= id {
                    return Err(format!("node {id} reads later node {i}"));
                }
            }
        }
        for &o in &self.outputs {
            if o >= self.nodes.len() {
                return Err(format!("dangling output {o}"));
            }
        }
        Ok(())
    }
}

fn node_args(vals: &[i64], node: &Node) -> Vec<i64> {
    node.inputs.iter().map(|&i| vals[i]).collect()
}

/// Bit-true block semantics.
fn eval_node(node: &Node, args: &[i64], inputs: &BTreeMap<String, i64>) -> i64 {
    match &node.kind {
        BlockKind::Input { name } => *inputs
            .get(name)
            .unwrap_or_else(|| panic!("missing input '{name}'")),
        BlockKind::SignAbs => args[0].unsigned_abs() as i64,
        BlockKind::SignBit => (args[0] < 0) as i64,
        BlockKind::CmpGeConst { k } => (args[0] >= *k) as i64,
        BlockKind::RomGather { positions, table } => {
            let mut addr = 0usize;
            for (j, &p) in positions.iter().enumerate() {
                addr |= (((args[0] >> p) & 1) as usize) << j;
            }
            table[addr]
        }
        BlockKind::MulRound { frac } => {
            (args[0] * args[1] + (1i64 << (frac - 1))) >> frac
        }
        BlockKind::SubFromConst { k } => k - args[0],
        BlockKind::OnesFromConst { k } => (k - 1) - args[0],
        BlockKind::ConcatConst { k } => k + args[0],
        BlockKind::ShiftRight { k } => args[0] >> k,
        BlockKind::SeedSub { c } => c - (args[0] << 1),
        BlockKind::RoundShift { k } => (args[0] + (1i64 << (k - 1))) >> k,
        BlockKind::ClampMax { max } => args[0].clamp(0, *max),
        BlockKind::NegIf => {
            if args[1] != 0 {
                -args[0]
            } else {
                args[0]
            }
        }
        BlockKind::MuxConst { k } => {
            if args[1] != 0 {
                *k
            } else {
                args[0]
            }
        }
        BlockKind::FloatDivRef { out_frac } => crate::fixed::rint(
            args[0] as f64 / args[1] as f64 * (1i64 << out_frac) as f64,
        ),
    }
}

/// NAND2-equivalent logic levels of a block (fast-implementation
/// formulas; see module docs).
pub fn levels_of(node: &Node) -> f64 {
    let w = node.width as f64;
    match &node.kind {
        BlockKind::Input { .. } | BlockKind::SignBit => 0.0,
        // Mux + conditional increment, carry-lookahead class.
        BlockKind::SignAbs | BlockKind::NegIf => w.log2().ceil() + 3.0,
        BlockKind::CmpGeConst { .. } => w.log2().ceil() + 2.0,
        // Address decode + OR plane.
        BlockKind::RomGather { table, .. } => {
            (table.len() as f64).log2().ceil() + 3.0
        }
        // Dadda tree (truncated) + final fast CPA, as mapped by synthesis
        // onto compound cells (4:2 compressors, carry-save absorbed into
        // the CPA): pp 1 + ~0.8·log1.5(w) compressor levels +
        // ~0.8·log2(2w) CPA levels. Calibrated so a 17x17 multiplier maps
        // to ~12 levels — typical for 40nm-class commercial mapping.
        BlockKind::MulRound { .. } => {
            1.0 + (0.8 * w.ln() / 1.5f64.ln()).ceil()
                + (0.8 * (2.0 * w).log2()).ceil()
        }
        // Constant subtract: synthesis absorbs `k - x` (k a power of two)
        // as a complement + extra partial-product row in the adjacent
        // multiplier / CPA, leaving ~2 levels of visible logic.
        BlockKind::SubFromConst { .. } => 2.0,
        BlockKind::SeedSub { .. } => w.log2().ceil() + 2.0,
        BlockKind::OnesFromConst { .. } => 1.0, // inverters only
        BlockKind::ConcatConst { .. } | BlockKind::ShiftRight { .. } => 0.0,
        BlockKind::RoundShift { .. } => w.log2().ceil() + 2.0,
        BlockKind::ClampMax { .. } => w.log2().ceil() + 2.0,
        BlockKind::MuxConst { .. } => 1.0,
        BlockKind::FloatDivRef { .. } => 60.0, // placeholder, non-synth
    }
}

/// NAND2-equivalent gate count of a block.
pub fn gates_of(node: &Node) -> f64 {
    let w = node.width as f64;
    match &node.kind {
        BlockKind::Input { .. }
        | BlockKind::SignBit
        | BlockKind::ConcatConst { .. }
        | BlockKind::ShiftRight { .. } => 0.0,
        BlockKind::SignAbs | BlockKind::NegIf => 3.0 * w,
        BlockKind::CmpGeConst { .. } => 1.5 * w,
        // Synthesized ROM plane ~ 0.25 gate per stored bit + decoder.
        BlockKind::RomGather { positions, table } => {
            0.25 * (table.len() as f64) * w + 2.0 * positions.len() as f64
        }
        // Truncated multiplier: ~2.2 gates per partial-product cell on
        // the kept (upper) half + CPA.
        BlockKind::MulRound { .. } => 2.2 * w * w + 2.5 * w,
        BlockKind::SubFromConst { .. } | BlockKind::SeedSub { .. } => 2.5 * w,
        BlockKind::OnesFromConst { .. } => 0.5 * w,
        BlockKind::RoundShift { .. } => 2.0 * w,
        BlockKind::ClampMax { .. } => 2.0 * w,
        BlockKind::MuxConst { .. } => 1.5 * w,
        BlockKind::FloatDivRef { .. } => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_node(kind: BlockKind, width: u32, args: &[i64]) -> i64 {
        let node = Node { kind, inputs: vec![], width };
        eval_node(&node, args, &BTreeMap::new())
    }

    #[test]
    fn block_semantics() {
        assert_eq!(one_node(BlockKind::SignAbs, 16, &[-5]), 5);
        assert_eq!(one_node(BlockKind::SignBit, 1, &[-5]), 1);
        assert_eq!(one_node(BlockKind::CmpGeConst { k: 7 }, 1, &[7]), 1);
        assert_eq!(one_node(BlockKind::CmpGeConst { k: 7 }, 1, &[6]), 0);
        assert_eq!(
            one_node(BlockKind::MulRound { frac: 4 }, 8, &[24, 24]),
            36
        );
        assert_eq!(one_node(BlockKind::SubFromConst { k: 16 }, 5, &[5]), 11);
        assert_eq!(one_node(BlockKind::OnesFromConst { k: 16 }, 5, &[5]), 10);
        assert_eq!(one_node(BlockKind::ConcatConst { k: 16 }, 5, &[5]), 21);
        assert_eq!(one_node(BlockKind::ShiftRight { k: 2 }, 5, &[21]), 5);
        assert_eq!(one_node(BlockKind::SeedSub { c: 100 }, 8, &[30]), 40);
        assert_eq!(one_node(BlockKind::RoundShift { k: 3 }, 8, &[20]), 3);
        assert_eq!(one_node(BlockKind::ClampMax { max: 7 }, 4, &[9]), 7);
        assert_eq!(one_node(BlockKind::ClampMax { max: 7 }, 4, &[-2]), 0);
        assert_eq!(one_node(BlockKind::NegIf, 8, &[5, 1]), -5);
        assert_eq!(one_node(BlockKind::NegIf, 8, &[5, 0]), 5);
        assert_eq!(one_node(BlockKind::MuxConst { k: 99 }, 8, &[5, 1]), 99);
    }

    #[test]
    fn rom_gather_addresses_scattered_bits() {
        let kind = BlockKind::RomGather {
            positions: vec![0, 3],
            table: vec![10, 11, 12, 13],
        };
        // n = 0b1001 -> addr = bit0 | bit3<<1 = 1 | 2 = 3.
        assert_eq!(one_node(kind, 8, &[0b1001]), 13);
    }

    #[test]
    fn netlist_eval_chain() {
        let mut n = Netlist::default();
        let x = n.input("x", 8);
        let a = n.add(BlockKind::SignAbs, vec![x], 8);
        let m = n.add(BlockKind::MulRound { frac: 2 }, vec![a, a], 10);
        n.mark_output(m);
        let mut ins = BTreeMap::new();
        ins.insert("x".to_string(), -6i64);
        assert_eq!(n.eval(&ins), vec![9]); // 6*6/4
        n.check().unwrap();
    }

    #[test]
    fn arrival_accumulates() {
        let mut n = Netlist::default();
        let x = n.input("x", 8);
        let a = n.add(BlockKind::SignAbs, vec![x], 8);
        let m = n.add(BlockKind::MulRound { frac: 2 }, vec![a, a], 10);
        n.mark_output(m);
        let arr = n.arrival_levels();
        assert_eq!(arr[0], 0.0);
        assert!(arr[1] > 0.0);
        assert!(arr[2] > arr[1]);
        assert_eq!(n.critical_levels(), arr[2]);
    }

    #[test]
    fn topology_enforced() {
        let mut n = Netlist::default();
        let x = n.input("x", 8);
        n.add(BlockKind::SignAbs, vec![x], 8);
        n.nodes[0].inputs = vec![1]; // manual corruption
        assert!(n.check().is_err());
    }

    #[test]
    fn gate_counts_positive_for_logic() {
        let node = Node {
            kind: BlockKind::MulRound { frac: 16 },
            inputs: vec![],
            width: 17,
        };
        assert!(gates_of(&node) > 500.0);
        assert!(levels_of(&node) > 10.0);
    }
}
