//! PPA roll-up: static timing + area + leakage for a pipelined datapath
//! against a cell library — the engine behind Tables III and IV.

use crate::gates::{CellClass, CellLibrary};
use crate::tanh::TanhConfig;

use super::datapath::build_tanh_datapath;
use super::netlist::Netlist;
use super::pipeline::{assign_stages, PipelineAssignment};

/// One synthesized flavour (a row of Table III/IV).
#[derive(Clone, Debug)]
pub struct PpaReport {
    pub cells: CellClass,
    pub latency_clocks: u32,
    pub area_um2: f64,
    pub leakage_uw: f64,
    pub fmax_mhz: f64,
    pub logic_levels: u32,
    pub reg_bits: u64,
    pub gate_count: f64,
}

impl PpaReport {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.cells.name().to_string(),
            format!("{}", self.latency_clocks),
            format!("{:.2}", self.area_um2),
            format!("{:.2}", self.leakage_uw),
            format!("{:.0}", self.fmax_mhz),
            format!("{}", self.logic_levels),
        ]
    }
}

/// Synthesize (model) one flavour of the tanh unit.
pub fn ppa_for(cfg: &TanhConfig, class: CellClass, stages: u32) -> PpaReport {
    let net = build_tanh_datapath(cfg);
    ppa_for_netlist(&net, class, stages)
}

/// PPA for an arbitrary netlist (used by ablations over other datapaths).
pub fn ppa_for_netlist(net: &Netlist, class: CellClass, stages: u32) -> PpaReport {
    let lib = CellLibrary::by_class(class);
    let pipe: PipelineAssignment = assign_stages(net, stages);

    // Technology mapping: richer cells shorten the path for LVT runs.
    let levels = pipe.worst_stage_levels() * lib.mapping_depth_factor;

    // Static timing: per-level delay shrinks under sizing pressure.
    let per_level = lib.gate_delay_ps * lib.sizing_speedup(levels);
    let period_ps = levels * per_level + lib.reg_overhead_ps;
    let fmax_mhz = 1e6 / period_ps;

    // Area: logic (sized) + pipeline registers.
    let sizing = lib.sizing_area_factor(levels);
    let gate_count = net.total_gates();
    let logic_area = gate_count * lib.gate_area_um2 * sizing;
    let reg_area = pipe.reg_bits as f64 * lib.reg_area_um2;
    let area_um2 = logic_area + reg_area;

    // Leakage scales with sized gate count + registers.
    let leakage_nw = gate_count * lib.gate_leak_nw * sizing
        + pipe.reg_bits as f64 * lib.reg_leak_nw;

    PpaReport {
        cells: class,
        latency_clocks: stages,
        area_um2,
        leakage_uw: leakage_nw / 1000.0,
        fmax_mhz,
        logic_levels: levels.round() as u32,
        reg_bits: pipe.reg_bits,
        gate_count,
    }
}

/// The paper's sweep: {SVT, LVT} x {1, 2, 7} stages.
pub fn table_rows(cfg: &TanhConfig) -> Vec<PpaReport> {
    let mut rows = Vec::new();
    for stages in [1u32, 2, 7] {
        for class in [CellClass::Svt, CellClass::Lvt] {
            rows.push(ppa_for(cfg, class, stages));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::TanhConfig;

    fn report(class: CellClass, stages: u32) -> PpaReport {
        ppa_for(&TanhConfig::s3_12(), class, stages)
    }

    #[test]
    fn calibration_1stage_svt_16bit() {
        // Table III row 1: 3748 µm², 4.2 µW, 188 MHz, 135 levels.
        // Modelled substrate: same order of magnitude (±40%), see
        // DESIGN.md §6 for the calibration stance.
        let r = report(CellClass::Svt, 1);
        assert!((2200.0..5300.0).contains(&r.area_um2), "area {}", r.area_um2);
        assert!((2.0..8.0).contains(&r.leakage_uw), "leak {}", r.leakage_uw);
        assert!((110.0..260.0).contains(&r.fmax_mhz), "fmax {}", r.fmax_mhz);
        assert!((90..200).contains(&r.logic_levels), "lvl {}", r.logic_levels);
    }

    #[test]
    fn shape_lvt_faster_same_depth() {
        for stages in [1u32, 2, 7] {
            let svt = report(CellClass::Svt, stages);
            let lvt = report(CellClass::Lvt, stages);
            assert!(lvt.fmax_mhz > svt.fmax_mhz);
            assert!(lvt.leakage_uw > 20.0 * svt.leakage_uw);
            assert!(lvt.logic_levels <= svt.logic_levels);
        }
    }

    #[test]
    fn shape_deeper_pipeline_scales_fmax() {
        let f1 = report(CellClass::Svt, 1).fmax_mhz;
        let f2 = report(CellClass::Svt, 2).fmax_mhz;
        let f7 = report(CellClass::Svt, 7).fmax_mhz;
        assert!(f2 > 1.2 * f1);
        // Paper: 188 -> 1176 MHz (6.25x). Accept 3.5x..9x.
        let ratio = f7 / f1;
        assert!((3.5..9.0).contains(&ratio), "1->7 ratio {ratio}");
    }

    #[test]
    fn shape_area_roughly_flat_with_depth() {
        let a1 = report(CellClass::Svt, 1).area_um2;
        let a7 = report(CellClass::Svt, 7).area_um2;
        let growth = a7 / a1;
        assert!((0.9..1.45).contains(&growth), "area growth {growth}");
    }

    #[test]
    fn shape_8bit_much_smaller() {
        let a16 = report(CellClass::Svt, 1).area_um2;
        let a8 = ppa_for(&TanhConfig::s3_5(), CellClass::Svt, 1).area_um2;
        // Paper: 3748 vs 764 µm² (4.9x). Accept 3x..7x.
        let ratio = a16 / a8;
        assert!((2.5..7.0).contains(&ratio), "16/8 area ratio {ratio}");
    }

    #[test]
    fn table_rows_complete() {
        let rows = table_rows(&TanhConfig::s3_12());
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.fmax_mhz > 50.0 && r.area_um2 > 100.0);
        }
    }
}
