//! Pipeline stage assignment (retiming model).
//!
//! The paper evaluates 1-, 2- and 7-stage pipelined flavours of the same
//! RTL. We model the synthesis retiming step: blocks are assigned to
//! stages by cutting the DAG at accumulated-depth thresholds, with the
//! topological constraint `stage(node) >= stage(pred)`. Cuts happen at
//! *block* granularity — a multiplier cannot be split — which is exactly
//! why the paper's 2-stage flavour reports 95 levels rather than 135/2,
//! and the 7-stage one 25 rather than 135/7.

use super::netlist::{levels_of, Netlist};

/// A stage assignment for a netlist.
#[derive(Clone, Debug)]
pub struct PipelineAssignment {
    pub stages: u32,
    /// Stage index of each node.
    pub stage_of: Vec<u32>,
    /// Per-stage critical path, in block levels.
    pub stage_levels: Vec<f64>,
    /// Total pipeline-register bits inserted at cut boundaries
    /// (including the output register; excluding the input register).
    pub reg_bits: u64,
}

impl PipelineAssignment {
    /// Worst per-stage logic depth (the paper's "Logic Levels" column).
    pub fn worst_stage_levels(&self) -> f64 {
        self.stage_levels.iter().copied().fold(0.0, f64::max)
    }
}

/// Assign `stages` pipeline stages to `net` by balanced-depth cuts.
pub fn assign_stages(net: &Netlist, stages: u32) -> PipelineAssignment {
    assert!(stages >= 1);
    let arr = net.arrival_levels();
    let total = arr.iter().copied().fold(0.0, f64::max).max(1e-9);
    let budget = total / stages as f64;

    // Initial assignment by midpoint of each block's span, then enforce
    // topological monotonicity.
    let mut stage_of = vec![0u32; net.nodes.len()];
    for (id, node) in net.nodes.iter().enumerate() {
        let mid = arr[id] - levels_of(node) / 2.0;
        let s = ((mid / budget).floor() as i64).clamp(0, stages as i64 - 1);
        let pred_max = node
            .inputs
            .iter()
            .map(|&i| stage_of[i])
            .max()
            .unwrap_or(0);
        stage_of[id] = (s as u32).max(pred_max);
    }

    // Per-stage critical path: longest chain of blocks within a stage.
    let mut intra = vec![0f64; net.nodes.len()];
    let mut stage_levels = vec![0f64; stages as usize];
    for (id, node) in net.nodes.iter().enumerate() {
        let base = node
            .inputs
            .iter()
            .filter(|&&i| stage_of[i] == stage_of[id])
            .map(|&i| intra[i])
            .fold(0.0f64, f64::max);
        intra[id] = base + levels_of(node);
        let s = stage_of[id] as usize;
        stage_levels[s] = stage_levels[s].max(intra[id]);
    }

    // Register bits: retiming shares pipeline registers across consumers
    // — a node crossing k stage boundaries (to its furthest consumer)
    // contributes k registered copies of its width. Plus the output reg.
    let mut furthest = vec![0u32; net.nodes.len()];
    for (id, node) in net.nodes.iter().enumerate() {
        for &i in &node.inputs {
            furthest[i] = furthest[i].max(stage_of[id]);
        }
    }
    let mut reg_bits = 0u64;
    for (id, node) in net.nodes.iter().enumerate() {
        let hops = furthest[id].saturating_sub(stage_of[id]) as u64;
        reg_bits += hops * node.width as u64;
    }
    for &o in &net.outputs {
        reg_bits += net.nodes[o].width as u64; // output register
    }

    PipelineAssignment { stages, stage_of, stage_levels, reg_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::datapath::build_tanh_datapath;
    use crate::tanh::TanhConfig;

    fn net16() -> Netlist {
        build_tanh_datapath(&TanhConfig::s3_12())
    }

    #[test]
    fn single_stage_is_whole_path() {
        let net = net16();
        let p = assign_stages(&net, 1);
        assert!(p.stage_of.iter().all(|&s| s == 0));
        assert!((p.worst_stage_levels() - net.critical_levels()).abs() < 1e-9);
    }

    #[test]
    fn stages_monotone_along_edges() {
        let net = net16();
        for stages in [2u32, 3, 7] {
            let p = assign_stages(&net, stages);
            for (id, node) in net.nodes.iter().enumerate() {
                for &i in &node.inputs {
                    assert!(p.stage_of[i] <= p.stage_of[id]);
                }
            }
        }
    }

    #[test]
    fn deeper_pipeline_fewer_levels_per_stage() {
        let net = net16();
        let l1 = assign_stages(&net, 1).worst_stage_levels();
        let l2 = assign_stages(&net, 2).worst_stage_levels();
        let l7 = assign_stages(&net, 7).worst_stage_levels();
        assert!(l2 < l1 && l7 < l2, "{l1} {l2} {l7}");
        // Block granularity: 2-stage worst > ideal half (paper: 95 vs 67).
        assert!(l2 > l1 / 2.0);
        assert!(l7 > l1 / 7.0);
    }

    #[test]
    fn register_bits_grow_with_depth() {
        let net = net16();
        let r1 = assign_stages(&net, 1).reg_bits;
        let r7 = assign_stages(&net, 7).reg_bits;
        assert!(r7 > r1, "{r1} vs {r7}");
        // 1-stage still has the output register.
        assert!(r1 >= 16);
    }

    #[test]
    fn all_stages_populated() {
        let net = net16();
        for stages in [2u32, 7] {
            let p = assign_stages(&net, stages);
            for s in 0..stages {
                assert!(
                    p.stage_of.iter().any(|&x| x == s),
                    "stage {s}/{stages} empty"
                );
            }
        }
    }
}
