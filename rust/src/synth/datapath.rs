//! Build the optimized velocity-factor tanh datapath (paper fig. 5) as a
//! structural netlist, bit-identical to the golden model.

use crate::tanh::config::{Subtractor, TanhConfig};
use crate::tanh::lut::lut_tables;

use super::netlist::{BlockKind, Netlist, NodeId};

/// Construct the datapath netlist for `cfg`. The single input is `"x"`
/// (signed s{in_int}.{in_frac} word); the single output is the signed
/// s.{out_frac} tanh word.
pub fn build_tanh_datapath(cfg: &TanhConfig) -> Netlist {
    cfg.validate().expect("invalid config");
    let mut n = Netlist::default();
    let l = cfg.lut_bits;
    let m = cfg.mult_bits;
    let one_l = 1i64 << l;

    let x = n.input("x", cfg.in_width());
    let mag = n.add(BlockKind::SignAbs, vec![x], cfg.mag_bits());
    let sign = n.add(BlockKind::SignBit, vec![x], 1);
    let sat = n.add(
        BlockKind::CmpGeConst { k: cfg.sat_threshold() },
        vec![mag],
        1,
    );

    // Grouped LUT lookups (fig. 5 left) followed by the product chain of
    // §IV.B.3. The chain is kept *sequential* — the same association and
    // rounding order as the cross-layer spec — so the netlist is
    // bit-identical to the golden model and the Pallas kernel. (A
    // balanced tree would shave one multiplier level but changes the
    // intermediate rounding; see DESIGN.md §5.)
    let factors: Vec<NodeId> = cfg
        .group_positions()
        .into_iter()
        .zip(lut_tables(cfg))
        .map(|(positions, table)| {
            n.add(BlockKind::RomGather { positions, table }, vec![mag], l + 1)
        })
        .collect();
    let mut f = factors[0];
    for &e in &factors[1..] {
        f = n.add(BlockKind::MulRound { frac: l }, vec![f, e], l + 1);
    }

    // Output stage: num = 1 - f (subtractor flavour), den = 1 + f (wire).
    let num = match cfg.subtractor {
        Subtractor::Twos => {
            n.add(BlockKind::SubFromConst { k: one_l }, vec![f], l)
        }
        Subtractor::Ones => {
            n.add(BlockKind::OnesFromConst { k: one_l }, vec![f], l)
        }
    };
    let den = n.add(BlockKind::ConcatConst { k: one_l }, vec![f], l + 1);

    let t = if cfg.nr_stages == 0 {
        n.add(
            BlockKind::FloatDivRef { out_frac: cfg.out_frac },
            vec![num, den],
            cfg.out_frac + 1,
        )
    } else {
        // d = (1+f)/2 at M fractional bits (wire: shift).
        let d = n.add(BlockKind::ShiftRight { k: l + 1 - m }, vec![den], m + 1);
        // NR seed and iterations.
        let mut xr = n.add(
            BlockKind::SeedSub { c: cfg.nr_seed_const() },
            vec![d],
            m + 2,
        );
        for _ in 0..cfg.nr_stages {
            let t0 = n.add(BlockKind::MulRound { frac: m }, vec![d, xr], m + 2);
            let sub = n.add(
                BlockKind::SubFromConst { k: 2i64 << m },
                vec![t0],
                m + 2,
            );
            xr = n.add(BlockKind::MulRound { frac: m }, vec![xr, sub], m + 2);
        }
        // tanh = num * recip / 2 rounded into the output format: a single
        // round-shift multiply (no intermediate rounding).
        let shift = l + m + 1 - cfg.out_frac;
        n.add(
            BlockKind::MulRound { frac: shift },
            vec![num, xr],
            cfg.out_frac + 2,
        )
    };

    let clamped = n.add(
        BlockKind::ClampMax { max: cfg.out_max() },
        vec![t],
        cfg.out_frac,
    );
    let sat_sel = n.add(
        BlockKind::MuxConst { k: cfg.out_max() },
        vec![clamped, sat],
        cfg.out_frac,
    );
    let out = n.add(BlockKind::NegIf, vec![sat_sel, sign], cfg.out_width());
    n.mark_output(out);
    n.check().unwrap();
    n
}

/// Evaluate the netlist on one input word (test/simulation helper).
pub fn eval_datapath(net: &Netlist, x: i64) -> i64 {
    let mut ins = std::collections::BTreeMap::new();
    ins.insert("x".to_string(), x);
    net.eval(&ins)[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::golden::tanh_golden_batch;
    use crate::tanh::Subtractor;

    #[test]
    fn netlist_matches_golden_8bit_exhaustive() {
        for sub in [Subtractor::Twos, Subtractor::Ones] {
            for nr in [0u32, 2, 3] {
                let cfg = TanhConfig::s3_5().with_nr(nr).with_subtractor(sub);
                let net = build_tanh_datapath(&cfg);
                let xs: Vec<i64> = (-256..256).collect();
                let want = tanh_golden_batch(&xs, &cfg);
                for (&x, &w) in xs.iter().zip(&want) {
                    assert_eq!(eval_datapath(&net, x), w,
                               "x={x} cfg={}", cfg.describe());
                }
            }
        }
    }

    #[test]
    fn netlist_matches_golden_16bit_sampled() {
        let cfg = TanhConfig::s3_12();
        let net = build_tanh_datapath(&cfg);
        let xs: Vec<i64> = (-32768..32768).step_by(97).collect();
        let want = tanh_golden_batch(&xs, &cfg);
        for (&x, &w) in xs.iter().zip(&want) {
            assert_eq!(eval_datapath(&net, x), w, "x={x}");
        }
    }

    #[test]
    fn structure_multiplier_count() {
        // §IV.B.3: 4-bit grouping for s3.12 -> 4 LUTs, 3 chain multipliers;
        // NR3 adds 6; final recompose adds 1 -> 10 MulRound nodes.
        let net = build_tanh_datapath(&TanhConfig::s3_12());
        let muls = net
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, BlockKind::MulRound { .. }))
            .count();
        assert_eq!(muls, 10);
        let roms = net
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, BlockKind::RomGather { .. }))
            .count();
        assert_eq!(roms, 4);
    }

    #[test]
    fn critical_path_in_paper_band() {
        // Paper Table III: 135 logic levels for the 1-stage 16-bit SVT
        // flavour. The structural model must land in the same band.
        let net = build_tanh_datapath(&TanhConfig::s3_12());
        let levels = net.critical_levels();
        assert!(
            (90.0..200.0).contains(&levels),
            "critical levels {levels} out of the paper's band"
        );
    }

    #[test]
    fn eight_bit_shallower_than_16() {
        let l16 = build_tanh_datapath(&TanhConfig::s3_12()).critical_levels();
        let l8 = build_tanh_datapath(&TanhConfig::s3_5()).critical_levels();
        assert!(l8 < l16);
    }

    #[test]
    fn sequential_product_chain_order() {
        // The chain must associate left-to-right (spec rounding order):
        // each chain multiplier's arrival strictly grows.
        let cfg = TanhConfig::s3_12();
        let net = build_tanh_datapath(&cfg);
        let arr = net.arrival_levels();
        let mul_arr: Vec<f64> = net
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.kind, BlockKind::MulRound { .. }))
            .map(|(i, _)| arr[i])
            .collect();
        // First three MulRounds are the LUT chain.
        assert!(mul_arr[0] < mul_arr[1] && mul_arr[1] < mul_arr[2]);
    }
}
