//! Structural netlist + synthesis model: the substrate that regenerates
//! the paper's PPA tables.
//!
//! * [`netlist`]  — word-level structural blocks (bit-true `eval`,
//!   NAND2-equivalent depth/gate formulas per block).
//! * [`datapath`] — builds the velocity-factor tanh datapath (fig. 5)
//!   from a [`crate::tanh::TanhConfig`].
//! * [`pipeline`] — retiming-style stage assignment for N-stage flavours.
//! * [`ppa`]      — static timing + area/leakage roll-up against a
//!   [`crate::gates::CellLibrary`] -> the Tables III/IV rows.
//!
//! Fidelity stance (DESIGN.md §6): block `eval` is bit-exact with the
//! golden model (tested exhaustively at 8-bit, sampled at 16-bit); the
//! PPA numbers are *modelled*, calibrated once at the 1-stage/SVT/16-bit
//! point, with every other row produced structurally.

pub mod datapath;
pub mod netlist;
pub mod pipeline;
pub mod ppa;

pub use datapath::build_tanh_datapath;
pub use netlist::{BlockKind, Netlist, NodeId};
pub use pipeline::PipelineAssignment;
pub use ppa::{ppa_for, PpaReport};
