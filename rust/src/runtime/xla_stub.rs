//! Offline stand-in for the `xla` (PJRT binding) crate.
//!
//! The offline build has no vendored `xla` crate, so this module mirrors
//! the exact API surface `runtime` uses. Host-side data plumbing
//! ([`Literal`]) is real — construction, reshape and readback work, and
//! the manifest/validation layer stays fully testable — while device
//! entry points ([`PjRtClient::cpu`]) return a descriptive error. The
//! coordinator is built to survive that: a PJRT-backed worker whose
//! backend fails to construct drains its queue with errors instead of
//! stranding requests, so serving stays live on native routes.
//!
//! When a vendored `xla` crate lands, delete this file and restore
//! `use xla;` in `runtime/mod.rs` — no other code changes needed.

use crate::anyhow;
use crate::util::error::Result;

fn unavailable(what: &str) -> crate::util::error::Error {
    anyhow!(
        "{what}: PJRT backend unavailable (built without the vendored \
         `xla` crate; native routes remain fully functional)"
    )
}

/// Element storage for [`Literal`] (stub-public, not part of the real
/// xla API).
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// Host-side tensor literal (functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(anyhow!(
                "reshape: {:?} has {} elements, target {:?} wants {}",
                self.dims,
                self.data.len(),
                dims,
                want
            ));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| anyhow!("literal dtype mismatch"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Stub PJRT client: construction fails with a descriptive error.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module text (held opaquely by the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        // Real file I/O so missing-artifact errors stay accurate.
        std::fs::read_to_string(path)
            .map(|_| HloModuleProto)
            .map_err(|e| anyhow!("{path}: {e}"))
    }
}

/// Computation wrapper (opaque).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by execution (unreachable in the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (unreachable in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(r.to_vec::<f32>().is_err());
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn client_fails_descriptively() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("PJRT backend unavailable"));
    }
}
