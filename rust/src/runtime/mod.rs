//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! them from the rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module makes
//! the resulting HLO text executable: parse `artifacts/manifest.json`,
//! compile each entry once on the PJRT CPU client, validate buffer
//! shapes/dtypes against the manifest before dispatch, and cache the
//! compiled executables for reuse.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::{anyhow, bail};

mod xla_stub;
use xla_stub as xla;

/// Supported element types of artifact I/O.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" | "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Declared shape/dtype of one artifact input or output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("io entry missing name"))?
                .to_string(),
            shape: v
                .get("shape")
                .and_then(Json::as_i64_vec)
                .ok_or_else(|| anyhow!("io entry missing shape"))?
                .into_iter()
                .map(|d| d as usize)
                .collect(),
            dtype: Dtype::parse(
                v.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
            )?,
        })
    }
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct EntryMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, EntryMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json (run `make artifacts`)",
                    dir.display()
                )
            })?;
        let root = json::parse(&text)
            .map_err(|e| anyhow!("parsing manifest.json: {e}"))?;
        let mut entries = BTreeMap::new();
        let obj = root
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest has no entries"))?;
        for (name, e) in obj {
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("entry {name} missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("entry {name} missing outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let default_file = format!("{name}.hlo.txt");
            entries.insert(
                name.clone(),
                EntryMeta {
                    name: name.clone(),
                    file: e
                        .get("file")
                        .and_then(Json::as_str)
                        .unwrap_or(&default_file)
                        .to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }
}

/// A tensor travelling into/out of an executable.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32(_) => Dtype::F32,
            Tensor::I32(_) => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32(v) => Some(v),
            _ => None,
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(v) => xla::Literal::vec1(v),
            Tensor::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal, dtype: Dtype) -> Result<Tensor> {
        Ok(match dtype {
            Dtype::F32 => Tensor::F32(lit.to_vec::<f32>()?),
            Dtype::I32 => Tensor::I32(lit.to_vec::<i32>()?),
        })
    }
}

/// PJRT-backed executor with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<BTreeMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { client, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn entry(&self, name: &str) -> Result<EntryMeta> {
        self.manifest
            .entries
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no artifact entry '{name}'"))
    }

    /// Compile (or fetch from cache) an entry point.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        let meta = self.entry(name)?;
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry point. Inputs are validated against the manifest.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self.entry(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&meta.inputs) {
            if t.len() != spec.elements() {
                bail!(
                    "{name}: input '{}' expects {} elements, got {}",
                    spec.name,
                    spec.elements(),
                    t.len()
                );
            }
            if t.dtype() != spec.dtype {
                bail!("{name}: input '{}' dtype mismatch", spec.name);
            }
        }
        self.ensure_compiled(name)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).unwrap();

        let literals = inputs
            .iter()
            .zip(&meta.inputs)
            .map(|(t, s)| t.to_literal(s))
            .collect::<Result<Vec<_>>>()?;
        let result =
            exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                meta.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| Tensor::from_literal(lit, spec.dtype))
            .collect()
    }
}

/// Default artifacts directory (repo-relative).
pub fn artifacts_dir() -> PathBuf {
    crate::util::repo_path("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Manifest parsing is unit-testable without PJRT; executor paths are
    // covered by `rust/tests/pjrt_integration.rs`.

    #[test]
    fn manifest_parses() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let tanh = &man.entries["tanh_s3_12"];
        assert_eq!(tanh.inputs.len(), 1);
        assert_eq!(tanh.inputs[0].dtype, Dtype::I32);
        assert_eq!(tanh.inputs[0].shape, vec![1024]);
        let mlp = &man.entries["mlp_b32"];
        assert_eq!(mlp.inputs.len(), 7);
        assert_eq!(mlp.outputs[0].shape, vec![32, 10]);
    }

    #[test]
    fn tensor_validation() {
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![4, 2],
            dtype: Dtype::F32,
        };
        assert_eq!(spec.elements(), 8);
        let t = Tensor::F32(vec![0.0; 8]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert!(t.to_literal(&spec).is_ok());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("s32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }
}
