//! End-to-end distributed tracing for the serving stack.
//!
//! Every traced request gets a 128-bit trace ID; every leg of its
//! execution (server dispatch, proxy forward, fan-out shard) records a
//! [`Span`] with start/end timestamps, peer, route, status, and a
//! free-form annotation (failover hops, transport errors). The trace
//! ID plus the caller's span ID ride the `x-tanhvf-trace` header
//! ([`TRACE_HEADER`]) across cluster legs, so the receiving node's
//! server span nests under the sender's client span; the response
//! carries the bare trace ID back to the external client. Gossip and
//! health probes are deliberately untraced — they are periodic
//! background chatter, not request work.
//!
//! Spans land in a per-node bounded ring buffer ([`TraceStore`]):
//! overflow evicts the oldest span (visible as
//! `tanhvf_spans_dropped_total` / `tanhvf_trace_store_bytes` on
//! `/metrics`), and `GET /debug/trace/{id}` renders whatever the node
//! still holds as a JSON span tree — 404 for never-seen IDs, 410 for
//! IDs the ring remembers evicting.
//!
//! Two determinism seams matter for the simulator
//! ([`super::sim`]):
//!
//! * **Time** goes through [`Clock`]: wall-monotonic in production,
//!   the simulator's virtual clock under `SimNet` — so a replayed
//!   seed yields bit-identical span timestamps.
//! * **IDs** come from a seeded [`SplitMix64`] stream. Production
//!   seeds from boot entropy; tests pin the seed. Callers on a
//!   deterministic path must allocate IDs in a deterministic order
//!   (the fan-out path allocates shard span IDs before spawning shard
//!   threads for exactly this reason).

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{self, Json};
use crate::util::log;
use crate::util::rng::SplitMix64;

/// Request *and* response header carrying trace context.
///
/// Request form: `<trace-id:32 hex>-<parent-span-id:16 hex>` — the
/// parent is the sender's client-leg span, so the receiver's server
/// span nests under it. Response form: bare `<trace-id:32 hex>`.
pub const TRACE_HEADER: &str = "x-tanhvf-trace";

/// Default span-ring capacity (spans, not traces). At ~200 bytes per
/// span this bounds the store near 1 MiB.
pub const DEFAULT_SPAN_CAPACITY: usize = 4096;

/// Evicted-trace memory: how many distinct trace IDs the store
/// remembers having dropped spans for (the 410-vs-404 distinction).
const EVICTED_IDS_KEPT: usize = 512;

/// Default slow-request threshold when `TANHVF_SLOW_REQUEST_MS` is
/// unset: completed root traces slower than this are logged.
const DEFAULT_SLOW_REQUEST_MS: u64 = 500;

// ---------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------

/// Span timestamp source: microseconds since an arbitrary per-node
/// origin. Production uses a monotonic wall anchor; the simulator
/// injects its virtual clock so span trees replay bit-identically.
#[derive(Clone)]
pub struct Clock(ClockKind);

#[derive(Clone)]
enum ClockKind {
    Wall(Instant),
    /// Closure returning virtual *milliseconds* (the simulator's
    /// native unit).
    Virtual(Arc<dyn Fn() -> u64 + Send + Sync>),
}

impl Clock {
    /// Monotonic wall clock anchored at construction.
    pub fn wall() -> Clock {
        Clock(ClockKind::Wall(Instant::now()))
    }

    /// Virtual clock: `now_ms` returns the simulator's current virtual
    /// millisecond.
    pub fn virtual_ms(now_ms: Arc<dyn Fn() -> u64 + Send + Sync>) -> Clock {
        Clock(ClockKind::Virtual(now_ms))
    }

    /// Current time in microseconds since the clock's origin.
    pub fn now_us(&self) -> u64 {
        match &self.0 {
            ClockKind::Wall(origin) => origin.elapsed().as_micros() as u64,
            ClockKind::Virtual(f) => f().saturating_mul(1000),
        }
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            ClockKind::Wall(_) => f.write_str("Clock::Wall"),
            ClockKind::Virtual(_) => f.write_str("Clock::Virtual"),
        }
    }
}

// ---------------------------------------------------------------------
// IDs and header codec
// ---------------------------------------------------------------------

/// 128-bit trace identifier (hex-rendered, 32 chars on the wire).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TraceId(pub u128);

impl TraceId {
    pub fn hex(&self) -> String {
        format!("{:032x}", self.0)
    }

    pub fn parse(s: &str) -> Option<TraceId> {
        if s.len() != 32 {
            return None;
        }
        u128::from_str_radix(s, 16).ok().map(TraceId)
    }
}

/// Render a span ID as its 16-hex-char wire form.
pub fn span_id_hex(id: u64) -> String {
    format!("{id:016x}")
}

/// Encode the request-side header value: trace ID plus the sender's
/// span (the receiver's parent).
pub fn encode_header(trace: TraceId, parent_span: u64) -> String {
    format!("{}-{}", trace.hex(), span_id_hex(parent_span))
}

/// Decode an incoming header. Accepts the full `trace-parent` request
/// form and the bare-trace response form (parent 0).
pub fn decode_header(value: &str) -> Option<(TraceId, u64)> {
    match value.split_once('-') {
        Some((t, p)) => {
            if p.len() != 16 {
                return None;
            }
            let trace = TraceId::parse(t)?;
            let parent = u64::from_str_radix(p, 16).ok()?;
            Some((trace, parent))
        }
        None => TraceId::parse(value).map(|t| (t, 0)),
    }
}

// ---------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------

/// One completed leg of a traced request.
#[derive(Clone, Debug)]
pub struct Span {
    pub trace: TraceId,
    pub id: u64,
    /// Parent span ID; 0 marks a root (no parent known to this node).
    pub parent: u64,
    /// Leg kind: `server` (dispatch on this node), `forward` (proxy
    /// leg to the ring owner), `shard` (one fan-out shard), `local`
    /// (the fan-out's locally-evaluated shard).
    pub kind: &'static str,
    /// HTTP route (`/v1/batch`) the leg served.
    pub route: String,
    /// Remote peer address for client legs, empty for local work.
    pub peer: String,
    /// HTTP status of the leg; 0 for legs that failed below HTTP.
    pub status: u16,
    pub start_us: u64,
    pub end_us: u64,
    /// Retry/failover annotation (`failover hop 1`, transport errors).
    pub note: String,
}

impl Span {
    pub fn new(
        trace: TraceId,
        id: u64,
        parent: u64,
        kind: &'static str,
        route: &str,
    ) -> Span {
        Span {
            trace,
            id,
            parent,
            kind,
            route: route.to_string(),
            peer: String::new(),
            status: 0,
            start_us: 0,
            end_us: 0,
            note: String::new(),
        }
    }

    /// Approximate heap+inline footprint, for the store-bytes gauge.
    fn cost(&self) -> u64 {
        (std::mem::size_of::<Span>()
            + self.route.len()
            + self.peer.len()
            + self.note.len()) as u64
    }
}

// ---------------------------------------------------------------------
// Store
// ---------------------------------------------------------------------

/// `GET /debug/trace/{id}` resolution.
pub enum TraceQuery {
    /// Spans this node still holds for the trace (possibly partial if
    /// eviction already claimed early legs).
    Found(Vec<Span>),
    /// The node held spans for this trace once, but the ring evicted
    /// them all (HTTP 410).
    Evicted,
    /// Never seen here (HTTP 404).
    Unknown,
}

struct StoreInner {
    spans: VecDeque<Span>,
    /// Trace IDs with at least one evicted span, newest last.
    evicted: VecDeque<TraceId>,
}

/// Per-node bounded span ring plus the trace-ID generator.
pub struct TraceStore {
    cap_spans: usize,
    slow_threshold_us: u64,
    ids: Mutex<SplitMix64>,
    inner: Mutex<StoreInner>,
    dropped: AtomicU64,
    bytes: AtomicU64,
}

impl TraceStore {
    /// Fully pinned constructor (tests and the simulator): same seed →
    /// same ID stream.
    pub fn new(
        cap_spans: usize,
        id_seed: u64,
        slow_threshold_us: u64,
    ) -> TraceStore {
        TraceStore {
            cap_spans: cap_spans.max(1),
            slow_threshold_us,
            ids: Mutex::new(SplitMix64::new(id_seed)),
            inner: Mutex::new(StoreInner {
                spans: VecDeque::new(),
                evicted: VecDeque::new(),
            }),
            dropped: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    /// Production constructor: boot-entropy ID seed, slow threshold
    /// from `TANHVF_SLOW_REQUEST_MS` (milliseconds, default 500).
    pub fn with_entropy(cap_spans: usize) -> TraceStore {
        let threshold_ms = std::env::var("TANHVF_SLOW_REQUEST_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(DEFAULT_SLOW_REQUEST_MS);
        TraceStore::new(
            cap_spans,
            entropy_seed(),
            threshold_ms.saturating_mul(1000),
        )
    }

    /// Completed root traces at least this long get a slow-request log
    /// line.
    pub fn slow_threshold_us(&self) -> u64 {
        self.slow_threshold_us
    }

    /// Fresh nonzero span ID.
    pub fn next_span_id(&self) -> u64 {
        let mut g = self.ids.lock().unwrap();
        loop {
            let id = g.next_u64();
            if id != 0 {
                return id;
            }
        }
    }

    /// Fresh nonzero 128-bit trace ID.
    pub fn new_trace_id(&self) -> TraceId {
        let mut g = self.ids.lock().unwrap();
        loop {
            let hi = g.next_u64();
            let lo = g.next_u64();
            let id = ((hi as u128) << 64) | (lo as u128);
            if id != 0 {
                return TraceId(id);
            }
        }
    }

    /// Record a completed span, evicting the oldest past capacity.
    pub fn push(&self, span: Span) {
        self.bytes.fetch_add(span.cost(), Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        inner.spans.push_back(span);
        while inner.spans.len() > self.cap_spans {
            let old = inner.spans.pop_front().unwrap();
            self.bytes.fetch_sub(old.cost(), Ordering::Relaxed);
            self.dropped.fetch_add(1, Ordering::Relaxed);
            if !inner.evicted.contains(&old.trace) {
                inner.evicted.push_back(old.trace);
                if inner.evicted.len() > EVICTED_IDS_KEPT {
                    inner.evicted.pop_front();
                }
            }
        }
    }

    /// Spans evicted by the ring bound since boot
    /// (`tanhvf_spans_dropped_total`).
    pub fn spans_dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Approximate bytes currently held (`tanhvf_trace_store_bytes`).
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Spans currently held.
    pub fn span_count(&self) -> usize {
        self.inner.lock().unwrap().spans.len()
    }

    /// Resolve a trace ID against the ring (the `/debug/trace/{id}`
    /// backend).
    pub fn lookup(&self, id: TraceId) -> TraceQuery {
        let inner = self.inner.lock().unwrap();
        let spans: Vec<Span> = inner
            .spans
            .iter()
            .filter(|s| s.trace == id)
            .cloned()
            .collect();
        if !spans.is_empty() {
            TraceQuery::Found(spans)
        } else if inner.evicted.contains(&id) {
            TraceQuery::Evicted
        } else {
            TraceQuery::Unknown
        }
    }

    /// Slow-request log: called with the just-completed *root* span.
    /// Emits one structured line carrying the whole local span tree if
    /// the root exceeded the threshold.
    pub fn maybe_log_slow(&self, root: &Span) {
        let duration = root.end_us.saturating_sub(root.start_us);
        if duration < self.slow_threshold_us {
            return;
        }
        if !log::enabled(log::Level::Warn) {
            return;
        }
        let spans = match self.lookup(root.trace) {
            TraceQuery::Found(s) => s,
            _ => vec![root.clone()],
        };
        log::warn(
            "trace",
            "slow request",
            &[
                ("trace_id", root.trace.hex()),
                ("route", root.route.clone()),
                ("status", root.status.to_string()),
                ("duration_us", duration.to_string()),
                ("spans", json::write(&span_tree_json(&spans))),
            ],
        );
    }
}

/// Boot-entropy seed for production trace/span IDs: wall nanoseconds
/// mixed with a stack address (ASLR), then finalized through splitmix.
fn entropy_seed() -> u64 {
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let marker = 0u8;
    let addr = &marker as *const u8 as u64;
    SplitMix64::new(t ^ addr.rotate_left(29)).next_u64()
}

// ---------------------------------------------------------------------
// Span-tree rendering
// ---------------------------------------------------------------------

/// Render spans as a canonical JSON forest: children nested under
/// their parent, siblings ordered by `(start_us, span_id)`. Spans
/// whose parent isn't in the set (evicted, or recorded on another
/// node) surface as roots, so a partially-evicted trace still renders.
pub fn span_tree_json(spans: &[Span]) -> Json {
    let mut order: Vec<usize> = (0..spans.len()).collect();
    order.sort_by_key(|&i| (spans[i].start_us, spans[i].id));
    let ids: BTreeSet<u64> = spans.iter().map(|s| s.id).collect();
    let mut children: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    let mut roots: Vec<usize> = Vec::new();
    for &i in &order {
        let s = &spans[i];
        if s.parent != 0 && s.parent != s.id && ids.contains(&s.parent) {
            children.entry(s.parent).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    fn build(
        spans: &[Span],
        children: &BTreeMap<u64, Vec<usize>>,
        i: usize,
    ) -> Json {
        let s = &spans[i];
        let mut m = BTreeMap::new();
        m.insert("span_id".to_string(), Json::Str(span_id_hex(s.id)));
        m.insert(
            "parent_id".to_string(),
            if s.parent == 0 {
                Json::Null
            } else {
                Json::Str(span_id_hex(s.parent))
            },
        );
        m.insert("kind".to_string(), Json::Str(s.kind.to_string()));
        m.insert("route".to_string(), Json::Str(s.route.clone()));
        m.insert("peer".to_string(), Json::Str(s.peer.clone()));
        m.insert("status".to_string(), Json::Num(s.status as f64));
        m.insert("start_us".to_string(), Json::Num(s.start_us as f64));
        m.insert("end_us".to_string(), Json::Num(s.end_us as f64));
        m.insert("note".to_string(), Json::Str(s.note.clone()));
        let kids = children
            .get(&s.id)
            .map(|v| v.iter().map(|&c| build(spans, children, c)).collect())
            .unwrap_or_default();
        m.insert("children".to_string(), Json::Arr(kids));
        Json::Obj(m)
    }
    Json::Arr(roots.iter().map(|&i| build(spans, &children, i)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> TraceStore {
        TraceStore::new(8, 42, u64::MAX)
    }

    #[test]
    fn header_roundtrip() {
        let s = store();
        let t = s.new_trace_id();
        let parent = s.next_span_id();
        let h = encode_header(t, parent);
        assert_eq!(h.len(), 32 + 1 + 16);
        assert_eq!(decode_header(&h), Some((t, parent)));
        // Response (bare) form decodes with parent 0.
        assert_eq!(decode_header(&t.hex()), Some((t, 0)));
        assert_eq!(decode_header("nonsense"), None);
        assert_eq!(decode_header(""), None);
    }

    #[test]
    fn id_streams_are_seed_deterministic() {
        let a = TraceStore::new(8, 7, 0);
        let b = TraceStore::new(8, 7, 0);
        assert_eq!(a.new_trace_id(), b.new_trace_id());
        assert_eq!(a.next_span_id(), b.next_span_id());
    }

    #[test]
    fn ring_evicts_counts_and_answers_410_vs_404() {
        let s = store(); // capacity 8
        let first = s.new_trace_id();
        let mut sp = Span::new(first, 1, 0, "server", "/v1/eval");
        sp.start_us = 1;
        sp.end_us = 2;
        s.push(sp.clone());
        assert!(matches!(s.lookup(first), TraceQuery::Found(_)));
        assert!(s.bytes() > 0);
        // Flood the ring with other traces until `first` is evicted.
        for i in 0..16u64 {
            let t = s.new_trace_id();
            let mut other = Span::new(t, i + 2, 0, "server", "/v1/eval");
            other.start_us = 10 + i;
            other.end_us = 11 + i;
            s.push(other);
        }
        assert_eq!(s.span_count(), 8);
        assert_eq!(s.spans_dropped(), 9);
        assert!(matches!(s.lookup(first), TraceQuery::Evicted));
        assert!(matches!(
            s.lookup(TraceId(0xdead_beef)),
            TraceQuery::Unknown
        ));
    }

    #[test]
    fn bytes_gauge_shrinks_on_eviction() {
        let s = TraceStore::new(1, 3, 0);
        let t = s.new_trace_id();
        let mut big = Span::new(t, 1, 0, "server", "/v1/batch");
        big.note = "x".repeat(1000);
        s.push(big);
        let with_big = s.bytes();
        let mut small = Span::new(t, 2, 0, "server", "/v1/batch");
        small.start_us = 5;
        s.push(small); // evicts `big`
        assert!(s.bytes() < with_big);
        assert_eq!(s.spans_dropped(), 1);
    }

    #[test]
    fn tree_nests_children_and_orders_siblings() {
        let t = TraceId(9);
        let mut root = Span::new(t, 10, 0, "server", "/v1/batch");
        root.start_us = 0;
        root.end_us = 100;
        let mut shard_b = Span::new(t, 12, 10, "shard", "/v1/batch");
        shard_b.start_us = 20;
        shard_b.end_us = 40;
        let mut shard_a = Span::new(t, 11, 10, "shard", "/v1/batch");
        shard_a.start_us = 10;
        shard_a.end_us = 30;
        // Storage order scrambled on purpose; rendering must sort.
        let tree = span_tree_json(&[shard_b.clone(), root, shard_a.clone()]);
        let roots = tree.as_arr().unwrap();
        assert_eq!(roots.len(), 1);
        let kids = roots[0].get("children").unwrap().as_arr().unwrap();
        assert_eq!(kids.len(), 2);
        assert_eq!(
            kids[0].get("span_id").unwrap().as_str().unwrap(),
            span_id_hex(shard_a.id)
        );
        assert_eq!(
            kids[1].get("span_id").unwrap().as_str().unwrap(),
            span_id_hex(shard_b.id)
        );
        // An orphaned parent reference renders as a root, not a loss.
        let orphan_tree = span_tree_json(&[shard_a]);
        assert_eq!(orphan_tree.as_arr().unwrap().len(), 1);
    }
}
