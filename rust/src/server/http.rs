//! Strict HTTP/1.1 wire layer (no external deps).
//!
//! The core is [`Parser`], an *incremental* message parser: feed it
//! bytes as they arrive and it resumes mid-request-line, mid-header,
//! mid-body — exactly what the nonblocking reactor (the crate-private
//! `conn`/`reactor` modules) needs. It handles request-line +
//! header parsing with hard limits, `Content-Length` bodies, and
//! `Transfer-Encoding: chunked` bodies (with trailer handling and the
//! same max-body bound as fixed-length bodies). Malformed input maps to
//! a 4xx via [`HttpError::status`].
//!
//! [`HttpConn`] is the blocking convenience wrapper over the same
//! parser, used by the thread-per-connection server backend, the client
//! side of [`super::loadgen`], and the e2e tests — so requests and
//! responses are parsed by one code path regardless of backend.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;

use crate::util::json::{self, Json};

/// Longest accepted request/status/header line, in bytes.
const MAX_LINE: usize = 8192;
/// Most header/trailer lines accepted per message.
const MAX_HEADERS: usize = 64;
/// Upfront body reservation cap — declared lengths are attacker-claimed
/// until the bytes actually arrive.
const MAX_PREALLOC: usize = 64 << 10;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub target: String,
    pub version: String,
    /// Header names lowercased, values trimmed (chunked trailers are
    /// merged in after the body).
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(String::as_str)
    }

    /// Path component of the target (query string stripped).
    pub fn path(&self) -> &str {
        self.target.split(['?', '#']).next().unwrap_or(&self.target)
    }

    /// HTTP/1.1 keep-alive semantics (1.0 requires opt-in).
    pub fn keep_alive(&self) -> bool {
        let conn = self
            .header("connection")
            .map(str::to_ascii_lowercase)
            .unwrap_or_default();
        if self.version == "HTTP/1.0" {
            conn == "keep-alive"
        } else {
            conn != "close"
        }
    }

    /// Body parsed as JSON, or a reason it can't be.
    pub fn json_body(&self) -> Result<Json, String> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| "body is not valid UTF-8".to_string())?;
        json::parse(text).map_err(|e| e.to_string())
    }
}

/// Protocol-level failure while reading a message.
#[derive(Debug)]
pub enum HttpError {
    /// Syntactically invalid input -> 400.
    Malformed(String),
    /// Mid-message read timeout (slow client) -> 408.
    Timeout(String),
    /// Line/header/body limits exceeded -> 431 or 413.
    TooLarge { what: String, status: u16 },
    /// Valid HTTP we refuse to implement (e.g. gzip transfer coding)
    /// -> 501.
    Unsupported(String),
    /// Transport error; no response possible.
    Io(std::io::Error),
}

impl HttpError {
    /// The response status this error maps to (0 = connection is dead).
    pub fn status(&self) -> u16 {
        match self {
            HttpError::Malformed(_) => 400,
            HttpError::Timeout(_) => 408,
            HttpError::TooLarge { status, .. } => *status,
            HttpError::Unsupported(_) => 501,
            HttpError::Io(_) => 0,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Malformed(m) => write!(f, "malformed request: {m}"),
            HttpError::Timeout(m) => write!(f, "timeout: {m}"),
            HttpError::TooLarge { what, .. } => write!(f, "too large: {what}"),
            HttpError::Unsupported(m) => write!(f, "unsupported: {m}"),
            HttpError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

// ---------------------------------------------------------------------
// Incremental message parser
// ---------------------------------------------------------------------

/// A complete HTTP message: start line + headers + decoded body.
///
/// Interpretation of the start line is the caller's job — see
/// [`request_from_message`] (server side) and [`response_from_message`]
/// (client side).
#[derive(Debug)]
pub struct Message {
    pub start: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum PState {
    /// Before the start line (tolerates up to 2 stray blank lines).
    Start,
    Headers,
    FixedBody { remaining: usize },
    /// Chunked transfer coding: a chunk-size line comes next.
    ChunkSize,
    ChunkData { remaining: usize },
    /// The CRLF terminating a chunk's data.
    ChunkEnd,
    /// Trailer header block after the last (zero-size) chunk.
    Trailers,
}

/// Resumable HTTP/1.1 message parser.
///
/// [`Parser::feed`] appends raw bytes; [`Parser::advance`] consumes as
/// much as it can and yields a [`Message`] once one is complete. State
/// is preserved across calls, so bytes may arrive split at *any*
/// boundary (mid-header, mid-chunk-size-line, mid-chunk-data). Leftover
/// bytes after a complete message are kept for pipelining.
pub struct Parser {
    buf: Vec<u8>,
    pos: usize,
    state: PState,
    start_line: String,
    headers: BTreeMap<String, String>,
    body: Vec<u8>,
    blanks: u32,
    /// Header + trailer *lines* seen for the current message — counted
    /// independently of the map so duplicate names can't dodge the
    /// MAX_HEADERS bound.
    header_lines: u32,
}

impl Default for Parser {
    fn default() -> Self {
        Parser::new()
    }
}

impl Parser {
    pub fn new() -> Parser {
        Parser {
            buf: Vec::with_capacity(4096),
            pos: 0,
            state: PState::Start,
            start_line: String::new(),
            headers: BTreeMap::new(),
            body: Vec::new(),
            blanks: 0,
            header_lines: 0,
        }
    }

    /// Append raw bytes from the transport.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// True when the parser sits cleanly between messages with nothing
    /// buffered — the only point where EOF/idle is not an error.
    pub fn is_clean(&self) -> bool {
        self.state == PState::Start && self.pos >= self.buf.len()
    }

    /// Drop consumed bytes.
    fn compact(&mut self) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Next CRLF/LF-terminated line if one is fully buffered.
    fn take_line(&mut self) -> Result<Option<String>, HttpError> {
        fn too_large() -> HttpError {
            HttpError::TooLarge {
                what: "header line exceeds 8 KiB".into(),
                status: 431,
            }
        }
        let Some(off) = self.buf[self.pos..].iter().position(|&b| b == b'\n')
        else {
            if self.buf.len() - self.pos > MAX_LINE {
                return Err(too_large());
            }
            return Ok(None);
        };
        // The limit also applies when the terminator arrived in the same
        // (possibly large) feed as the line itself.
        if off > MAX_LINE {
            return Err(too_large());
        }
        let end = self.pos + off;
        let mut line = &self.buf[self.pos..end];
        if line.last() == Some(&b'\r') {
            line = &line[..line.len() - 1];
        }
        let text = String::from_utf8(line.to_vec()).map_err(|_| {
            HttpError::Malformed("non-UTF-8 header bytes".into())
        })?;
        self.pos = end + 1;
        Ok(Some(text))
    }

    fn count_header_line(&mut self) -> Result<(), HttpError> {
        self.header_lines += 1;
        if self.header_lines > MAX_HEADERS as u32 {
            return Err(HttpError::TooLarge {
                what: "more than 64 headers".into(),
                status: 431,
            });
        }
        Ok(())
    }

    /// Validate and store one `Name: value` header line.
    fn push_header(&mut self, line: String) -> Result<(), HttpError> {
        self.count_header_line()?;
        let (name, value) = parse_header_line(&line)?;
        // Conflicting framing fields are a request-smuggling seed (a
        // fronting proxy may honor the other copy): reject outright
        // rather than last-wins (RFC 9112 §6.3).
        if matches!(name.as_str(), "content-length" | "transfer-encoding")
            && self.headers.contains_key(&name)
        {
            return Err(HttpError::Malformed(format!(
                "duplicate {name} header"
            )));
        }
        self.headers.insert(name, value);
        Ok(())
    }

    /// Validate and merge one trailer line. Trailers may add metadata
    /// but must never introduce or override framing/routing/control
    /// fields (RFC 9110 §6.5.1), nor clobber an existing header.
    fn push_trailer(&mut self, line: String) -> Result<(), HttpError> {
        self.count_header_line()?;
        let (name, value) = parse_header_line(&line)?;
        if !FORBIDDEN_TRAILERS.contains(&name.as_str())
            && !self.headers.contains_key(&name)
        {
            self.headers.insert(name, value);
        }
        Ok(())
    }

    /// Decide body framing once the header block ends.
    fn framing(&self, max_body: usize) -> Result<PState, HttpError> {
        if let Some(te) = self.headers.get("transfer-encoding") {
            if !te.eq_ignore_ascii_case("chunked") {
                return Err(HttpError::Unsupported(format!(
                    "transfer-encoding {te:?} (only chunked)"
                )));
            }
            if self.headers.contains_key("content-length") {
                return Err(HttpError::Malformed(
                    "both content-length and transfer-encoding".into(),
                ));
            }
            return Ok(PState::ChunkSize);
        }
        let len = match self.headers.get("content-length") {
            None => 0,
            Some(v) => v.parse::<usize>().map_err(|_| {
                HttpError::Malformed(format!("bad content-length {v:?}"))
            })?,
        };
        if len > max_body {
            return Err(HttpError::TooLarge {
                what: format!("body of {len} bytes (limit {max_body})"),
                status: 413,
            });
        }
        Ok(PState::FixedBody { remaining: len })
    }

    /// Package the accumulated message and reset for the next one.
    fn finish(&mut self) -> Message {
        self.state = PState::Start;
        self.blanks = 0;
        self.header_lines = 0;
        self.compact();
        Message {
            start: std::mem::take(&mut self.start_line),
            headers: std::mem::take(&mut self.headers),
            body: std::mem::take(&mut self.body),
        }
    }

    /// Consume buffered bytes; `Ok(None)` means more input is needed.
    ///
    /// `max_body` bounds the *decoded* body (fixed-length and chunked
    /// alike); beyond it the message is rejected with 413.
    pub fn advance(
        &mut self,
        max_body: usize,
    ) -> Result<Option<Message>, HttpError> {
        loop {
            match self.state {
                PState::Start => {
                    let Some(line) = self.take_line()? else {
                        self.compact();
                        return Ok(None);
                    };
                    if line.is_empty() {
                        self.blanks += 1;
                        if self.blanks > 2 {
                            return Err(HttpError::Malformed(
                                "blank lines before start line".into(),
                            ));
                        }
                    } else {
                        self.start_line = line;
                        self.state = PState::Headers;
                    }
                }
                PState::Headers => {
                    let Some(line) = self.take_line()? else {
                        self.compact();
                        return Ok(None);
                    };
                    if line.is_empty() {
                        match self.framing(max_body)? {
                            PState::FixedBody { remaining: 0 } => {
                                return Ok(Some(self.finish()));
                            }
                            next => {
                                if let PState::FixedBody { remaining } = next {
                                    // Cap the upfront reservation: the
                                    // length is attacker-claimed; real
                                    // bytes grow the Vec as they land.
                                    self.body
                                        .reserve(remaining.min(MAX_PREALLOC));
                                }
                                self.state = next;
                            }
                        }
                    } else {
                        self.push_header(line)?;
                    }
                }
                PState::FixedBody { remaining } => {
                    let avail = self.buf.len() - self.pos;
                    if avail == 0 {
                        self.compact();
                        return Ok(None);
                    }
                    let take = avail.min(remaining);
                    self.body
                        .extend_from_slice(&self.buf[self.pos..self.pos + take]);
                    self.pos += take;
                    if remaining == take {
                        return Ok(Some(self.finish()));
                    }
                    self.state =
                        PState::FixedBody { remaining: remaining - take };
                }
                PState::ChunkSize => {
                    let Some(line) = self.take_line()? else {
                        self.compact();
                        return Ok(None);
                    };
                    let size = parse_chunk_size(&line)?;
                    if size == 0 {
                        self.state = PState::Trailers;
                    } else if self.body.len().saturating_add(size) > max_body {
                        return Err(HttpError::TooLarge {
                            what: format!(
                                "chunked body beyond {max_body} bytes"
                            ),
                            status: 413,
                        });
                    } else {
                        self.body.reserve(size.min(MAX_PREALLOC));
                        self.state = PState::ChunkData { remaining: size };
                    }
                }
                PState::ChunkData { remaining } => {
                    let avail = self.buf.len() - self.pos;
                    if avail == 0 {
                        self.compact();
                        return Ok(None);
                    }
                    let take = avail.min(remaining);
                    self.body
                        .extend_from_slice(&self.buf[self.pos..self.pos + take]);
                    self.pos += take;
                    if remaining == take {
                        self.state = PState::ChunkEnd;
                    } else {
                        self.state =
                            PState::ChunkData { remaining: remaining - take };
                    }
                }
                PState::ChunkEnd => {
                    let Some(line) = self.take_line()? else {
                        self.compact();
                        return Ok(None);
                    };
                    if !line.is_empty() {
                        return Err(HttpError::Malformed(
                            "missing CRLF after chunk data".into(),
                        ));
                    }
                    self.state = PState::ChunkSize;
                }
                PState::Trailers => {
                    let Some(line) = self.take_line()? else {
                        self.compact();
                        return Ok(None);
                    };
                    if line.is_empty() {
                        return Ok(Some(self.finish()));
                    }
                    self.push_trailer(line)?;
                }
            }
        }
    }

    /// Server-side convenience: advance and interpret as a request.
    pub fn next_request(
        &mut self,
        max_body: usize,
    ) -> Result<Option<Request>, HttpError> {
        match self.advance(max_body)? {
            Some(msg) => request_from_message(msg).map(Some),
            None => Ok(None),
        }
    }
}

/// Header names a trailer section may never add or override: framing,
/// routing, and connection control (RFC 9110 §6.5.1 subset).
const FORBIDDEN_TRAILERS: &[&str] = &[
    "connection",
    "content-length",
    "content-type",
    "expect",
    "host",
    "te",
    "trailer",
    "transfer-encoding",
    "upgrade",
];

/// Split and validate a `Name: value` header/trailer line into a
/// (lowercased name, trimmed value) pair.
fn parse_header_line(line: &str) -> Result<(String, String), HttpError> {
    let (name, value) = line.split_once(':').ok_or_else(|| {
        HttpError::Malformed(format!("header without ':': {line:?}"))
    })?;
    if name.is_empty()
        || !name.bytes().all(|b| b.is_ascii_graphic() && b != b':')
    {
        return Err(HttpError::Malformed(format!(
            "invalid header name {name:?}"
        )));
    }
    Ok((name.to_ascii_lowercase(), value.trim().to_string()))
}

/// Chunk-size line: hex count, optional `;extension` ignored. Strict
/// HEXDIG-only grammar (RFC 9112 §7.1) — `from_str_radix` alone would
/// admit a leading `+`, a parser-disagreement seed for request
/// smuggling behind a fronting proxy.
fn parse_chunk_size(line: &str) -> Result<usize, HttpError> {
    let hex = line.split(';').next().unwrap_or("").trim();
    if hex.is_empty() || !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(HttpError::Malformed(format!(
            "bad chunk size {line:?}"
        )));
    }
    usize::from_str_radix(hex, 16).map_err(|_| {
        HttpError::Malformed(format!("bad chunk size {line:?}"))
    })
}

/// Interpret a parsed message as an HTTP request (server side).
pub fn request_from_message(msg: Message) -> Result<Request, HttpError> {
    let line = &msg.start;
    let mut parts = line.split(' ');
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None)
                if !m.is_empty() && !t.is_empty() =>
            {
                (m.to_string(), t.to_string(), v.to_string())
            }
            _ => {
                return Err(HttpError::Malformed(format!(
                    "bad request line {line:?}"
                )))
            }
        };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::Malformed(format!("bad method {method:?}")));
    }
    if !target.starts_with('/') {
        return Err(HttpError::Malformed(format!("bad target {target:?}")));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HttpError::Malformed(format!(
            "unsupported version {version:?}"
        )));
    }
    Ok(Request {
        method,
        target,
        version,
        headers: msg.headers,
        body: msg.body,
    })
}

/// Interpret a parsed message as a response (client side).
pub fn response_from_message(
    msg: Message,
) -> Result<(u16, BTreeMap<String, String>, Vec<u8>), HttpError> {
    let line = &msg.start;
    let mut parts = line.splitn(3, ' ');
    let (version, code) = (parts.next().unwrap_or(""), parts.next());
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("bad status line {line:?}")));
    }
    let status: u16 = code.and_then(|c| c.parse().ok()).ok_or_else(|| {
        HttpError::Malformed(format!("bad status line {line:?}"))
    })?;
    Ok((status, msg.headers, msg.body))
}

/// Serialize a response head+body into one buffer (single `write_all`:
/// no mid-message gap for the peer's read timeout to land in).
pub fn encode_response(resp: &Response, keep_alive: bool) -> Vec<u8> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
         Connection: {}\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in &resp.headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    let mut msg = head.into_bytes();
    msg.extend_from_slice(&resp.body);
    msg
}

// ---------------------------------------------------------------------
// Blocking connection wrapper
// ---------------------------------------------------------------------

/// Result of waiting for the next request on a connection.
pub enum Outcome {
    Request(Request),
    /// Peer closed cleanly between requests.
    Closed,
    /// Read timeout with no bytes pending — caller decides whether the
    /// keep-alive idle budget is spent.
    IdleTimeout,
}

enum Fill {
    Data,
    Eof,
    Idle,
}

/// A buffered blocking HTTP connection (server or client side), built on
/// the incremental [`Parser`].
pub struct HttpConn {
    stream: TcpStream,
    parser: Parser,
}

impl HttpConn {
    pub fn new(stream: TcpStream) -> HttpConn {
        HttpConn { stream, parser: Parser::new() }
    }

    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    /// True when the connection sits cleanly between messages: no
    /// partial message in flight and no unread pipelined bytes. The
    /// cluster connection pool ([`super::pool`]) only re-admits clean
    /// connections — anything else would hand the next request a
    /// desynchronized byte stream.
    pub fn is_clean(&self) -> bool {
        self.parser.is_clean()
    }

    /// Read more bytes from the socket into the parser.
    fn fill(&mut self) -> Result<Fill, HttpError> {
        let mut chunk = [0u8; 4096];
        match self.stream.read(&mut chunk) {
            Ok(0) => Ok(Fill::Eof),
            Ok(n) => {
                self.parser.feed(&chunk[..n]);
                Ok(Fill::Data)
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                Ok(Fill::Idle)
            }
            Err(e) => Err(HttpError::Io(e)),
        }
    }

    /// Drive the parser until a message, EOF, or an idle tick.
    fn next_message(
        &mut self,
        max_body: usize,
    ) -> Result<MsgOutcome, HttpError> {
        loop {
            if let Some(msg) = self.parser.advance(max_body)? {
                return Ok(MsgOutcome::Message(msg));
            }
            match self.fill()? {
                Fill::Data => {}
                Fill::Eof => {
                    return if self.parser.is_clean() {
                        Ok(MsgOutcome::Closed)
                    } else {
                        Err(HttpError::Malformed("unexpected eof".into()))
                    };
                }
                Fill::Idle => {
                    return if self.parser.is_clean() {
                        Ok(MsgOutcome::Idle)
                    } else {
                        Err(HttpError::Timeout("mid-message read stall".into()))
                    };
                }
            }
        }
    }

    /// Server side: wait for the next request.
    pub fn read_request(
        &mut self,
        max_body: usize,
    ) -> Result<Outcome, HttpError> {
        match self.next_message(max_body)? {
            MsgOutcome::Closed => Ok(Outcome::Closed),
            MsgOutcome::Idle => Ok(Outcome::IdleTimeout),
            MsgOutcome::Message(msg) => {
                Ok(Outcome::Request(request_from_message(msg)?))
            }
        }
    }

    /// Server side: serialize a response.
    pub fn write_response(
        &mut self,
        resp: &Response,
        keep_alive: bool,
    ) -> std::io::Result<()> {
        self.stream.write_all(&encode_response(resp, keep_alive))?;
        self.stream.flush()
    }

    /// Client side: serialize a request (always keep-alive).
    pub fn write_request(
        &mut self,
        method: &str,
        target: &str,
        body: &[u8],
    ) -> std::io::Result<()> {
        self.write_request_with_headers(method, target, &[], body)
    }

    /// Client side: serialize a request with extra headers — the proxy
    /// leg of the cluster tier uses this to tag forwarded requests.
    pub fn write_request_with_headers(
        &mut self,
        method: &str,
        target: &str,
        extra_headers: &[(&str, &str)],
        body: &[u8],
    ) -> std::io::Result<()> {
        let host = self
            .stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "localhost".into());
        let mut head = format!(
            "{method} {target} HTTP/1.1\r\nHost: {host}\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\
             Connection: keep-alive\r\n",
            body.len(),
        );
        for (name, value) in extra_headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        let mut msg = head.into_bytes();
        msg.extend_from_slice(body);
        self.stream.write_all(&msg)?;
        self.stream.flush()
    }

    /// Client side: read a status + headers + body response.
    pub fn read_response(
        &mut self,
        max_body: usize,
    ) -> Result<(u16, BTreeMap<String, String>, Vec<u8>), HttpError> {
        match self.next_message(max_body)? {
            MsgOutcome::Closed => {
                Err(HttpError::Malformed("closed before response".into()))
            }
            MsgOutcome::Idle => {
                Err(HttpError::Timeout("waiting for response".into()))
            }
            MsgOutcome::Message(msg) => response_from_message(msg),
        }
    }
}

enum MsgOutcome {
    Message(Message),
    Closed,
    Idle,
}

/// An HTTP response about to be serialized.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
    /// Extra response headers beyond the Content-Type/Content-Length/
    /// Connection trio that [`encode_response`] always emits (e.g. the
    /// `x-tanhvf-trace` propagation header). Names must not collide
    /// with the built-in three.
    pub headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json".into(),
            body: json::write(v).into_bytes(),
            headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8".into(),
            body: body.as_bytes().to_vec(),
            headers: Vec::new(),
        }
    }

    /// Attach an extra response header (builder-style).
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }
}

/// Canonical reason phrases for the statuses the service emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        410 => "Gone",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    /// Loopback socket pair for exercising the parser on real streams.
    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    fn feed(bytes: &[u8]) -> Result<Outcome, HttpError> {
        let (mut client, server) = pair();
        client.write_all(bytes).unwrap();
        drop(client); // EOF terminates the message cleanly for the parser
        HttpConn::new(server).read_request(1 << 20)
    }

    /// Parse one request straight through the incremental parser.
    fn parse_all(bytes: &[u8], max_body: usize) -> Result<Request, HttpError> {
        let mut p = Parser::new();
        p.feed(bytes);
        match p.next_request(max_body)? {
            Some(r) => Ok(r),
            None => Err(HttpError::Malformed("incomplete".into())),
        }
    }

    #[test]
    fn parses_post_with_body() {
        let req = feed(
            b"POST /v1/eval HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd",
        );
        match req.unwrap() {
            Outcome::Request(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path(), "/v1/eval");
                assert_eq!(r.body, b"abcd");
                assert!(r.keep_alive());
            }
            _ => panic!("expected request"),
        }
    }

    #[test]
    fn query_string_is_stripped_and_close_honoured() {
        let out =
            feed(b"GET /metrics?x=1 HTTP/1.1\r\nConnection: close\r\n\r\n");
        match out.unwrap() {
            Outcome::Request(r) => {
                assert_eq!(r.path(), "/metrics");
                assert!(!r.keep_alive());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn clean_eof_is_closed() {
        match feed(b"") {
            Ok(Outcome::Closed) => {}
            other => panic!("{other:?}", other = other.map(|_| "req")),
        }
    }

    #[test]
    fn garbage_is_malformed() {
        for bad in [
            &b"NOT AN HTTP REQUEST\r\n\r\n"[..],
            b"GET\r\n\r\n",
            b"get /x HTTP/1.1\r\n\r\n",
            b"GET x HTTP/1.1\r\n\r\n",
            b"GET /x HTTP/2\r\n\r\n",
            b"GET /x HTTP/1.1\r\nbad header\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort",
            // Conflicting framing copies are a smuggling seed -> 400.
            b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\
              Content-Length: 50\r\n\r\nhello",
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\
              Transfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        ] {
            let err = match feed(bad) {
                Err(e) => e,
                Ok(Outcome::Request(r)) => panic!("parsed {bad:?} as {r:?}"),
                Ok(_) => panic!("{bad:?} not treated as malformed"),
            };
            assert_eq!(err.status(), 400, "{bad:?} -> {err}");
        }
    }

    #[test]
    fn oversize_body_is_413() {
        let (mut client, server) = pair();
        client
            .write_all(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\n")
            .unwrap();
        drop(client);
        let err413 = HttpConn::new(server).read_request(16).unwrap_err();
        assert_eq!(err413.status(), 413);
    }

    #[test]
    fn overlong_line_is_431_even_when_fully_buffered() {
        // A single large feed can deliver a >8 KiB line *with* its
        // terminator; the limit must still hold (the reactor feeds up
        // to 64 KiB per readiness event).
        let mut wire = b"GET /".to_vec();
        wire.extend(std::iter::repeat(b'a').take(MAX_LINE + 10));
        wire.extend_from_slice(b" HTTP/1.1\r\n\r\n");
        let mut p = Parser::new();
        p.feed(&wire);
        let err = p.next_request(1 << 20).unwrap_err();
        assert_eq!(err.status(), 431, "{err}");
    }

    #[test]
    fn chunked_request_is_decoded() {
        let req = feed(
            b"POST /v1/eval HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              4\r\nab{}\r\n6\r\n\"x\": 1\r\n0\r\n\r\n",
        );
        match req.unwrap() {
            Outcome::Request(r) => {
                assert_eq!(r.body, b"ab{}\"x\": 1");
                assert_eq!(r.header("transfer-encoding"), Some("chunked"));
            }
            _ => panic!("expected request"),
        }
    }

    #[test]
    fn chunked_trailers_merge_into_headers() {
        let req = parse_all(
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              3\r\nabc\r\n0\r\nX-Checksum: deadbeef\r\n\r\n",
            1 << 20,
        )
        .unwrap();
        assert_eq!(req.body, b"abc");
        assert_eq!(req.header("x-checksum"), Some("deadbeef"));
    }

    #[test]
    fn trailers_cannot_override_control_headers() {
        let req = parse_all(
            b"POST /x HTTP/1.1\r\nConnection: close\r\n\
              Transfer-Encoding: chunked\r\n\r\n\
              3\r\nabc\r\n0\r\n\
              Connection: keep-alive\r\nContent-Length: 999\r\n\
              X-Meta: ok\r\n\r\n",
            1 << 20,
        )
        .unwrap();
        // Control/framing fields from the trailer are dropped ...
        assert_eq!(req.header("connection"), Some("close"));
        assert!(!req.keep_alive());
        assert_eq!(req.header("content-length"), None);
        // ... benign metadata still merges.
        assert_eq!(req.header("x-meta"), Some("ok"));
    }

    #[test]
    fn chunked_survives_any_split_boundary() {
        // The acceptance-criteria wire test: the exact same chunked
        // message must parse identically no matter where the transport
        // splits it — including mid-chunk-size-line and mid-data.
        let wire = b"POST /v1/batch HTTP/1.1\r\nHost: x\r\n\
                     Transfer-Encoding: chunked\r\n\r\n\
                     a\r\n0123456789\r\n2;ext=1\r\nAB\r\n0\r\nT: v\r\n\r\n";
        for split in 0..wire.len() {
            let mut p = Parser::new();
            p.feed(&wire[..split]);
            // First half alone must never produce a *wrong* result.
            let first = p.next_request(1 << 20).unwrap();
            if let Some(r) = first {
                assert_eq!(split, wire.len(), "early message at {split}");
                assert_eq!(r.body, b"0123456789AB");
                continue;
            }
            p.feed(&wire[split..]);
            let r = p.next_request(1 << 20).unwrap().unwrap_or_else(|| {
                panic!("incomplete after full feed, split {split}")
            });
            assert_eq!(r.body, b"0123456789AB", "split {split}");
            assert_eq!(r.header("t"), Some("v"), "split {split}");
        }
        // Byte-at-a-time feed.
        let mut p = Parser::new();
        let mut got = None;
        for &b in wire.iter() {
            p.feed(&[b]);
            if let Some(r) = p.next_request(1 << 20).unwrap() {
                got = Some(r);
            }
        }
        assert_eq!(got.expect("byte-fed request").body, b"0123456789AB");
    }

    #[test]
    fn chunked_body_beyond_limit_is_413() {
        let mut p = Parser::new();
        p.feed(
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              10\r\n0123456789abcdef\r\n10\r\n",
        );
        let err = p.next_request(20).unwrap_err();
        assert_eq!(err.status(), 413, "{err}");
    }

    #[test]
    fn bad_chunk_framing_is_4xx() {
        // Bad hex size.
        let mut p = Parser::new();
        p.feed(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\n");
        assert_eq!(p.next_request(64).unwrap_err().status(), 400);
        // Missing CRLF after chunk data.
        let mut p = Parser::new();
        p.feed(
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              3\r\nabcXX\r\n",
        );
        assert_eq!(p.next_request(64).unwrap_err().status(), 400);
        // Unsupported coding.
        let mut p = Parser::new();
        p.feed(b"POST /x HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n");
        assert_eq!(p.next_request(64).unwrap_err().status(), 501);
        // Conflicting framing headers.
        let mut p = Parser::new();
        p.feed(
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\
              Content-Length: 3\r\n\r\n",
        );
        assert_eq!(p.next_request(64).unwrap_err().status(), 400);
    }

    #[test]
    fn response_roundtrips_through_client_parser() {
        let (client, mut server_stream) = pair();
        let resp = Response::json(
            200,
            &Json::Obj(
                [("ok".to_string(), Json::Bool(true))].into_iter().collect(),
            ),
        );
        // Serialize server->client, parse with the client-side reader.
        let mut server = HttpConn::new(server_stream.try_clone().unwrap());
        server.write_response(&resp, true).unwrap();
        server_stream.flush().unwrap();
        let mut c = HttpConn::new(client);
        let (status, headers, body) = c.read_response(1 << 20).unwrap();
        assert_eq!(status, 200);
        assert_eq!(
            headers.get("content-type").map(String::as_str),
            Some("application/json")
        );
        assert_eq!(body, br#"{"ok":true}"#);
    }

    #[test]
    fn keep_alive_serves_two_requests_on_one_connection() {
        let (mut client, server) = pair();
        client
            .write_all(
                b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\nConnection: close\r\n\r\n",
            )
            .unwrap();
        let mut conn = HttpConn::new(server);
        let a = match conn.read_request(64).unwrap() {
            Outcome::Request(r) => r,
            _ => panic!(),
        };
        let b = match conn.read_request(64).unwrap() {
            Outcome::Request(r) => r,
            _ => panic!(),
        };
        assert_eq!((a.path(), b.path()), ("/a", "/b"));
        assert!(a.keep_alive() && !b.keep_alive());
    }
}
