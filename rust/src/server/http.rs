//! Strict HTTP/1.1 wire layer (no external deps).
//!
//! Exactly the subset the activation service needs: request-line +
//! header parsing with hard limits, `Content-Length` bodies, keep-alive,
//! and a response writer that always emits `Content-Length`. Malformed
//! input maps to a 4xx via [`HttpError::status`]; chunked transfer
//! encoding is refused with 501. The same buffered-connection type also
//! implements the client side (used by [`super::loadgen`] and the e2e
//! tests), so requests and responses are parsed by one code path.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;

use crate::util::json::{self, Json};

/// Longest accepted request/status/header line, in bytes.
const MAX_LINE: usize = 8192;
/// Most headers accepted per message.
const MAX_HEADERS: usize = 64;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub target: String,
    pub version: String,
    /// Header names lowercased, values trimmed.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(String::as_str)
    }

    /// Path component of the target (query string stripped).
    pub fn path(&self) -> &str {
        self.target.split(['?', '#']).next().unwrap_or(&self.target)
    }

    /// HTTP/1.1 keep-alive semantics (1.0 requires opt-in).
    pub fn keep_alive(&self) -> bool {
        let conn = self
            .header("connection")
            .map(str::to_ascii_lowercase)
            .unwrap_or_default();
        if self.version == "HTTP/1.0" {
            conn == "keep-alive"
        } else {
            conn != "close"
        }
    }

    /// Body parsed as JSON, or a reason it can't be.
    pub fn json_body(&self) -> Result<Json, String> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| "body is not valid UTF-8".to_string())?;
        json::parse(text).map_err(|e| e.to_string())
    }
}

/// Protocol-level failure while reading a message.
#[derive(Debug)]
pub enum HttpError {
    /// Syntactically invalid input -> 400.
    Malformed(String),
    /// Mid-message read timeout (slow client) -> 408.
    Timeout(String),
    /// Line/header/body limits exceeded -> 431 or 413.
    TooLarge { what: String, status: u16 },
    /// Valid HTTP we refuse to implement (chunked) -> 501.
    Unsupported(String),
    /// Transport error; no response possible.
    Io(std::io::Error),
}

impl HttpError {
    /// The response status this error maps to (0 = connection is dead).
    pub fn status(&self) -> u16 {
        match self {
            HttpError::Malformed(_) => 400,
            HttpError::Timeout(_) => 408,
            HttpError::TooLarge { status, .. } => *status,
            HttpError::Unsupported(_) => 501,
            HttpError::Io(_) => 0,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Malformed(m) => write!(f, "malformed request: {m}"),
            HttpError::Timeout(m) => write!(f, "timeout: {m}"),
            HttpError::TooLarge { what, .. } => write!(f, "too large: {what}"),
            HttpError::Unsupported(m) => write!(f, "unsupported: {m}"),
            HttpError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

/// Result of waiting for the next request on a connection.
pub enum Outcome {
    Request(Request),
    /// Peer closed cleanly between requests.
    Closed,
    /// Read timeout with no bytes pending — caller decides whether the
    /// keep-alive idle budget is spent.
    IdleTimeout,
}

enum Line {
    Text(String),
    Eof,
    Idle,
}

/// A buffered HTTP connection (server or client side).
pub struct HttpConn {
    stream: TcpStream,
    buf: Vec<u8>,
    pos: usize,
}

impl HttpConn {
    pub fn new(stream: TcpStream) -> HttpConn {
        HttpConn { stream, buf: Vec::with_capacity(4096), pos: 0 }
    }

    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    fn buffered_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    /// Drop consumed bytes (called between messages).
    fn compact(&mut self) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Read more bytes from the socket into the buffer.
    fn fill(&mut self) -> std::io::Result<usize> {
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk)?;
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(n)
    }

    /// Next CRLF/LF-terminated line; classifies EOF and idle timeouts.
    fn next_line(&mut self, at_message_start: bool) -> Result<Line, HttpError> {
        loop {
            if let Some(off) =
                self.buf[self.pos..].iter().position(|&b| b == b'\n')
            {
                let end = self.pos + off;
                let mut line = &self.buf[self.pos..end];
                if line.last() == Some(&b'\r') {
                    line = &line[..line.len() - 1];
                }
                let text = String::from_utf8(line.to_vec()).map_err(|_| {
                    HttpError::Malformed("non-UTF-8 header bytes".into())
                })?;
                self.pos = end + 1;
                return Ok(Line::Text(text));
            }
            if self.buf.len() - self.pos > MAX_LINE {
                return Err(HttpError::TooLarge {
                    what: "header line exceeds 8 KiB".into(),
                    status: 431,
                });
            }
            match self.fill() {
                Ok(0) => {
                    return if self.buffered_empty() && at_message_start {
                        Ok(Line::Eof)
                    } else {
                        Err(HttpError::Malformed("unexpected eof".into()))
                    };
                }
                Ok(_) => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return if self.buffered_empty() && at_message_start {
                        Ok(Line::Idle)
                    } else {
                        Err(HttpError::Timeout("mid-message read stall".into()))
                    };
                }
                Err(e) => return Err(HttpError::Io(e)),
            }
        }
    }

    /// Read exactly `len` body bytes (headers already consumed).
    fn read_body(&mut self, len: usize) -> Result<Vec<u8>, HttpError> {
        while self.buf.len() - self.pos < len {
            match self.fill() {
                Ok(0) => {
                    return Err(HttpError::Malformed("eof in body".into()))
                }
                Ok(_) => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Err(HttpError::Timeout("body read stall".into()));
                }
                Err(e) => return Err(HttpError::Io(e)),
            }
        }
        let body = self.buf[self.pos..self.pos + len].to_vec();
        self.pos += len;
        Ok(body)
    }

    /// Shared header-block reader (server requests + client responses).
    fn read_headers(&mut self) -> Result<BTreeMap<String, String>, HttpError> {
        let mut headers = BTreeMap::new();
        loop {
            let Line::Text(line) = self.next_line(false)? else {
                return Err(HttpError::Malformed("eof in headers".into()));
            };
            if line.is_empty() {
                return Ok(headers);
            }
            if headers.len() >= MAX_HEADERS {
                return Err(HttpError::TooLarge {
                    what: "more than 64 headers".into(),
                    status: 431,
                });
            }
            let (name, value) = line.split_once(':').ok_or_else(|| {
                HttpError::Malformed(format!("header without ':': {line:?}"))
            })?;
            if name.is_empty()
                || !name.bytes().all(|b| b.is_ascii_graphic() && b != b':')
            {
                return Err(HttpError::Malformed(format!(
                    "invalid header name {name:?}"
                )));
            }
            headers.insert(name.to_ascii_lowercase(), value.trim().to_string());
        }
    }

    fn body_from_headers(
        &mut self,
        headers: &BTreeMap<String, String>,
        max_body: usize,
    ) -> Result<Vec<u8>, HttpError> {
        if headers.contains_key("transfer-encoding") {
            return Err(HttpError::Unsupported(
                "transfer-encoding (use Content-Length)".into(),
            ));
        }
        let len = match headers.get("content-length") {
            None => 0,
            Some(v) => v.parse::<usize>().map_err(|_| {
                HttpError::Malformed(format!("bad content-length {v:?}"))
            })?,
        };
        if len > max_body {
            return Err(HttpError::TooLarge {
                what: format!("body of {len} bytes (limit {max_body})"),
                status: 413,
            });
        }
        self.read_body(len)
    }

    /// Server side: wait for the next request.
    pub fn read_request(&mut self, max_body: usize) -> Result<Outcome, HttpError> {
        self.compact();
        // Request line (tolerate a stray CRLF after the previous message).
        let mut blanks = 0;
        let line = loop {
            match self.next_line(true)? {
                Line::Eof => return Ok(Outcome::Closed),
                Line::Idle => return Ok(Outcome::IdleTimeout),
                Line::Text(t) if t.is_empty() => {
                    blanks += 1;
                    if blanks > 2 {
                        return Err(HttpError::Malformed(
                            "blank lines before request line".into(),
                        ));
                    }
                }
                Line::Text(t) => break t,
            }
        };
        let mut parts = line.split(' ');
        let (method, target, version) =
            match (parts.next(), parts.next(), parts.next(), parts.next()) {
                (Some(m), Some(t), Some(v), None)
                    if !m.is_empty() && !t.is_empty() =>
                {
                    (m.to_string(), t.to_string(), v.to_string())
                }
                _ => {
                    return Err(HttpError::Malformed(format!(
                        "bad request line {line:?}"
                    )))
                }
            };
        if !method.bytes().all(|b| b.is_ascii_uppercase()) {
            return Err(HttpError::Malformed(format!("bad method {method:?}")));
        }
        if !target.starts_with('/') {
            return Err(HttpError::Malformed(format!("bad target {target:?}")));
        }
        if version != "HTTP/1.1" && version != "HTTP/1.0" {
            return Err(HttpError::Malformed(format!(
                "unsupported version {version:?}"
            )));
        }
        let headers = self.read_headers()?;
        let body = self.body_from_headers(&headers, max_body)?;
        Ok(Outcome::Request(Request { method, target, version, headers, body }))
    }

    /// Server side: serialize a response.
    pub fn write_response(
        &mut self,
        resp: &Response,
        keep_alive: bool,
    ) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
             Connection: {}\r\n\r\n",
            resp.status,
            reason(resp.status),
            resp.content_type,
            resp.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        // One write_all for head+body: no mid-message gap for the peer's
        // read timeout to land in.
        let mut msg = head.into_bytes();
        msg.extend_from_slice(&resp.body);
        self.stream.write_all(&msg)?;
        self.stream.flush()
    }

    /// Client side: serialize a request (always keep-alive).
    pub fn write_request(
        &mut self,
        method: &str,
        target: &str,
        body: &[u8],
    ) -> std::io::Result<()> {
        let host = self
            .stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "localhost".into());
        let head = format!(
            "{method} {target} HTTP/1.1\r\nHost: {host}\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\
             Connection: keep-alive\r\n\r\n",
            body.len(),
        );
        let mut msg = head.into_bytes();
        msg.extend_from_slice(body);
        self.stream.write_all(&msg)?;
        self.stream.flush()
    }

    /// Client side: read a status + headers + body response.
    pub fn read_response(
        &mut self,
        max_body: usize,
    ) -> Result<(u16, BTreeMap<String, String>, Vec<u8>), HttpError> {
        self.compact();
        let line = match self.next_line(true)? {
            Line::Text(t) => t,
            Line::Eof => {
                return Err(HttpError::Malformed("closed before response".into()))
            }
            Line::Idle => {
                return Err(HttpError::Timeout("waiting for response".into()))
            }
        };
        let mut parts = line.splitn(3, ' ');
        let (version, code) = (parts.next().unwrap_or(""), parts.next());
        if !version.starts_with("HTTP/1.") {
            return Err(HttpError::Malformed(format!(
                "bad status line {line:?}"
            )));
        }
        let status: u16 = code
            .and_then(|c| c.parse().ok())
            .ok_or_else(|| {
                HttpError::Malformed(format!("bad status line {line:?}"))
            })?;
        let headers = self.read_headers()?;
        let body = self.body_from_headers(&headers, max_body)?;
        Ok((status, headers, body))
    }
}

/// An HTTP response about to be serialized.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json".into(),
            body: json::write(v).into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8".into(),
            body: body.as_bytes().to_vec(),
        }
    }
}

/// Canonical reason phrases for the statuses the service emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    /// Loopback socket pair for exercising the parser on real streams.
    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    fn feed(bytes: &[u8]) -> Result<Outcome, HttpError> {
        let (mut client, server) = pair();
        client.write_all(bytes).unwrap();
        drop(client); // EOF terminates the message cleanly for the parser
        HttpConn::new(server).read_request(1 << 20)
    }

    #[test]
    fn parses_post_with_body() {
        let req = feed(
            b"POST /v1/eval HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd",
        );
        match req.unwrap() {
            Outcome::Request(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path(), "/v1/eval");
                assert_eq!(r.body, b"abcd");
                assert!(r.keep_alive());
            }
            _ => panic!("expected request"),
        }
    }

    #[test]
    fn query_string_is_stripped_and_close_honoured() {
        let out = feed(
            b"GET /metrics?x=1 HTTP/1.1\r\nConnection: close\r\n\r\n",
        );
        match out.unwrap() {
            Outcome::Request(r) => {
                assert_eq!(r.path(), "/metrics");
                assert!(!r.keep_alive());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn clean_eof_is_closed() {
        match feed(b"") {
            Ok(Outcome::Closed) => {}
            other => panic!("{other:?}", other = other.map(|_| "req")),
        }
    }

    #[test]
    fn garbage_is_malformed() {
        for bad in [
            &b"NOT AN HTTP REQUEST\r\n\r\n"[..],
            b"GET\r\n\r\n",
            b"get /x HTTP/1.1\r\n\r\n",
            b"GET x HTTP/1.1\r\n\r\n",
            b"GET /x HTTP/2\r\n\r\n",
            b"GET /x HTTP/1.1\r\nbad header\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort",
        ] {
            let err = match feed(bad) {
                Err(e) => e,
                Ok(Outcome::Request(r)) => panic!("parsed {bad:?} as {r:?}"),
                Ok(_) => panic!("{bad:?} not treated as malformed"),
            };
            assert_eq!(err.status(), 400, "{bad:?} -> {err}");
        }
    }

    #[test]
    fn oversize_body_is_413_and_chunked_501() {
        let err = feed(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\n")
            .map(|_| ())
            .unwrap_err();
        // parsed against a 16-byte limit
        let (mut client, server) = pair();
        client
            .write_all(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\n")
            .unwrap();
        drop(client);
        let err413 = HttpConn::new(server).read_request(16).unwrap_err();
        assert_eq!(err413.status(), 413);
        drop(err);

        let err501 = feed(
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        )
        .map(|_| ())
        .unwrap_err();
        assert_eq!(err501.status(), 501);
    }

    #[test]
    fn response_roundtrips_through_client_parser() {
        let (client, mut server_stream) = pair();
        let resp = Response::json(
            200,
            &Json::Obj(
                [("ok".to_string(), Json::Bool(true))].into_iter().collect(),
            ),
        );
        // Serialize server->client, parse with the client-side reader.
        let mut server = HttpConn::new(server_stream.try_clone().unwrap());
        server.write_response(&resp, true).unwrap();
        server_stream.flush().unwrap();
        let mut c = HttpConn::new(client);
        let (status, headers, body) = c.read_response(1 << 20).unwrap();
        assert_eq!(status, 200);
        assert_eq!(
            headers.get("content-type").map(String::as_str),
            Some("application/json")
        );
        assert_eq!(body, br#"{"ok":true}"#);
    }

    #[test]
    fn keep_alive_serves_two_requests_on_one_connection() {
        let (mut client, server) = pair();
        client
            .write_all(
                b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\nConnection: close\r\n\r\n",
            )
            .unwrap();
        let mut conn = HttpConn::new(server);
        let a = match conn.read_request(64).unwrap() {
            Outcome::Request(r) => r,
            _ => panic!(),
        };
        let b = match conn.read_request(64).unwrap() {
            Outcome::Request(r) => r,
            _ => panic!(),
        };
        assert_eq!((a.path(), b.path()), ("/a", "/b"));
        assert!(a.keep_alive() && !b.keep_alive());
    }
}
