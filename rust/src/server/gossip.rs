//! SWIM-lite gossip membership for the cluster tier (zero deps, over
//! the existing HTTP plane).
//!
//! PR 4's cluster learned its node set once, from `--peers` flags.
//! This module makes membership *dynamic*: every node keeps a table of
//! `addr -> (incarnation, alive)` and periodically exchanges the whole
//! table with one peer via `POST /v1/gossip` (full-state anti-entropy —
//! the clusters this tier targets are a handful of fronts, so full
//! state per round costs a few hundred bytes and converges in O(log n)
//! rounds without SWIM's infection-style piggybacking). A node started
//! with only `--join <seed>` announces itself to the seed, merges the
//! response, and from then on participates like any statically
//! configured peer — `--peers` is just the bootstrap special case of a
//! pre-populated table.
//!
//! The SWIM ideas kept ("lite"):
//!
//! * **Incarnation numbers.** Each node stamps itself with a
//!   wall-clock-derived incarnation at startup. A higher incarnation
//!   always wins a merge, so a restarted node supersedes its own stale
//!   entries everywhere without coordination.
//! * **Death certificates beat life at equal incarnation.** Ties break
//!   toward `alive = false`; only a *newer* incarnation resurrects.
//!   Dead entries are kept (not purged) so a late gossip of an old
//!   death can't re-add a removed node.
//! * **Refutation.** A node that sees itself reported dead bumps its
//!   own incarnation past the report and gossips the refutation.
//! * **Suspicion reuse.** Short outages are handled by the existing
//!   probe thread's eviction/re-admission thresholds (routing-level,
//!   never gossiped); only *sustained* failure — the same
//!   `failure_threshold`, times [`DEATH_FACTOR`] — declares a member
//!   dead and disseminates it. Direct observation can resurrect: a
//!   dead member that answers probes again is re-declared alive with a
//!   bumped incarnation (the prober acts as the unreachable node's
//!   proxy-refuter, which keeps gossip-free static peers rejoinable).
//!
//! Membership (this module) and health (the peer table in
//! [`super::cluster`]) are deliberately separate planes: membership
//! decides *who is in the ring*, health decides *who is routable right
//! now*. Ring rebuilds happen only on membership changes, so routing
//! stays a pure function of the alive-member set.
//!
//! **Load piggybacking (PR 10).** Member entries optionally carry a
//! versioned load stanza (`load: {v, q, lat_us, arena_b}`) so every
//! gossip exchange doubles as a load report: run-queue depth, EWMA
//! request latency, and arena bytes, stamped with a per-origin monotone
//! version so relayed third-party reports keep freshness order. The
//! stanza is *advisory*: it never changes membership outcomes, a
//! malformed stanza is ignored rather than rejected, and a missing one
//! means "load unknown" (pre-PR-10 nodes) — such peers are excluded
//! from power-of-two-choices routing but remain fully routable.
//! Messages may also carry a `routes` list of hot-route replica claims
//! (`{route, replicas, epoch}`); claims merge by lexicographic
//! `(epoch, replicas)` max, a join-semilattice, so partitioned nodes
//! that both raised a route converge to one winner after heal. Both
//! additions ride protocol v1 as optional keys: old decoders read only
//! the keys they know and round-trip cleanly.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Wire path for gossip exchanges (handled in [`super::api`]).
pub const GOSSIP_PATH: &str = "/v1/gossip";

/// Gossip protocol version tag (reject anything newer).
pub const GOSSIP_VERSION: u64 = 1;

/// Largest accepted incarnation: 2^53, the f64-exact integer ceiling
/// (exactly representable, so the wire check and the `as u64` cast
/// agree). Internal bumps ([`merge`]'s refutation and the prober's
/// resurrection) clamp here too — a node pushed to the ceiling must
/// still emit *decodable* gossip rather than poison every message it
/// sends. Wall-clock-millis incarnations sit ~5 orders of magnitude
/// below this.
pub const MAX_INCARNATION: u64 = 1 << 53;

/// Cap on *alive* members (ring size / probe fan-out). Gossip is
/// perimeter-trusted (like the rest of the HTTP plane); the cap bounds
/// what one crafted message can do to the ring and the probe round, at
/// an order of magnitude above any realistic front count. Tombstones
/// do not count against it — long-lived clusters with address churn
/// must keep accepting joins.
pub const MAX_MEMBERS: usize = 256;

/// Total table bound, tombstones included, and the per-message wire
/// cap. When the table is full, unknown *tombstone* imports are
/// dropped first (they are merely protective: at worst a stale alive
/// claim re-adds a dead member, which then dies again by probing).
pub const MAX_TABLE: usize = 1024;

/// Request-body cap for `POST /v1/gossip`, rejected with 413 above it.
/// A maximal legitimate message is `MAX_TABLE` entries of address +
/// incarnation + flag — generously under 256 KiB — so anything bigger
/// is garbage or abuse and must not be buffered toward the server-wide
/// body limit on the control plane.
pub const MAX_GOSSIP_BODY: usize = 256 * 1024;

/// Consecutive probe failures that declare a member dead, as a
/// multiple of the routing-eviction threshold. Eviction (routing skips
/// the peer) is cheap to undo, so it fires fast; death (ring rebuild,
/// disseminated) is expensive to get wrong, so it fires an order of
/// magnitude later.
pub const DEATH_FACTOR: u32 = 10;

/// Wire cap on hot-route replica claims per message. Routes come from
/// the `--routes` flag (a handful), so the cap is an order of
/// magnitude above any real deployment; excess claims are dropped, not
/// fatal — membership must merge even from a node abusing the stanza.
pub const MAX_ROUTE_OVERRIDES: usize = 64;

/// Longest accepted route name in a replica claim (matches the route
/// table's own sanity bound; longer names are crafted, skip them).
pub const MAX_ROUTE_NAME: usize = 128;

/// One row of the membership table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Member {
    /// Startup stamp of the node (millis since epoch, or the test
    /// override); higher always wins a merge.
    pub incarnation: u64,
    /// Dead members stay in the table as tombstones but leave the
    /// ring.
    pub alive: bool,
}

/// A node's self-reported load, piggybacked on its member entry.
///
/// `version` is a per-origin monotone counter bumped at every local
/// sample; merges keep the higher version, so a report relayed through
/// a third node can never roll a fresher direct report back.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoadInfo {
    /// Freshness stamp (per-origin monotone counter, not wall clock).
    pub version: u64,
    /// In-flight local requests (run-queue depth proxy).
    pub queue_depth: u64,
    /// EWMA of local request service latency, microseconds.
    pub ewma_latency_us: u64,
    /// Bytes parked in the node's word arenas.
    pub arena_bytes: u64,
}

/// One member as carried on the wire.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MemberEntry {
    pub addr: String,
    pub incarnation: u64,
    pub alive: bool,
    /// `None` = load unknown (pre-PR-10 sender, or nothing learned
    /// yet). Unknown-load peers are excluded from p2c selection.
    pub load: Option<LoadInfo>,
}

/// A hot-route replica-count claim: "route X runs at `replicas`
/// effective replicas as of `epoch`". Ordered lexicographically by
/// `(epoch, replicas)`; merges keep the max, so concurrent claims from
/// a partitioned cluster converge to one winner deterministically.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct RouteClaim {
    pub epoch: u64,
    pub replicas: u64,
}

/// One route claim as carried on the wire.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteOverride {
    pub route: String,
    pub claim: RouteClaim,
}

/// A decoded gossip message (request and response share the shape).
#[derive(Clone, Debug)]
pub struct GossipMsg {
    /// Sender's advertised identity (it also appears in `members`).
    pub from: String,
    pub members: Vec<MemberEntry>,
    /// Hot-route replica claims (empty from pre-PR-10 senders).
    pub routes: Vec<RouteOverride>,
}

/// What a merge changed — the caller rebuilds the ring iff
/// `ring_changed`.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct MergeOutcome {
    /// The alive-member set changed (join, death, or resurrection).
    pub ring_changed: bool,
    /// Addresses newly added to the table — alive joins and imported
    /// tombstones alike (the caller checks the table for aliveness).
    pub added: Vec<String>,
    /// Members that *transitioned* alive → dead in this merge. Unknown
    /// members imported already-dead are not listed: they are not
    /// death events this node observed, only inherited history.
    pub died: Vec<String>,
    /// Tombstones flipped back alive by a newer incarnation (the
    /// restart/rejoin path — they need their health slots back).
    pub resurrected: Vec<String>,
    /// This node saw itself reported dead and bumped its incarnation.
    pub refuted: bool,
    /// Tombstones evicted to admit joins at the table bound
    /// (surfaced as `tanhvf_cluster_tombstone_evictions_total`).
    pub evicted_tombstones: u64,
}

/// Merge a remote member list into `table`. `self_addr`/`self_inc`
/// identify the local node; on refutation `self_inc` is bumped past
/// the dead report and the table's own entry is refreshed.
///
/// Pure table logic — locking, ring rebuilds, and peer-slot bookkeeping
/// stay in [`super::cluster::Cluster`].
pub fn merge(
    table: &mut BTreeMap<String, Member>,
    self_addr: &str,
    self_inc: &mut u64,
    remote: &[MemberEntry],
) -> MergeOutcome {
    let mut out = MergeOutcome::default();
    for e in remote {
        if e.addr == self_addr {
            // Refutation: only we may assert our own liveness. A dead
            // report at `inc >= ours` would otherwise win ties forever.
            // (Saturating: an at-the-limit report must not overflow —
            // decode bounds the wire value, this guards direct callers.)
            if !e.alive && e.incarnation >= *self_inc {
                *self_inc =
                    e.incarnation.saturating_add(1).min(MAX_INCARNATION);
                table.insert(
                    self_addr.to_string(),
                    Member { incarnation: *self_inc, alive: true },
                );
                out.refuted = true;
                out.ring_changed = true; // our ring entry was contested
            }
            continue;
        }
        match table.get_mut(&e.addr) {
            None => {
                // Bounded growth: alive members against MAX_MEMBERS
                // (tombstones excluded, so churn can't block joins),
                // everything against MAX_TABLE. At the table bound a
                // join evicts one tombstone to make room — dropping a
                // tombstone is merely un-protective (a stale alive
                // claim could re-add the dead member, which then dies
                // again by probing), whereas refusing joins forever
                // would freeze a long-lived cluster's growth.
                if e.alive {
                    if table.values().filter(|m| m.alive).count()
                        >= MAX_MEMBERS
                    {
                        continue;
                    }
                    if table.len() >= MAX_TABLE {
                        let victim = table
                            .iter()
                            .find(|(_, m)| !m.alive)
                            .map(|(a, _)| a.clone());
                        match victim {
                            Some(v) => {
                                table.remove(&v);
                                out.evicted_tombstones += 1;
                            }
                            None => continue,
                        }
                    }
                } else if table.len() >= MAX_TABLE {
                    // Never evict anything for an incoming tombstone.
                    continue;
                }
                table.insert(
                    e.addr.clone(),
                    Member { incarnation: e.incarnation, alive: e.alive },
                );
                out.added.push(e.addr.clone());
                if e.alive {
                    out.ring_changed = true;
                }
            }
            Some(m) => {
                let newer = e.incarnation > m.incarnation
                    || (e.incarnation == m.incarnation
                        && !e.alive
                        && m.alive);
                if newer {
                    if e.alive != m.alive {
                        out.ring_changed = true;
                        if e.alive {
                            out.resurrected.push(e.addr.clone());
                        } else {
                            out.died.push(e.addr.clone());
                        }
                    }
                    m.incarnation = e.incarnation;
                    m.alive = e.alive;
                }
            }
        }
    }
    out
}

/// Merge relayed load reports into the local load view. Pure freshness
/// logic: a report wins iff its version is strictly higher than what
/// we hold. The local node's own entry is skipped (we are the origin
/// of our load; a relay can only be stale). Returns `true` if any
/// entry changed — callers refresh their read-path snapshot then.
///
/// Load never touches membership: dead members keep their last report
/// here until the caller prunes it, and a report about an address we
/// have never heard of is still stored (the member entry that carried
/// it merges in the same message).
pub fn merge_loads(
    loads: &mut BTreeMap<String, LoadInfo>,
    self_addr: &str,
    remote: &[MemberEntry],
) -> bool {
    let mut changed = false;
    for e in remote {
        if e.addr == self_addr {
            continue;
        }
        let Some(load) = e.load else { continue };
        match loads.get_mut(&e.addr) {
            Some(cur) if cur.version >= load.version => {}
            Some(cur) => {
                *cur = load;
                changed = true;
            }
            None => {
                loads.insert(e.addr.clone(), load);
                changed = true;
            }
        }
    }
    changed
}

/// Merge remote hot-route claims into the local claim table: keep the
/// lexicographic `(epoch, replicas)` max per route. Join-semilattice
/// merge — commutative, associative, idempotent — so any gossip order
/// (including claims raised on both sides of a partition) converges
/// every node to the same winner. Returns `true` if any claim changed.
pub fn merge_route_claims(
    claims: &mut BTreeMap<String, RouteClaim>,
    remote: &[RouteOverride],
) -> bool {
    let mut changed = false;
    for r in remote.iter().take(MAX_ROUTE_OVERRIDES) {
        match claims.get_mut(&r.route) {
            Some(cur) if *cur >= r.claim => {}
            Some(cur) => {
                *cur = r.claim;
                changed = true;
            }
            None => {
                claims.insert(r.route.clone(), r.claim);
                changed = true;
            }
        }
    }
    changed
}

/// Serialize a membership snapshot as the gossip wire message.
///
/// Load stanzas and route claims are emitted only where present, as
/// optional v1 keys: a pre-PR-10 decoder reads `addr`/`incarnation`/
/// `alive` and ignores the rest, so mixed-version clusters keep
/// converging on membership.
pub fn encode(
    from: &str,
    members: &[MemberEntry],
    routes: &[RouteOverride],
) -> Json {
    let members = members
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("addr".to_string(), Json::Str(e.addr.clone())),
                ("incarnation".to_string(), Json::Num(e.incarnation as f64)),
                ("alive".to_string(), Json::Bool(e.alive)),
            ];
            if let Some(l) = &e.load {
                fields.push((
                    "load".to_string(),
                    Json::Obj(
                        [
                            ("v".to_string(), Json::Num(l.version as f64)),
                            ("q".to_string(), Json::Num(l.queue_depth as f64)),
                            (
                                "lat_us".to_string(),
                                Json::Num(l.ewma_latency_us as f64),
                            ),
                            (
                                "arena_b".to_string(),
                                Json::Num(l.arena_bytes as f64),
                            ),
                        ]
                        .into_iter()
                        .collect(),
                    ),
                ));
            }
            Json::Obj(fields.into_iter().collect())
        })
        .collect();
    let mut top = vec![
        ("v".to_string(), Json::Num(GOSSIP_VERSION as f64)),
        ("from".to_string(), Json::Str(from.to_string())),
        ("members".to_string(), Json::Arr(members)),
    ];
    if !routes.is_empty() {
        let routes = routes
            .iter()
            .take(MAX_ROUTE_OVERRIDES)
            .map(|r| {
                Json::Obj(
                    [
                        ("route".to_string(), Json::Str(r.route.clone())),
                        (
                            "replicas".to_string(),
                            Json::Num(r.claim.replicas as f64),
                        ),
                        ("epoch".to_string(), Json::Num(r.claim.epoch as f64)),
                    ]
                    .into_iter()
                    .collect(),
                )
            })
            .collect();
        top.push(("routes".to_string(), Json::Arr(routes)));
    }
    Json::Obj(top.into_iter().collect())
}

/// Read one non-negative f64-exact integer field out of an advisory
/// stanza. `None` on absence or anything out of bounds — advisory
/// data is dropped, never fatal.
fn advisory_u64(obj: &Json, key: &str) -> Option<u64> {
    obj.get(key)
        .and_then(Json::as_f64)
        .filter(|n| {
            *n >= 0.0 && *n <= MAX_INCARNATION as f64 && n.fract() == 0.0
        })
        .map(|n| n as u64)
}

/// Parse a member's optional load stanza. Missing or malformed both
/// yield `None` ("load unknown"): the stanza is advisory, so a crafted
/// or future-shaped stanza must not reject the membership data riding
/// in the same message.
fn decode_load(m: &Json) -> Option<LoadInfo> {
    let l = m.get("load")?;
    Some(LoadInfo {
        version: advisory_u64(l, "v")?,
        queue_depth: advisory_u64(l, "q")?,
        ewma_latency_us: advisory_u64(l, "lat_us")?,
        arena_bytes: advisory_u64(l, "arena_b")?,
    })
}

/// Parse the optional top-level route-claim list. Same advisory
/// posture as the load stanza: malformed entries are skipped, the list
/// is capped at [`MAX_ROUTE_OVERRIDES`], and a replica count outside
/// `1..=MAX_MEMBERS` is crafted (no ring can satisfy it) so the entry
/// is dropped.
fn decode_routes(body: &Json) -> Vec<RouteOverride> {
    let Some(arr) = body.get("routes").and_then(Json::as_arr) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for r in arr {
        if out.len() >= MAX_ROUTE_OVERRIDES {
            break;
        }
        let Some(route) = r.get("route").and_then(Json::as_str) else {
            continue;
        };
        if route.is_empty() || route.len() > MAX_ROUTE_NAME {
            continue;
        }
        let (Some(replicas), Some(epoch)) =
            (advisory_u64(r, "replicas"), advisory_u64(r, "epoch"))
        else {
            continue;
        };
        if replicas == 0 || replicas > MAX_MEMBERS as u64 {
            continue;
        }
        out.push(RouteOverride {
            route: route.to_string(),
            claim: RouteClaim { epoch, replicas },
        });
    }
    out
}

/// Parse and validate a gossip wire message.
pub fn decode(body: &Json) -> Result<GossipMsg, String> {
    let v = body
        .get("v")
        .and_then(Json::as_f64)
        .ok_or("gossip: missing protocol version")? as u64;
    if v > GOSSIP_VERSION {
        return Err(format!("gossip: unsupported protocol version {v}"));
    }
    let from = body
        .get("from")
        .and_then(Json::as_str)
        .ok_or("gossip: missing from")?
        .to_string();
    let arr = body
        .get("members")
        .and_then(Json::as_arr)
        .ok_or("gossip: missing members array")?;
    if arr.len() > MAX_TABLE {
        return Err(format!(
            "gossip: {} members exceeds the {MAX_TABLE} cap",
            arr.len()
        ));
    }
    let mut members = Vec::with_capacity(arr.len());
    for m in arr {
        let addr = m
            .get("addr")
            .and_then(Json::as_str)
            .ok_or("gossip: member without addr")?
            .to_string();
        // Bounded to [0, MAX_INCARNATION]: a crafted huge incarnation
        // would otherwise saturate the `as u64` cast to u64::MAX and
        // freeze the conflict-resolution order (nothing could ever
        // supersede it).
        let incarnation = m
            .get("incarnation")
            .and_then(Json::as_f64)
            .filter(|n| {
                *n >= 0.0 && *n <= MAX_INCARNATION as f64 && n.fract() == 0.0
            })
            .ok_or("gossip: member incarnation not an integer in bounds")?
            as u64;
        let alive = match m.get("alive") {
            Some(Json::Bool(b)) => *b,
            _ => return Err("gossip: member without alive flag".into()),
        };
        // Death certificates are clamped one below the ceiling so a
        // refutation bump always has headroom: an at-the-ceiling death
        // would otherwise win its tie-break forever and the victim
        // could never rejoin.
        let incarnation = if alive {
            incarnation
        } else {
            incarnation.min(MAX_INCARNATION - 1)
        };
        let load = decode_load(m);
        members.push(MemberEntry { addr, incarnation, alive, load });
    }
    let routes = decode_routes(body);
    Ok(GossipMsg { from, members, routes })
}

#[cfg(test)]
mod tests {
    use super::*;

    const ME: &str = "10.0.0.1:1";

    fn table(entries: &[(&str, u64, bool)]) -> BTreeMap<String, Member> {
        entries
            .iter()
            .map(|&(a, incarnation, alive)| {
                (a.to_string(), Member { incarnation, alive })
            })
            .collect()
    }

    fn entry(addr: &str, incarnation: u64, alive: bool) -> MemberEntry {
        MemberEntry { addr: addr.to_string(), incarnation, alive, load: None }
    }

    fn load(version: u64, queue_depth: u64) -> LoadInfo {
        LoadInfo {
            version,
            queue_depth,
            ewma_latency_us: 10 * queue_depth,
            arena_bytes: 100 * queue_depth,
        }
    }

    fn loaded(addr: &str, incarnation: u64, l: LoadInfo) -> MemberEntry {
        MemberEntry {
            addr: addr.to_string(),
            incarnation,
            alive: true,
            load: Some(l),
        }
    }

    #[test]
    fn unknown_members_are_added_and_change_the_ring() {
        let mut t = table(&[(ME, 5, true)]);
        let mut inc = 5;
        let out = merge(&mut t, ME, &mut inc, &[entry("10.0.0.2:1", 7, true)]);
        assert!(out.ring_changed);
        assert_eq!(out.added, vec!["10.0.0.2:1"]);
        assert_eq!(t["10.0.0.2:1"], Member { incarnation: 7, alive: true });
    }

    #[test]
    fn higher_incarnation_wins_lower_is_ignored() {
        let mut t = table(&[(ME, 5, true), ("b:1", 10, true)]);
        let mut inc = 5;
        // Stale news: ignored entirely.
        let out = merge(&mut t, ME, &mut inc, &[entry("b:1", 9, false)]);
        assert!(!out.ring_changed);
        assert!(t["b:1"].alive);
        // Newer incarnation flips it.
        let out = merge(&mut t, ME, &mut inc, &[entry("b:1", 11, false)]);
        assert!(out.ring_changed);
        assert_eq!(out.died, vec!["b:1"]);
        assert!(!t["b:1"].alive);
        // And a yet-newer incarnation resurrects (node restarted).
        let out = merge(&mut t, ME, &mut inc, &[entry("b:1", 12, true)]);
        assert!(out.ring_changed);
        assert_eq!(out.resurrected, vec!["b:1"]);
        assert!(t["b:1"].alive);
    }

    #[test]
    fn death_beats_life_at_equal_incarnation() {
        let mut t = table(&[(ME, 5, true), ("b:1", 10, true)]);
        let mut inc = 5;
        let out = merge(&mut t, ME, &mut inc, &[entry("b:1", 10, false)]);
        assert!(out.ring_changed && !t["b:1"].alive);
        // The reverse tie (alive at the same incarnation) must NOT
        // resurrect — only a new incarnation can.
        let out = merge(&mut t, ME, &mut inc, &[entry("b:1", 10, true)]);
        assert!(!out.ring_changed && !t["b:1"].alive);
    }

    #[test]
    fn dead_unknowns_become_tombstones_not_ring_members() {
        let mut t = table(&[(ME, 5, true)]);
        let mut inc = 5;
        let out = merge(&mut t, ME, &mut inc, &[entry("gone:1", 3, false)]);
        assert!(!out.ring_changed, "a tombstone must not rebuild the ring");
        assert!(!t["gone:1"].alive);
        // Late arrival of the old alive claim can't resurrect it.
        let out = merge(&mut t, ME, &mut inc, &[entry("gone:1", 3, true)]);
        assert!(!out.ring_changed && !t["gone:1"].alive);
    }

    #[test]
    fn member_table_growth_is_capped() {
        let mut t = table(&[(ME, 5, true)]);
        let mut inc = 5;
        let flood: Vec<MemberEntry> = (0..(MAX_MEMBERS + 50))
            .map(|i| {
                entry(&format!("10.1.{}.{}:1", i / 256, i % 256), 1, true)
            })
            .collect();
        merge(&mut t, ME, &mut inc, &flood);
        assert!(t.len() <= MAX_MEMBERS, "table grew to {}", t.len());
        // Known members still merge normally at the cap.
        let known =
            t.keys().find(|k| k.as_str() != ME).unwrap().clone();
        let out = merge(&mut t, ME, &mut inc, &[entry(&known, 99, false)]);
        assert!(out.ring_changed && !t[&known].alive);
    }

    #[test]
    fn full_table_evicts_a_tombstone_for_a_join() {
        // Table at MAX_TABLE, mostly tombstones: a fresh alive join
        // must still be admitted (one tombstone evicted), and an
        // incoming tombstone must not evict anything.
        let mut t = table(&[(ME, 5, true)]);
        let mut inc = 5;
        for i in 0..(MAX_TABLE - 1) {
            t.insert(
                format!("10.3.{}.{}:1", i / 256, i % 256),
                Member { incarnation: 1, alive: false },
            );
        }
        assert_eq!(t.len(), MAX_TABLE);
        let out = merge(&mut t, ME, &mut inc, &[entry("fresh:1", 9, true)]);
        assert!(out.ring_changed, "join refused at the table bound");
        assert!(t["fresh:1"].alive);
        assert_eq!(t.len(), MAX_TABLE, "a tombstone must have been evicted");
        assert_eq!(out.evicted_tombstones, 1);
        let before = t.len();
        merge(&mut t, ME, &mut inc, &[entry("late-tomb:1", 9, false)]);
        assert_eq!(t.len(), before, "tombstone import must not evict");
    }

    #[test]
    fn ceiling_death_certificate_is_refutable() {
        // decode clamps dead certs below MAX_INCARNATION, so the
        // refutation bump always has headroom.
        let json = encode(
            "a:1",
            &[MemberEntry {
                addr: ME.to_string(),
                incarnation: MAX_INCARNATION,
                alive: false,
                load: None,
            }],
            &[],
        );
        let msg = decode(&json).unwrap();
        assert_eq!(msg.members[0].incarnation, MAX_INCARNATION - 1);
        let mut t = table(&[(ME, 5, true)]);
        let mut inc = 5;
        let out = merge(&mut t, ME, &mut inc, &msg.members);
        assert!(out.refuted);
        assert_eq!(inc, MAX_INCARNATION, "bump must exceed the cert");
        assert!(t[ME].alive);
    }

    #[test]
    fn tombstones_do_not_block_new_joins() {
        // A long-lived table full of departed members must keep
        // accepting fresh alive joins (the alive cap ignores
        // tombstones).
        let mut t = table(&[(ME, 5, true)]);
        let mut inc = 5;
        let dead: Vec<MemberEntry> = (0..(MAX_MEMBERS + 20))
            .map(|i| {
                entry(&format!("10.2.{}.{}:1", i / 256, i % 256), 1, false)
            })
            .collect();
        merge(&mut t, ME, &mut inc, &dead);
        assert!(t.len() > MAX_MEMBERS, "tombstones should be retained");
        let out =
            merge(&mut t, ME, &mut inc, &[entry("fresh:1", 9, true)]);
        assert!(out.ring_changed, "join blocked by tombstones");
        assert!(t["fresh:1"].alive);
    }

    #[test]
    fn self_death_report_is_refuted_with_a_bumped_incarnation() {
        let mut t = table(&[(ME, 5, true), ("b:1", 1, true)]);
        let mut inc = 5;
        let out = merge(&mut t, ME, &mut inc, &[entry(ME, 8, false)]);
        assert!(out.refuted);
        assert_eq!(inc, 9, "incarnation must jump past the death report");
        assert_eq!(t[ME], Member { incarnation: 9, alive: true });
        // An older report about ourselves is ignored.
        let out = merge(&mut t, ME, &mut inc, &[entry(ME, 4, false)]);
        assert!(!out.refuted && inc == 9);
    }

    #[test]
    fn wire_roundtrip_preserves_the_table() {
        let entries = vec![
            entry("a:1", 17, true),
            entry("b:2", 99, false),
            loaded("c:3", 3, load(7, 42)),
        ];
        let routes = vec![RouteOverride {
            route: "s3_12".to_string(),
            claim: RouteClaim { epoch: 4, replicas: 3 },
        }];
        let json = encode("a:1", &entries, &routes);
        let msg = decode(&json).unwrap();
        assert_eq!(msg.from, "a:1");
        assert_eq!(msg.members, entries);
        assert_eq!(msg.routes, routes);
    }

    #[test]
    fn pre_load_stanza_messages_decode_with_unknown_load() {
        // A PR-9-era sender emits only addr/incarnation/alive and no
        // routes key. The new decoder must accept it verbatim: load is
        // "unknown" (None) and the claim list empty — never an error.
        let old = obj(vec![
            ("v", Json::Num(1.0)),
            ("from", Json::Str("old:1".into())),
            (
                "members",
                Json::Arr(vec![obj(vec![
                    ("addr", Json::Str("old:1".into())),
                    ("incarnation", Json::Num(44.0)),
                    ("alive", Json::Bool(true)),
                ])]),
            ),
        ]);
        let msg = decode(&old).unwrap();
        assert_eq!(msg.members, vec![entry("old:1", 44, true)]);
        assert!(msg.routes.is_empty());
    }

    #[test]
    fn malformed_advisory_stanzas_are_dropped_not_fatal() {
        // Garbage load stanzas and route claims must not reject the
        // membership data in the same message.
        let body = obj(vec![
            ("v", Json::Num(1.0)),
            ("from", Json::Str("a:1".into())),
            (
                "members",
                Json::Arr(vec![obj(vec![
                    ("addr", Json::Str("a:1".into())),
                    ("incarnation", Json::Num(5.0)),
                    ("alive", Json::Bool(true)),
                    // fractional queue depth: stanza dropped
                    (
                        "load",
                        obj(vec![
                            ("v", Json::Num(1.0)),
                            ("q", Json::Num(2.5)),
                            ("lat_us", Json::Num(1.0)),
                            ("arena_b", Json::Num(0.0)),
                        ]),
                    ),
                ])]),
            ),
            (
                "routes",
                Json::Arr(vec![
                    // replicas out of ring range: skipped
                    obj(vec![
                        ("route", Json::Str("a".into())),
                        ("replicas", Json::Num(0.0)),
                        ("epoch", Json::Num(1.0)),
                    ]),
                    obj(vec![
                        ("route", Json::Str("b".into())),
                        ("replicas", Json::Num(9000.0)),
                        ("epoch", Json::Num(1.0)),
                    ]),
                    // missing epoch: skipped
                    obj(vec![
                        ("route", Json::Str("c".into())),
                        ("replicas", Json::Num(2.0)),
                    ]),
                    // well-formed survivor
                    obj(vec![
                        ("route", Json::Str("keep".into())),
                        ("replicas", Json::Num(2.0)),
                        ("epoch", Json::Num(3.0)),
                    ]),
                ]),
            ),
        ]);
        let msg = decode(&body).unwrap();
        assert_eq!(msg.members.len(), 1);
        assert_eq!(msg.members[0].load, None, "bad stanza must drop to None");
        assert_eq!(
            msg.routes,
            vec![RouteOverride {
                route: "keep".to_string(),
                claim: RouteClaim { epoch: 3, replicas: 2 },
            }]
        );
    }

    #[test]
    fn load_merge_keeps_the_freshest_version_and_skips_self() {
        let mut loads = BTreeMap::new();
        assert!(merge_loads(
            &mut loads,
            ME,
            &[loaded("b:1", 1, load(3, 9)), loaded(ME, 1, load(99, 99))],
        ));
        assert_eq!(loads.get("b:1"), Some(&load(3, 9)));
        assert!(!loads.contains_key(ME), "own load is never imported");
        // A stale relay (lower version) must not roll the view back.
        assert!(!merge_loads(&mut loads, ME, &[loaded("b:1", 1, load(2, 0))]));
        assert_eq!(loads["b:1"].queue_depth, 9);
        // Equal version: no churn either.
        assert!(!merge_loads(&mut loads, ME, &[loaded("b:1", 1, load(3, 0))]));
        // Fresher wins.
        assert!(merge_loads(&mut loads, ME, &[loaded("b:1", 1, load(4, 1))]));
        assert_eq!(loads["b:1"].queue_depth, 1);
    }

    #[test]
    fn route_claim_merge_is_a_join_semilattice() {
        let claim = |route: &str, epoch, replicas| RouteOverride {
            route: route.to_string(),
            claim: RouteClaim { epoch, replicas },
        };
        let mut a = BTreeMap::new();
        assert!(merge_route_claims(&mut a, &[claim("m", 2, 3)]));
        // Older epoch loses even with more replicas.
        assert!(!merge_route_claims(&mut a, &[claim("m", 1, 7)]));
        assert_eq!(a["m"], RouteClaim { epoch: 2, replicas: 3 });
        // Same epoch: more replicas wins the tie deterministically.
        assert!(merge_route_claims(&mut a, &[claim("m", 2, 4)]));
        // Idempotent.
        assert!(!merge_route_claims(&mut a, &[claim("m", 2, 4)]));
        // Commutative: both sides of a partition raised the route;
        // merging in either order lands on the same winner.
        let mut b = BTreeMap::new();
        merge_route_claims(&mut b, &[claim("m", 3, 2)]);
        merge_route_claims(&mut b, &[claim("m", 2, 4)]);
        merge_route_claims(&mut a, &[claim("m", 3, 2)]);
        assert_eq!(a["m"], b["m"]);
        assert_eq!(a["m"], RouteClaim { epoch: 3, replicas: 2 });
    }

    fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    #[test]
    fn decode_rejects_malformed_messages() {
        for bad in [
            obj(vec![]), // no version
            obj(vec![
                ("v", Json::Num(99.0)), // future version
                ("from", Json::Str("a".into())),
                ("members", Json::Arr(vec![])),
            ]),
            obj(vec![
                ("v", Json::Num(1.0)),
                ("from", Json::Str("a".into())),
                (
                    "members",
                    // member missing the alive flag
                    Json::Arr(vec![obj(vec![
                        ("addr", Json::Str("x".into())),
                        ("incarnation", Json::Num(1.0)),
                    ])]),
                ),
            ]),
            obj(vec![
                ("v", Json::Num(1.0)),
                ("from", Json::Str("a".into())),
                (
                    "members",
                    // fractional incarnation
                    Json::Arr(vec![obj(vec![
                        ("addr", Json::Str("x".into())),
                        ("incarnation", Json::Num(1.5)),
                        ("alive", Json::Bool(true)),
                    ])]),
                ),
            ]),
            obj(vec![
                ("v", Json::Num(1.0)),
                ("from", Json::Str("a".into())),
                (
                    "members",
                    // incarnation beyond the f64-exact bound: the
                    // saturating `as u64` cast would freeze conflict
                    // resolution at u64::MAX, so it must be rejected.
                    Json::Arr(vec![obj(vec![
                        ("addr", Json::Str("x".into())),
                        ("incarnation", Json::Num(1.0e300)),
                        ("alive", Json::Bool(false)),
                    ])]),
                ),
            ]),
        ] {
            assert!(decode(&bad).is_err(), "{bad:?}");
        }
    }
}
