//! Layer-4 HTTP activation-serving front-end.
//!
//! A dependency-free HTTP/1.1 service layered on the multi-precision
//! [`Router`](crate::coordinator::router::Router): the network front
//! door for the paper's "easily tuned for different accuracy and
//! precision requirements" claim — one route per precision, selected
//! per-request by model name.
//!
//! ## Layer map
//!
//! * [`http`]    — incremental request/response parser ([`http::Parser`]:
//!   feed bytes, resume mid-header/mid-body, chunked transfer coding
//!   with trailers) plus the blocking [`http::HttpConn`] wrapper shared
//!   with the client side used by tests and the load generator.
//! * `conn`      — per-connection state machine for the reactor
//!   (crate-private): read → parse → dispatch → write → keep-alive,
//!   with per-state deadlines (slow-loris 408, write-stall close,
//!   idle budget, and a dispatch backstop so a lost completion can
//!   never leak the connection).
//! * `reactor`   — readiness event loop (crate-private): raw `epoll`
//!   bindings with a
//!   portable `poll(2)` fallback (`TANHVF_POLLER=poll`), a self-pipe
//!   [`Waker`](crate::exec::Waker), and the accept/dispatch/deadline
//!   loop. One thread multiplexes every connection.
//! * [`api`]     — JSON endpoints: `/health`, `/v1/models`, `/v1/eval`,
//!   `/v1/batch`, `/metrics`.
//! * [`arena`]   — reusable per-thread word buffers behind the eval
//!   routes' zero-copy body path: the `words` array streams straight
//!   into an arena buffer that is grown but never shrunk, with
//!   checkout/alloc/bytes accounting on `/metrics`.
//! * [`cluster`] — multi-node tier ([`Server::start_cluster`]):
//!   consistent-hash routing of model names across several fronts
//!   (FNV-1a ring with virtual nodes), a health-checked peer table
//!   (probe thread, failure-threshold eviction, re-admission), the
//!   proxy path that forwards `/v1/eval`/`/v1/batch` to the owning
//!   peer while answering locally for keys this node owns, and
//!   optional route replication with read fan-out (`--replicas`).
//! * [`gossip`]  — SWIM-lite membership over `POST /v1/gossip`:
//!   incarnation-numbered member table, full-state anti-entropy
//!   exchange each probe round, `--join` seeds, death certificates
//!   and refutation. Ring rebuilds happen on membership changes;
//!   `--peers` is the static-bootstrap special case.
//! * [`transport`] — the client-leg seam: [`transport::Transport`] /
//!   [`transport::Connection`] (connect/send/recv under explicit
//!   per-leg [`transport::Deadlines`]) with the production
//!   [`transport::TcpTransport`] on one side and the simulation's
//!   virtual network on the other.
//! * [`pool`]    — per-peer keep-alive connection pool under every
//!   cluster client leg (proxy, probe, gossip): bounded idle lists,
//!   LRU eviction, discard-and-redial on broken reuse, hit/miss
//!   counters on `/metrics`. Dials through a [`transport::Transport`].
//! * [`trace`]   — end-to-end distributed tracing: 128-bit trace IDs
//!   propagated across proxy/fan-out legs via the `x-tanhvf-trace`
//!   header, per-node bounded span ring served at
//!   `GET /debug/trace/{id}`, slow-request logging, and the
//!   virtual-clock seam that keeps span trees deterministic under the
//!   simulator.
//! * [`sim`]     — deterministic cluster simulation: an in-process
//!   [`sim::SimNet`] under a **virtual clock** with seeded fault
//!   injection (partitions, delay, loss, slow peers, crash/restart).
//!   N-node clusters run in one process with no real sockets; the
//!   `sim_*` test suites assert membership/retry/fan-out invariants
//!   over thousands of seeded schedules.
//! * [`loadgen`] — closed-loop multi-connection load generator (one
//!   address or a whole cluster of fronts) with a machine-readable
//!   JSON report.
//!
//! ## Backends
//!
//! [`ServerConfig::event_loop`] selects between two transport backends
//! over the same parser, API, and worker pool:
//!
//! * **Reactor** (default on unix): nonblocking sockets driven by
//!   readiness events. Open-connection capacity is bounded only by
//!   `max_connections`; `workers` bounds *in-flight dispatches*. A
//!   parsed request is handed to the [`ThreadPool`]; completion wakes
//!   the reactor through the self-pipe and the response drains
//!   nonblockingly (partial writes resume on the next writable event).
//! * **Threaded** (fallback, `TANHVF_SERVER_BACKEND=threaded`): one
//!   blocking handler thread per open connection, capacity
//!   `min(max_connections, workers)`.
//!
//! Backpressure is identical in both: the accept path answers 503 above
//! the connection limit, and coordinator queue-limit rejections surface
//! as 503 from the eval endpoints. Shutdown uses the crate's
//! `AtomicBool` pattern: flag + wake (self-pipe for the reactor, a
//! loopback connect for the blocking accept), then join.

pub mod api;
pub mod arena;
pub mod cluster;
#[cfg(unix)]
pub(crate) mod conn;
pub mod gossip;
pub mod http;
pub mod loadgen;
pub mod pool;
#[cfg(unix)]
pub(crate) mod reactor;
pub mod sim;
pub mod trace;
pub mod transport;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::router::{Route, Router};
use crate::coordinator::Snapshot;
use crate::exec::{ThreadPool, Waker};
use crate::runtime::artifacts_dir;
use crate::tanh::{Subtractor, TanhConfig};

use http::{HttpConn, Outcome};

/// Tuning knobs for one server instance.
///
/// With the reactor backend (`event_loop: true`), `max_connections`
/// bounds open sockets on its own and `workers` independently bounds
/// in-flight dispatches. With the threaded backend an admitted
/// connection owns one handler thread until it closes, so the effective
/// connection capacity is `min(max_connections, workers)`.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub addr: String,
    /// Dispatch workers (reactor) / connection-handler threads
    /// (threaded).
    pub workers: usize,
    /// Open-connection bound; beyond it new connections get an
    /// immediate 503.
    pub max_connections: usize,
    /// Request body size limit, decoded (413 beyond) — applies to
    /// `Content-Length` and chunked bodies alike.
    pub max_body_bytes: usize,
    /// Idle keep-alive budget per connection.
    pub keep_alive: Duration,
    /// How long an eval may wait on its coordinator before 504.
    pub request_timeout: Duration,
    /// Transport backend: readiness-driven reactor (true) or blocking
    /// thread-per-connection (false). Defaults to the reactor on unix;
    /// `TANHVF_SERVER_BACKEND=threaded|reactor` overrides.
    pub event_loop: bool,
    /// Reactor deadline: a partially received message must keep making
    /// progress (bytes arriving) at least this often, else 408 — the
    /// slow-loris stall defence (the threaded backend's analogue is its
    /// 250 ms blocking-read tick).
    pub header_timeout: Duration,
    /// Reactor deadline: a response must drain within this budget,
    /// else the connection is dropped.
    pub write_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8787".into(),
            workers: 16,
            max_connections: 64,
            max_body_bytes: 1 << 20,
            keep_alive: Duration::from_secs(5),
            request_timeout: Duration::from_secs(10),
            event_loop: default_event_loop(),
            header_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(10),
        }
    }
}

/// Backend default: reactor on unix, overridable for CI A/B runs.
fn default_event_loop() -> bool {
    match std::env::var("TANHVF_SERVER_BACKEND").as_deref() {
        Ok("threaded") => false,
        Ok("reactor") => true,
        _ => cfg!(unix),
    }
}

/// The `/health` name of the transport backend a server with this
/// `event_loop` setting runs on.
#[cfg(unix)]
fn backend_name(event_loop: bool) -> &'static str {
    if event_loop {
        reactor::backend_name()
    } else {
        "threaded"
    }
}

#[cfg(not(unix))]
fn backend_name(_event_loop: bool) -> &'static str {
    "threaded"
}

/// HTTP-level counters (the coordinator keeps per-route metrics).
#[derive(Default)]
pub(crate) struct HttpCounters {
    pub connections: AtomicU64,
    pub rejected_connections: AtomicU64,
    pub requests: AtomicU64,
    pub responses_2xx: AtomicU64,
    pub responses_4xx: AtomicU64,
    pub responses_5xx: AtomicU64,
}

impl HttpCounters {
    fn count_response(&self, status: u16) {
        let c = match status {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// Shared state behind every connection handler.
pub(crate) struct AppState {
    pub router: Router,
    pub http: HttpCounters,
    pub started: Instant,
    pub request_timeout: Duration,
    /// Present when this node runs in cluster mode: ring + peer table
    /// + proxy path (see [`cluster`]).
    pub cluster: Option<Arc<cluster::Cluster>>,
    /// Per-node span ring + trace/span ID generator (see [`trace`]).
    pub trace: Arc<trace::TraceStore>,
    /// Span timestamp source: wall-monotonic in production, the
    /// simulator's virtual clock in `sim_*` tests.
    pub clock: trace::Clock,
    /// Transport backend actually selected (`threaded`/`epoll`/`poll`)
    /// — reported on `/health`.
    pub backend: &'static str,
}

/// A running HTTP activation service. Dropping it (or calling
/// [`Server::shutdown`]) stops accepting, drains handlers, and joins
/// every thread.
pub struct Server {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    pool: Option<Arc<ThreadPool>>,
    state: Arc<AppState>,
    /// Present with the reactor backend: rouses the event loop so the
    /// shutdown flag is observed immediately.
    waker: Option<Waker>,
}

impl Server {
    /// Start the router, bind, and begin accepting (single node).
    pub fn start(cfg: ServerConfig, routes: Vec<Route>) -> Result<Server, String> {
        Server::start_inner(cfg, routes, None)
    }

    /// Start in cluster mode: same server plus a consistent-hash ring
    /// over `{advertise} ∪ peers`, a health-checked peer table, and
    /// proxying of eval/batch requests whose model is owned elsewhere.
    /// An empty `advertise` is filled with the bound address (useful
    /// with port 0 in tests).
    pub fn start_cluster(
        cfg: ServerConfig,
        routes: Vec<Route>,
        cluster_cfg: cluster::ClusterConfig,
    ) -> Result<Server, String> {
        Server::start_inner(cfg, routes, Some(cluster_cfg))
    }

    fn start_inner(
        cfg: ServerConfig,
        routes: Vec<Route>,
        cluster_cfg: Option<cluster::ClusterConfig>,
    ) -> Result<Server, String> {
        let router = Router::start(routes)?;
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let local_addr = listener.local_addr().map_err(|e| e.to_string())?;
        let cluster = match cluster_cfg {
            None => None,
            Some(mut c) => {
                if c.advertise.is_empty() {
                    c.advertise = local_addr.to_string();
                }
                if c.max_inflight_forwards == 0 {
                    // A forward blocks its worker; keep at least half
                    // the pool free for local and proxied-in requests
                    // so mutual proxying between fronts cannot
                    // deadlock both pools.
                    c.max_inflight_forwards = (cfg.workers / 2).max(1);
                }
                let cl = cluster::Cluster::start(c)?;
                // Real servers advertise live arena bytes in their
                // gossip load stanza. Sim-driven clusters skip the
                // sampler: the arena counters are process-global, so
                // reading them would leak nondeterminism between
                // concurrently replayed schedules.
                cl.set_arena_sampler(Arc::new(|| arena::stats().2));
                Some(cl)
            }
        };
        let state = Arc::new(AppState {
            router,
            http: HttpCounters::default(),
            started: Instant::now(),
            request_timeout: cfg.request_timeout,
            cluster,
            trace: Arc::new(trace::TraceStore::with_entropy(
                trace::DEFAULT_SPAN_CAPACITY,
            )),
            clock: trace::Clock::wall(),
            backend: backend_name(cfg.event_loop),
        });
        let pool = Arc::new(ThreadPool::new(cfg.workers.max(1)));
        let shutdown = Arc::new(AtomicBool::new(false));

        let (accept_thread, waker) =
            launch_backend(listener, &cfg, &state, &shutdown, &pool)?;

        Ok(Server {
            local_addr,
            shutdown,
            accept_thread: Some(accept_thread),
            pool: Some(pool),
            state,
            waker,
        })
    }

    /// The cluster view, when started with [`Server::start_cluster`].
    pub fn cluster(&self) -> Option<&cluster::Cluster> {
        self.state.cluster.as_deref()
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Per-route coordinator metrics.
    pub fn snapshots(&self) -> std::collections::BTreeMap<String, Snapshot> {
        self.state.router.snapshots()
    }

    /// The `/metrics` exposition text (same renderer as the endpoint).
    pub fn metrics_text(&self) -> String {
        String::from_utf8_lossy(&api::render_metrics(&self.state).body)
            .into_owned()
    }

    /// Stop accepting, drain in-flight connections, join all threads.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        // Stop the cluster prober first: it must not re-admit or probe
        // while the transport is tearing down.
        if let Some(c) = &self.state.cluster {
            c.stop();
        }
        self.shutdown.store(true, Ordering::SeqCst);
        match &self.waker {
            // Reactor: the self-pipe interrupts the poll wait.
            Some(w) => w.wake(),
            // Threaded: unblock accept() with a throwaway connect.
            None => {
                let _ = TcpStream::connect_timeout(
                    &self.local_addr,
                    Duration::from_millis(200),
                );
            }
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Dropping the last pool Arc joins the worker threads.
        self.pool.take();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Spawn the transport backend thread: the reactor event loop when
/// `event_loop` is set (unix only), else the blocking accept loop.
#[cfg(unix)]
fn launch_backend(
    listener: TcpListener,
    cfg: &ServerConfig,
    state: &Arc<AppState>,
    shutdown: &Arc<AtomicBool>,
    pool: &Arc<ThreadPool>,
) -> Result<(std::thread::JoinHandle<()>, Option<Waker>), String> {
    if !cfg.event_loop {
        return spawn_threaded(listener, cfg, state, shutdown, pool)
            .map(|t| (t, None));
    }
    let (wake_reader, waker) =
        reactor::self_pipe().map_err(|e| format!("self-pipe: {e}"))?;
    let poller = reactor::init_poller(&listener, &wake_reader)
        .map_err(|e| format!("reactor init: {e}"))?;
    let cfg = cfg.clone();
    let state = state.clone();
    let shutdown = shutdown.clone();
    let pool = pool.clone();
    let job_waker = waker.clone();
    let t = std::thread::Builder::new()
        .name("tanhvf-http-reactor".into())
        .spawn(move || {
            reactor::run(
                listener, poller, cfg, state, shutdown, pool, wake_reader,
                job_waker,
            )
        })
        .map_err(|e| format!("spawn reactor thread: {e}"))?;
    Ok((t, Some(waker)))
}

#[cfg(not(unix))]
fn launch_backend(
    listener: TcpListener,
    cfg: &ServerConfig,
    state: &Arc<AppState>,
    shutdown: &Arc<AtomicBool>,
    pool: &Arc<ThreadPool>,
) -> Result<(std::thread::JoinHandle<()>, Option<Waker>), String> {
    spawn_threaded(listener, cfg, state, shutdown, pool).map(|t| (t, None))
}

/// The legacy blocking backend: one accept thread feeding handler jobs
/// (one per open connection) into the pool.
fn spawn_threaded(
    listener: TcpListener,
    cfg: &ServerConfig,
    state: &Arc<AppState>,
    shutdown: &Arc<AtomicBool>,
    pool: &Arc<ThreadPool>,
) -> Result<std::thread::JoinHandle<()>, String> {
    let cfg = cfg.clone();
    let state = state.clone();
    let shutdown = shutdown.clone();
    let pool = pool.clone();
    let active = Arc::new(AtomicUsize::new(0));
    std::thread::Builder::new()
        .name("tanhvf-http-accept".into())
        .spawn(move || {
            accept_loop(&listener, &cfg, &state, &shutdown, &active, &pool)
        })
        .map_err(|e| format!("spawn accept thread: {e}"))
}

fn accept_loop(
    listener: &TcpListener,
    cfg: &ServerConfig,
    state: &Arc<AppState>,
    shutdown: &Arc<AtomicBool>,
    active: &Arc<AtomicUsize>,
    pool: &Arc<ThreadPool>,
) {
    loop {
        let stream = match listener.accept() {
            _ if shutdown.load(Ordering::SeqCst) => return,
            Ok((s, _)) => s,
            Err(_) => {
                // Transient accept failure (e.g. fd exhaustion): back off
                // briefly instead of spinning.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        state.http.connections.fetch_add(1, Ordering::Relaxed);
        // One handler thread per open connection: admission is bounded
        // by whichever of the two limits is tighter.
        let limit = cfg.max_connections.min(cfg.workers.max(1));
        let prev = active.fetch_add(1, Ordering::SeqCst);
        if prev >= limit {
            active.fetch_sub(1, Ordering::SeqCst);
            reject_over_limit(stream, state);
            continue;
        }
        let guard = ConnGuard(active.clone());
        let st = state.clone();
        let sd = shutdown.clone();
        let cc = cfg.clone();
        pool.spawn(move || {
            let _guard = guard;
            handle_connection(&st, &cc, stream, &sd);
        });
    }
}

/// Accept-time 503 rejection shared by both backends: a proactive
/// response before any request bytes, then a best-effort drain of
/// already-sent bytes so the close sends FIN rather than RST (which
/// could destroy the 503 in the peer's receive buffer).
pub(crate) fn reject_over_limit(stream: TcpStream, state: &AppState) {
    state.http.rejected_connections.fetch_add(1, Ordering::Relaxed);
    state.http.count_response(503);
    let mut conn = HttpConn::new(stream);
    let _ = conn.write_response(
        &api::error_resp(
            503,
            "overloaded",
            "connection limit reached, retry later",
        ),
        false,
    );
    let _ = conn.stream().set_nonblocking(true);
    let mut sink = [0u8; 4096];
    let mut r = conn.stream();
    let _ = std::io::Read::read(&mut r, &mut sink);
}

struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn handle_connection(
    state: &Arc<AppState>,
    cfg: &ServerConfig,
    stream: TcpStream,
    shutdown: &Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    // Short read tick so idle handlers notice shutdown promptly.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut conn = HttpConn::new(stream);
    let mut idle_since = Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match conn.read_request(cfg.max_body_bytes) {
            Ok(Outcome::Request(req)) => {
                state.http.requests.fetch_add(1, Ordering::Relaxed);
                let keep =
                    req.keep_alive() && !shutdown.load(Ordering::SeqCst);
                let resp = api::dispatch(state, &req);
                state.http.count_response(resp.status);
                if conn.write_response(&resp, keep).is_err() || !keep {
                    return;
                }
                // Anchor the idle budget at response completion: a slow
                // dispatch must not eat the next request's keep-alive.
                idle_since = Instant::now();
            }
            Ok(Outcome::Closed) => return,
            Ok(Outcome::IdleTimeout) => {
                if idle_since.elapsed() >= cfg.keep_alive {
                    return;
                }
            }
            Err(e) => {
                let status = e.status();
                if status != 0 {
                    state.http.count_response(status);
                    let _ = conn.write_response(
                        &api::error_resp(
                            status,
                            "protocol_error",
                            &e.to_string(),
                        ),
                        false,
                    );
                }
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Route-spec parsing (shared by `serve-http --routes` and `serve
// --backend` validation)
// ---------------------------------------------------------------------

/// Backend kinds a route spec may name.
pub const BACKENDS: &[&str] = &["native", "pjrt"];

/// Reject unknown backend kinds with the valid set in the message.
pub fn validate_backend(kind: &str) -> Result<(), String> {
    if BACKENDS.contains(&kind) {
        Ok(())
    } else {
        Err(format!(
            "unknown backend '{kind}' (valid: {})",
            BACKENDS.join("|")
        ))
    }
}

/// Parse `backend:name,backend:name,...` into a route table.
///
/// `native:<cfg>` uses [`named_config`]; `pjrt:<entry>` serves the named
/// artifact entry from the default artifacts directory.
pub fn parse_routes(spec: &str) -> Result<Vec<Route>, String> {
    let mut routes = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (kind, name) = part.split_once(':').ok_or_else(|| {
            format!("route '{part}': expected backend:name (e.g. native:s3_12)")
        })?;
        validate_backend(kind).map_err(|e| format!("route '{part}': {e}"))?;
        match kind {
            "native" => {
                routes.push(Route::native(name, named_config(name)?));
            }
            _ => {
                routes.push(Route::pjrt(name, artifacts_dir(), name, 1024));
            }
        }
    }
    if routes.is_empty() {
        return Err("empty route spec".into());
    }
    Ok(routes)
}

/// Resolve a precision name to a datapath config.
///
/// The canonical operating points (`s3_12`, `s3_5`) use the paper's
/// exact parameters; any other `s<int>_<frac>` derives the secondary
/// parameters the same way the scalability sweep does (out = frac+2,
/// L = out+3, M = out+1), demonstrating the "any precision from one
/// generator" claim over the wire.
pub fn named_config(name: &str) -> Result<TanhConfig, String> {
    match name {
        "s3_12" => return Ok(TanhConfig::s3_12()),
        "s3_5" => return Ok(TanhConfig::s3_5()),
        _ => {}
    }
    let parse = || -> Option<(u32, u32)> {
        let (i, f) = name.strip_prefix('s')?.split_once('_')?;
        Some((i.parse().ok()?, f.parse().ok()?))
    };
    let (in_int, in_frac) = parse().ok_or_else(|| {
        format!("unknown model config '{name}' (expected s<int>_<frac>, e.g. s3_12)")
    })?;
    let out_frac = in_frac + 2;
    let cfg = TanhConfig {
        in_int,
        in_frac,
        out_frac,
        lut_bits: out_frac + 3,
        mult_bits: out_frac + 1,
        lut_group: if in_int + in_frac >= 12 { 4 } else { 3 },
        shuffle: true,
        nr_stages: 3,
        subtractor: Subtractor::Twos,
    };
    cfg.validate().map_err(|e| format!("config '{name}': {e}"))?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_configs_resolve() {
        assert_eq!(named_config("s3_12").unwrap(), TanhConfig::s3_12());
        assert_eq!(named_config("s3_5").unwrap(), TanhConfig::s3_5());
        let c = named_config("s2_8").unwrap();
        assert_eq!((c.in_int, c.in_frac, c.out_frac), (2, 8, 10));
        c.validate().unwrap();
        assert!(named_config("q8").is_err());
        assert!(named_config("s99_99").is_err());
    }

    #[test]
    fn route_specs_parse() {
        let routes =
            parse_routes("native:s3_12, native:s2_8").unwrap();
        assert_eq!(routes.len(), 2);
        assert_eq!(routes[0].name, "s3_12");
        assert_eq!(routes[1].name, "s2_8");
        assert!(parse_routes("bogus:s3_12").is_err());
        assert!(parse_routes("native").is_err());
        assert!(parse_routes("").is_err());
        let p = parse_routes("pjrt:tanh_s3_12").unwrap();
        assert_eq!(p[0].backend.kind(), "pjrt");
    }

    #[test]
    fn validate_backend_lists_valid_set() {
        assert!(validate_backend("native").is_ok());
        assert!(validate_backend("pjrt").is_ok());
        let e = validate_backend("onnx").unwrap_err();
        assert!(e.contains("native|pjrt"), "{e}");
    }

    #[test]
    fn backend_env_override_parses() {
        // Whatever the ambient env says, an explicit field always wins;
        // this only checks the default resolver's fallback branch.
        let d = ServerConfig::default();
        assert_eq!(d.max_connections, 64);
        assert!(d.header_timeout > Duration::from_millis(0));
    }
}
