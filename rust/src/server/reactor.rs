//! Readiness-driven event loop for the L4 front end (zero external
//! deps).
//!
//! Three pieces:
//!
//! * [`Poller`] — a thin wrapper over raw `epoll_create1`/`epoll_ctl`/
//!   `epoll_wait` (Linux) with a portable `poll(2)` fallback, both via
//!   direct `extern "C"` bindings (std already links libc). The
//!   fallback is also selectable at runtime (`TANHVF_POLLER=poll`) so
//!   CI exercises it on Linux.
//! * [`self_pipe`] — the classic self-pipe waker: worker threads wake
//!   the blocked `wait()` by writing one byte; the read end is a
//!   registered fd like any other. Exposed as a [`crate::exec::Waker`]
//!   so completion callbacks stay decoupled from the pipe.
//! * [`run`] — the reactor proper: one thread multiplexing the
//!   listener, every connection's [`Conn`] state machine, dispatch
//!   completions from the [`ThreadPool`] workers, and per-state
//!   deadline sweeps. Connection capacity is bounded only by
//!   `max_connections` — workers bound *in-flight dispatches*, not open
//!   sockets.

use std::collections::HashMap;
use std::io;
use std::net::TcpListener;
use std::os::raw::{c_int, c_short, c_void};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::exec::{ThreadPool, Waker};

use super::api;
use super::conn::{Action, Conn, Phase};
use super::http::{Request, Response};
use super::{AppState, ServerConfig};

// ---------------------------------------------------------------------
// Raw syscall surface
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod epoll_sys {
    use super::{c_int, io, RawFd};

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLPRI: u32 = 0x002;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    /// Layout of `struct epoll_event`: packed on x86-64 only, matching
    /// the kernel ABI (see `epoll.h`).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut EpollEvent,
        ) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
    }

    /// Miri has no epoll shims; every wrapper degrades to
    /// `ErrorKind::Unsupported` so the interpreter never reaches the
    /// FFI call (callers already handle epoll being unavailable by
    /// falling back to the portable poller, which Miri skips too).
    #[cfg(miri)]
    fn miri_unsupported<T>() -> io::Result<T> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "epoll FFI is not available under miri",
        ))
    }

    pub fn create() -> io::Result<c_int> {
        #[cfg(miri)]
        return miri_unsupported();
        #[cfg(not(miri))]
        {
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(fd)
            }
        }
    }

    pub fn ctl(
        epfd: c_int,
        op: c_int,
        fd: RawFd,
        events: u32,
        token: u64,
    ) -> io::Result<()> {
        #[cfg(miri)]
        {
            let _ = (epfd, op, fd, events, token);
            return miri_unsupported();
        }
        #[cfg(not(miri))]
        {
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(epfd, op, fd, &mut ev) };
            if rc < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(())
            }
        }
    }

    pub fn wait(
        epfd: c_int,
        events: &mut [EpollEvent],
        timeout_ms: c_int,
    ) -> io::Result<usize> {
        #[cfg(miri)]
        {
            let _ = (epfd, events, timeout_ms);
            return miri_unsupported();
        }
        #[cfg(not(miri))]
        {
            let rc = unsafe {
                epoll_wait(
                    epfd,
                    events.as_mut_ptr(),
                    events.len() as c_int,
                    timeout_ms,
                )
            };
            if rc < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(rc as usize)
            }
        }
    }
}

/// `struct pollfd` for the portable fallback.
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: c_int,
    events: c_short,
    revents: c_short,
}

const POLLIN: c_short = 0x001;
const POLLPRI: c_short = 0x002;
const POLLOUT: c_short = 0x004;
const POLLERR: c_short = 0x008;
const POLLHUP: c_short = 0x010;
const POLLNVAL: c_short = 0x020;

#[cfg(target_os = "linux")]
type NfdsT = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = std::os::raw::c_uint;

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
#[cfg(target_os = "linux")]
const O_NONBLOCK: c_int = 0o4000;
#[cfg(not(target_os = "linux"))]
const O_NONBLOCK: c_int = 0x4;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    fn pipe(fds: *mut c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
}

fn set_nonblocking_fd(fd: RawFd) -> io::Result<()> {
    unsafe {
        let flags = fcntl(fd, F_GETFL, 0);
        if flags < 0 {
            return Err(io::Error::last_os_error());
        }
        if fcntl(fd, F_SETFL, flags | O_NONBLOCK) < 0 {
            return Err(io::Error::last_os_error());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Poller
// ---------------------------------------------------------------------

/// What a registered fd should be watched for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Interest {
    /// Only errors/hangup (a connection parked in dispatch).
    None,
    Read,
    Write,
}

/// One readiness notification.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Error or full hangup — the fd is dead regardless of interest.
    pub closed: bool,
}

/// Readiness selector: epoll on Linux, `poll(2)` elsewhere (or when
/// forced, so the fallback stays tested).
pub(crate) enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(EpollPoller),
    Poll(PollPoller),
}

impl Poller {
    pub fn new(force_poll: bool) -> io::Result<Poller> {
        #[cfg(target_os = "linux")]
        {
            if !force_poll {
                return Ok(Poller::Epoll(EpollPoller::new()?));
            }
        }
        let _ = force_poll;
        Ok(Poller::Poll(PollPoller::new()))
    }

    pub fn add(
        &mut self,
        fd: RawFd,
        token: u64,
        interest: Interest,
    ) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.add(fd, token, interest),
            Poller::Poll(p) => p.add(fd, token, interest),
        }
    }

    pub fn modify(
        &mut self,
        fd: RawFd,
        token: u64,
        interest: Interest,
    ) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.modify(fd, token, interest),
            Poller::Poll(p) => p.modify(fd, interest),
        }
    }

    pub fn remove(&mut self, fd: RawFd) {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.remove(fd),
            Poller::Poll(p) => p.remove(fd),
        }
    }

    /// Collect ready events into `out` (cleared first). A timeout with
    /// no events, or an EINTR, yields an empty `out`.
    pub fn wait(
        &mut self,
        out: &mut Vec<Event>,
        timeout_ms: i32,
    ) -> io::Result<()> {
        out.clear();
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(out, timeout_ms),
            Poller::Poll(p) => p.wait(out, timeout_ms),
        }
    }
}

#[cfg(target_os = "linux")]
pub(crate) struct EpollPoller {
    epfd: c_int,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    fn new() -> io::Result<EpollPoller> {
        Ok(EpollPoller { epfd: epoll_sys::create()? })
    }

    fn mask(interest: Interest) -> u32 {
        use epoll_sys::*;
        match interest {
            Interest::None => 0,
            Interest::Read => EPOLLIN | EPOLLRDHUP,
            Interest::Write => EPOLLOUT,
        }
    }

    fn add(&self, fd: RawFd, token: u64, i: Interest) -> io::Result<()> {
        epoll_sys::ctl(
            self.epfd,
            epoll_sys::EPOLL_CTL_ADD,
            fd,
            Self::mask(i),
            token,
        )
    }

    fn modify(&self, fd: RawFd, token: u64, i: Interest) -> io::Result<()> {
        epoll_sys::ctl(
            self.epfd,
            epoll_sys::EPOLL_CTL_MOD,
            fd,
            Self::mask(i),
            token,
        )
    }

    fn remove(&self, fd: RawFd) {
        let _ =
            epoll_sys::ctl(self.epfd, epoll_sys::EPOLL_CTL_DEL, fd, 0, 0);
    }

    fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        use epoll_sys::*;
        let mut evs = [EpollEvent { events: 0, data: 0 }; 64];
        let n = match epoll_sys::wait(self.epfd, &mut evs, timeout_ms) {
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => 0,
            Err(e) => return Err(e),
        };
        for ev in evs.iter().take(n) {
            // Copy the (possibly unaligned) packed fields out first.
            let events = ev.events;
            let token = ev.data;
            out.push(Event {
                token,
                readable: events & (EPOLLIN | EPOLLPRI | EPOLLRDHUP) != 0,
                writable: events & EPOLLOUT != 0,
                closed: events & (EPOLLERR | EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollPoller {
    fn drop(&mut self) {
        unsafe {
            close(self.epfd);
        }
    }
}

/// `poll(2)` fallback: the registered set is rebuilt-in-place and
/// scanned linearly — O(n) per wait, fine at the connection counts the
/// fallback targets.
pub(crate) struct PollPoller {
    fds: Vec<PollFd>,
    tokens: Vec<u64>,
}

impl PollPoller {
    fn new() -> PollPoller {
        PollPoller { fds: Vec::new(), tokens: Vec::new() }
    }

    fn mask(interest: Interest) -> c_short {
        match interest {
            Interest::None => 0,
            Interest::Read => POLLIN,
            Interest::Write => POLLOUT,
        }
    }

    fn add(&mut self, fd: RawFd, token: u64, i: Interest) -> io::Result<()> {
        self.fds.push(PollFd { fd, events: Self::mask(i), revents: 0 });
        self.tokens.push(token);
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, i: Interest) -> io::Result<()> {
        match self.fds.iter_mut().find(|p| p.fd == fd) {
            Some(p) => {
                p.events = Self::mask(i);
                Ok(())
            }
            None => Err(io::Error::new(
                io::ErrorKind::NotFound,
                "fd not registered",
            )),
        }
    }

    fn remove(&mut self, fd: RawFd) {
        if let Some(idx) = self.fds.iter().position(|p| p.fd == fd) {
            self.fds.swap_remove(idx);
            self.tokens.swap_remove(idx);
        }
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        // Like the epoll wrappers: no poll(2) shim under miri.
        #[cfg(miri)]
        {
            let _ = (out, timeout_ms);
            return Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "poll FFI is not available under miri",
            ));
        }
        #[cfg(not(miri))]
        {
            for p in self.fds.iter_mut() {
                p.revents = 0;
            }
            let rc = unsafe {
                poll(self.fds.as_mut_ptr(), self.fds.len() as NfdsT, timeout_ms)
            };
            if rc < 0 {
                let e = io::Error::last_os_error();
                return if e.kind() == io::ErrorKind::Interrupted {
                    Ok(())
                } else {
                    Err(e)
                };
            }
            for (p, &token) in self.fds.iter().zip(self.tokens.iter()) {
                if p.revents == 0 {
                    continue;
                }
                out.push(Event {
                    token,
                    readable: p.revents & (POLLIN | POLLPRI) != 0,
                    writable: p.revents & POLLOUT != 0,
                    closed: p.revents & (POLLERR | POLLHUP | POLLNVAL) != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------
// Self-pipe waker
// ---------------------------------------------------------------------

/// Owns the write end of the self-pipe; closed when the last
/// [`Waker`] clone drops.
struct PipeWriter(c_int);

// A write(2) on a shared fd is thread-safe.
unsafe impl Send for PipeWriter {}
unsafe impl Sync for PipeWriter {}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        unsafe {
            close(self.0);
        }
    }
}

/// Read end of the self-pipe, registered in the poller.
pub(crate) struct WakeReader(c_int);

impl WakeReader {
    pub fn fd(&self) -> RawFd {
        self.0
    }

    /// Swallow every pending wake byte (nonblocking).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe {
                read(self.0, buf.as_mut_ptr() as *mut c_void, buf.len())
            };
            if n <= 0 {
                break;
            }
        }
    }
}

impl Drop for WakeReader {
    fn drop(&mut self) {
        unsafe {
            close(self.0);
        }
    }
}

/// Build the self-pipe: returns the pollable read end and a cloneable
/// [`Waker`] whose `wake()` makes the read end readable. Writes to a
/// full pipe or after the reader is gone are silently dropped (a wake
/// is level-triggered; one pending byte is enough).
pub(crate) fn self_pipe() -> io::Result<(WakeReader, Waker)> {
    // No pipe(2)/fcntl(2) shims under miri; the reactor tests are
    // excluded from the miri CI filter, but fail soft if reached.
    #[cfg(miri)]
    return Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "self-pipe FFI is not available under miri",
    ));
    #[cfg(not(miri))]
    {
        let mut fds: [c_int; 2] = [0; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        let reader = WakeReader(fds[0]);
        let writer = Arc::new(PipeWriter(fds[1]));
        set_nonblocking_fd(fds[0])?;
        set_nonblocking_fd(fds[1])?;
        let waker = Waker::new(move || {
            let byte = 1u8;
            let _ = unsafe {
                write(writer.0, &byte as *const u8 as *const c_void, 1)
            };
        });
        Ok((reader, waker))
    }
}

// ---------------------------------------------------------------------
// The reactor loop
// ---------------------------------------------------------------------

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;
/// Poll tick: upper bound on deadline-sweep latency and shutdown lag.
const TICK_MS: i32 = 100;
/// Hard bound on the post-shutdown drain of in-flight work.
const DRAIN_GRACE: std::time::Duration = std::time::Duration::from_secs(5);

/// A finished dispatch: (connection token, response, keep-alive).
type Completion = (u64, Response, bool);

/// Should the poll fallback be forced? (`TANHVF_POLLER=poll`.)
pub(crate) fn force_poll_from_env() -> bool {
    std::env::var("TANHVF_POLLER").as_deref() == Ok("poll")
}

/// Human name of the readiness mechanism the reactor will select —
/// surfaced on `/health` so a running node's backend is discoverable.
pub(crate) fn backend_name() -> &'static str {
    if cfg!(target_os = "linux") && !force_poll_from_env() {
        "epoll"
    } else {
        "poll"
    }
}

/// Prepare the poller *before* the reactor thread spawns, so setup
/// failures (epoll/pipe fd exhaustion, fcntl errors) surface as
/// `Server::start` errors instead of a silently dead server.
pub(crate) fn init_poller(
    listener: &TcpListener,
    wake: &WakeReader,
) -> io::Result<Poller> {
    listener.set_nonblocking(true)?;
    let mut poller = Poller::new(force_poll_from_env())?;
    poller.add(listener.as_raw_fd(), TOKEN_LISTENER, Interest::Read)?;
    poller.add(wake.fd(), TOKEN_WAKER, Interest::Read)?;
    Ok(poller)
}

/// Run the event loop until `shutdown` is flagged (and woken via
/// `waker`). Owns the listener; dropping on return closes it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run(
    listener: TcpListener,
    mut poller: Poller,
    cfg: ServerConfig,
    state: Arc<AppState>,
    shutdown: Arc<AtomicBool>,
    pool: Arc<ThreadPool>,
    wake_reader: WakeReader,
    waker: Waker,
) {
    let completions: Arc<Mutex<Vec<Completion>>> =
        Arc::new(Mutex::new(Vec::new()));
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = FIRST_CONN_TOKEN;
    let mut events: Vec<Event> = Vec::new();

    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        if poller.wait(&mut events, TICK_MS).is_err() {
            return;
        }
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let now = Instant::now();

        for ev in events.drain(..) {
            match ev.token {
                TOKEN_LISTENER => accept_ready(
                    &listener,
                    &cfg,
                    &state,
                    &mut poller,
                    &mut conns,
                    &mut next_token,
                    now,
                ),
                TOKEN_WAKER => wake_reader.drain(),
                token => {
                    let Some(conn) = conns.get_mut(&token) else {
                        continue;
                    };
                    let action = match conn.phase() {
                        Phase::Reading if ev.readable || ev.closed => {
                            conn.on_readable(now, &state.http)
                        }
                        Phase::Writing if ev.writable || ev.closed => {
                            conn.on_writable(now, &state.http)
                        }
                        Phase::Dispatching if ev.closed => Action::Close,
                        _ => Action::Continue,
                    };
                    apply(
                        token, action, &mut conns, &mut poller, &state,
                        &shutdown, &pool, &completions, &waker,
                    );
                }
            }
        }

        // Dispatch completions pushed by pool workers.
        let done: Vec<Completion> = {
            let mut guard = completions.lock().unwrap();
            guard.drain(..).collect()
        };
        for (token, resp, keep) in done {
            let Some(conn) = conns.get_mut(&token) else {
                continue; // connection died while the request ran
            };
            if conn.phase() != Phase::Dispatching {
                continue;
            }
            let action = conn.complete(&resp, keep, now, &state.http);
            apply(
                token, action, &mut conns, &mut poller, &state, &shutdown,
                &pool, &completions, &waker,
            );
        }

        // Per-state deadline sweep (slow-loris stalls, stalled writes,
        // spent keep-alive budgets). Continue actions are applied too:
        // a deadline 408 that only partially flushed has just moved the
        // connection to Writing and needs its poll interest switched.
        let swept: Vec<(u64, Action)> = conns
            .iter_mut()
            .map(|(&t, c)| (t, c.check_deadline(now, &cfg, &state.http)))
            .collect();
        for (token, action) in swept {
            apply(
                token, action, &mut conns, &mut poller, &state, &shutdown,
                &pool, &completions, &waker,
            );
        }
    }

    // -- graceful drain (mirrors the threaded backend) ----------------
    // Stop accepting and reading, but let in-flight dispatches finish
    // and queued responses reach the wire, bounded by a hard deadline.
    poller.remove(listener.as_raw_fd());
    let idle: Vec<u64> = conns
        .iter()
        .filter(|(_, c)| c.phase() == Phase::Reading)
        .map(|(&t, _)| t)
        .collect();
    for token in idle {
        if let Some(c) = conns.remove(&token) {
            poller.remove(c.fd());
        }
    }
    let deadline = Instant::now() + DRAIN_GRACE;
    while !conns.is_empty() && Instant::now() < deadline {
        if poller.wait(&mut events, TICK_MS).is_err() {
            return;
        }
        let now = Instant::now();
        for ev in events.drain(..) {
            match ev.token {
                TOKEN_WAKER => wake_reader.drain(),
                token => {
                    let Some(conn) = conns.get_mut(&token) else {
                        continue;
                    };
                    let action = match conn.phase() {
                        Phase::Writing if ev.writable || ev.closed => {
                            conn.on_writable(now, &state.http)
                        }
                        Phase::Dispatching if ev.closed => Action::Close,
                        _ => Action::Continue,
                    };
                    // Once a response has drained, the connection is
                    // done — no keep-alive and no pipelined dispatches
                    // during shutdown.
                    let action = match action {
                        Action::Continue
                            if conn.phase() == Phase::Reading =>
                        {
                            Action::Close
                        }
                        Action::Dispatch(_) => Action::Close,
                        a => a,
                    };
                    apply(
                        token, action, &mut conns, &mut poller, &state,
                        &shutdown, &pool, &completions, &waker,
                    );
                }
            }
        }
        let done: Vec<Completion> = {
            let mut guard = completions.lock().unwrap();
            guard.drain(..).collect()
        };
        for (token, resp, _keep) in done {
            let Some(conn) = conns.get_mut(&token) else {
                continue;
            };
            if conn.phase() != Phase::Dispatching {
                continue;
            }
            // Never keep-alive during shutdown: the response drains and
            // the connection closes.
            let action = conn.complete(&resp, false, now, &state.http);
            apply(
                token, action, &mut conns, &mut poller, &state, &shutdown,
                &pool, &completions, &waker,
            );
        }
    }
}

/// Accept every pending connection; over-limit peers get a proactive
/// 503 on the still-blocking freshly accepted socket.
#[allow(clippy::too_many_arguments)]
fn accept_ready(
    listener: &TcpListener,
    cfg: &ServerConfig,
    state: &Arc<AppState>,
    poller: &mut Poller,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
    now: Instant,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        };
        state.http.connections.fetch_add(1, Ordering::Relaxed);
        if conns.len() >= cfg.max_connections {
            super::reject_over_limit(stream, state);
            continue;
        }
        let conn = match Conn::new(stream, now, cfg.max_body_bytes) {
            Ok(c) => c,
            Err(_) => continue,
        };
        let token = *next_token;
        *next_token += 1;
        if poller.add(conn.fd(), token, conn.interest()).is_ok() {
            conns.insert(token, conn);
        }
    }
}

/// Apply a state-machine action: refresh interest, spawn a dispatch, or
/// tear the connection down.
#[allow(clippy::too_many_arguments)]
fn apply(
    token: u64,
    action: Action,
    conns: &mut HashMap<u64, Conn>,
    poller: &mut Poller,
    state: &Arc<AppState>,
    shutdown: &Arc<AtomicBool>,
    pool: &Arc<ThreadPool>,
    completions: &Arc<Mutex<Vec<Completion>>>,
    waker: &Waker,
) {
    match action {
        Action::Close => {
            if let Some(conn) = conns.remove(&token) {
                poller.remove(conn.fd());
            }
        }
        Action::Dispatch(req) => {
            state.http.requests.fetch_add(1, Ordering::Relaxed);
            refresh_interest(token, conns, poller);
            spawn_dispatch(
                token, req, state, shutdown, pool, completions, waker,
            );
        }
        Action::Continue => refresh_interest(token, conns, poller),
    }
}

fn refresh_interest(
    token: u64,
    conns: &mut HashMap<u64, Conn>,
    poller: &mut Poller,
) {
    let Some(conn) = conns.get_mut(&token) else { return };
    let want = conn.interest();
    if conn.registered_interest() == want {
        return;
    }
    if poller.modify(conn.fd(), token, want).is_ok() {
        conn.set_registered_interest(want);
    }
}

/// Hand a parsed request to the worker pool; completion wakes the
/// reactor through the self-pipe.
fn spawn_dispatch(
    token: u64,
    req: Request,
    state: &Arc<AppState>,
    shutdown: &Arc<AtomicBool>,
    pool: &Arc<ThreadPool>,
    completions: &Arc<Mutex<Vec<Completion>>>,
    waker: &Waker,
) {
    let state = state.clone();
    let shutdown = shutdown.clone();
    let completions = completions.clone();
    let waker = waker.clone();
    pool.spawn(move || {
        let keep = req.keep_alive() && !shutdown.load(Ordering::SeqCst);
        let resp = api::dispatch(&state, &req);
        state.http.count_response(resp.status);
        completions.lock().unwrap().push((token, resp, keep));
        waker.wake();
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    fn pollers() -> Vec<Poller> {
        let mut v = vec![Poller::new(true).unwrap()];
        if cfg!(target_os = "linux") {
            v.push(Poller::new(false).unwrap());
        }
        v
    }

    #[test]
    fn poller_reports_listener_readable_on_connect() {
        for mut poller in pollers() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            listener.set_nonblocking(true).unwrap();
            poller
                .add(listener.as_raw_fd(), 7, Interest::Read)
                .unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, 0).unwrap();
            assert!(events.is_empty(), "no events before connect");

            let _client =
                TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            // The pending connection must surface within the timeout.
            let mut seen = false;
            for _ in 0..50 {
                poller.wait(&mut events, 100).unwrap();
                if events.iter().any(|e| e.token == 7 && e.readable) {
                    seen = true;
                    break;
                }
            }
            assert!(seen, "listener never became readable");
        }
    }

    #[test]
    fn poller_tracks_write_interest() {
        for mut poller in pollers() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let client =
                TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (server, _) = listener.accept().unwrap();
            server.set_nonblocking(true).unwrap();
            poller.add(server.as_raw_fd(), 3, Interest::Write).unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, 1000).unwrap();
            assert!(
                events.iter().any(|e| e.token == 3 && e.writable),
                "fresh socket must be writable: {events:?}"
            );
            // Downgrade to no interest: only errors may surface now.
            poller.modify(server.as_raw_fd(), 3, Interest::None).unwrap();
            poller.wait(&mut events, 0).unwrap();
            assert!(
                !events.iter().any(|e| e.writable),
                "writable after deregistration: {events:?}"
            );
            poller.remove(server.as_raw_fd());
            drop(client);
        }
    }

    #[test]
    fn self_pipe_wakes_poller_and_drains() {
        for mut poller in pollers() {
            let (reader, waker) = self_pipe().unwrap();
            poller.add(reader.fd(), TOKEN_WAKER, Interest::Read).unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, 0).unwrap();
            assert!(events.is_empty());

            // Wake from another thread, as the pool workers do.
            let w = waker.clone();
            let t = std::thread::spawn(move || w.wake());
            let mut woke = false;
            for _ in 0..50 {
                poller.wait(&mut events, 100).unwrap();
                if events.iter().any(|e| e.token == TOKEN_WAKER && e.readable)
                {
                    woke = true;
                    break;
                }
            }
            t.join().unwrap();
            assert!(woke, "waker did not rouse the poller");
            reader.drain();
            poller.wait(&mut events, 0).unwrap();
            assert!(
                !events.iter().any(|e| e.token == TOKEN_WAKER && e.readable),
                "drain left the pipe readable"
            );
        }
    }

    #[test]
    fn wake_after_reader_gone_is_harmless() {
        let (reader, waker) = self_pipe().unwrap();
        drop(reader);
        waker.wake(); // EPIPE swallowed (Rust ignores SIGPIPE)
        waker.wake();
    }

    #[test]
    fn poll_fallback_sees_plain_readable_data() {
        let mut poller = Poller::new(true).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client =
            TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        poller.add(server.as_raw_fd(), 9, Interest::Read).unwrap();
        client.write_all(b"hi").unwrap();
        let mut events = Vec::new();
        let mut seen = false;
        for _ in 0..50 {
            poller.wait(&mut events, 100).unwrap();
            if events.iter().any(|e| e.token == 9 && e.readable) {
                seen = true;
                break;
            }
        }
        assert!(seen, "data never surfaced through poll fallback");
    }
}
