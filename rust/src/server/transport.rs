//! Client-leg transport abstraction for the cluster tier.
//!
//! Every outbound round trip a cluster node makes — proxying an eval,
//! probing `/health`, exchanging `POST /v1/gossip` — goes through the
//! [`Transport`]/[`Connection`] pair defined here instead of touching
//! `TcpStream` directly. Two implementations exist:
//!
//! * [`TcpTransport`] — the production path: resolve, dial with a
//!   connect deadline, `TCP_NODELAY`, and per-leg read/write socket
//!   timeouts over the shared [`HttpConn`] HTTP/1.1 codec.
//! * [`super::sim`] — an in-process network with a **virtual clock**
//!   and scripted fault injection (partitions, delay, loss, slow
//!   peers, crash/restart). The whole cluster test matrix runs on it
//!   with no real sockets and no real time.
//!
//! The seam is deliberately narrow: connect/send/recv with explicit
//! [`Deadlines`], plus the two properties the pool and the
//! discard-and-redial retry actually depend on — [`Connection::is_clean`]
//! (safe to re-admit to the idle pool) and
//! [`TransportError::retryable`] (safe to redial and re-send). A
//! *retryable* failure is the stale-keep-alive signature: the send
//! failed outright, or the peer closed/reset before answering. A
//! timeout while awaiting the response is **not** retryable — the
//! request may be executing on the peer right now, and re-sending it
//! would double-execute.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::http::{HttpConn, HttpError};

/// Per-leg time budgets for one round trip. The connect leg applies to
/// dialing only; write and read bound each direction of an established
/// exchange separately, so a caller can give a gossip exchange a total
/// wall bound (connect + write + read) independent of the per-probe
/// budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Deadlines {
    pub connect: Duration,
    pub write: Duration,
    pub read: Duration,
}

impl Deadlines {
    /// The same budget on every leg (the probe/proxy default).
    pub fn uniform(d: Duration) -> Deadlines {
        Deadlines { connect: d, write: d, read: d }
    }

    /// Explicit per-leg budgets.
    pub fn split(connect: Duration, write: Duration, read: Duration) -> Deadlines {
        Deadlines { connect, write, read }
    }

    /// Worst-case wall time for one full round trip on these budgets.
    pub fn total(&self) -> Duration {
        self.connect + self.write + self.read
    }
}

/// A failed send/recv, classified for the discard-and-redial loop.
#[derive(Debug)]
pub struct TransportError {
    /// True when retrying the round trip on a fresh connection cannot
    /// double-execute the request (send failed, or the peer closed
    /// before answering). False for response timeouts: the request may
    /// already be executing on the peer.
    pub retryable: bool,
    pub msg: String,
}

impl TransportError {
    pub fn new(retryable: bool, msg: impl Into<String>) -> TransportError {
        TransportError { retryable, msg: msg.into() }
    }
}

/// One established client connection. Implementations pair with a
/// [`Transport`]; the pool stores them boxed and re-admits only clean
/// ones.
pub trait Connection: Send {
    /// (Re)apply per-leg budgets — called on every pool checkout so
    /// probe and proxy legs can share pooled connections under
    /// different budgets.
    fn set_deadlines(&mut self, deadlines: &Deadlines);

    /// Serialize and send one request.
    fn send(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<(), TransportError>;

    /// Await the response: `(status, headers, body)`.
    fn recv(
        &mut self,
        max_body: usize,
    ) -> Result<(u16, BTreeMap<String, String>, Vec<u8>), TransportError>;

    /// True when the connection sits cleanly between messages — the
    /// pool's re-admission gate.
    fn is_clean(&self) -> bool;
}

/// Dials [`Connection`]s to peer addresses.
pub trait Transport: Send + Sync {
    fn connect(
        &self,
        addr: &str,
        deadlines: &Deadlines,
    ) -> Result<Box<dyn Connection>, String>;
}

// ---------------------------------------------------------------------
// TCP (production)
// ---------------------------------------------------------------------

/// The real-socket transport: what every cluster node uses unless a
/// test injects [`super::sim::SimTransport`].
pub struct TcpTransport;

impl Transport for TcpTransport {
    fn connect(
        &self,
        addr: &str,
        deadlines: &Deadlines,
    ) -> Result<Box<dyn Connection>, String> {
        let sa = resolve(addr)?;
        let stream = TcpStream::connect_timeout(&sa, deadlines.connect)
            .map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let mut conn = TcpConnection::new(HttpConn::new(stream));
        conn.set_deadlines(deadlines);
        Ok(Box::new(conn))
    }
}

/// [`HttpConn`] adapted to the [`Connection`] trait (also the wrapper
/// pool tests use around raw loopback sockets).
pub struct TcpConnection {
    conn: HttpConn,
}

impl TcpConnection {
    pub fn new(conn: HttpConn) -> TcpConnection {
        TcpConnection { conn }
    }

    pub fn from_stream(stream: TcpStream) -> TcpConnection {
        TcpConnection::new(HttpConn::new(stream))
    }
}

impl Connection for TcpConnection {
    fn set_deadlines(&mut self, deadlines: &Deadlines) {
        let _ = self.conn.stream().set_read_timeout(Some(deadlines.read));
        let _ = self.conn.stream().set_write_timeout(Some(deadlines.write));
    }

    fn send(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<(), TransportError> {
        self.conn
            .write_request_with_headers(method, path, headers, body)
            // A failed send never reached a complete request; redial
            // and re-send cannot double-execute.
            .map_err(|e| TransportError::new(true, e.to_string()))
    }

    fn recv(
        &mut self,
        max_body: usize,
    ) -> Result<(u16, BTreeMap<String, String>, Vec<u8>), TransportError> {
        self.conn.read_response(max_body).map_err(|e| {
            // Timeout = the peer may be executing the request right
            // now; anything else (closed, reset, malformed) means no
            // response will ever come for *this* send.
            TransportError::new(
                !matches!(e, HttpError::Timeout(_)),
                e.to_string(),
            )
        })
    }

    fn is_clean(&self) -> bool {
        self.conn.is_clean()
    }
}

fn resolve(addr: &str) -> Result<SocketAddr, String> {
    addr.to_socket_addrs()
        .map_err(|e| format!("resolve {addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("resolve {addr}: no address"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn deadlines_constructors() {
        let u = Deadlines::uniform(Duration::from_millis(100));
        assert_eq!(u.connect, u.read);
        assert_eq!(u.total(), Duration::from_millis(300));
        let s = Deadlines::split(
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        );
        assert_eq!(s.total(), Duration::from_millis(60));
    }

    #[test]
    fn tcp_transport_dials_and_applies_deadlines() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let t = TcpTransport;
        let d = Deadlines::uniform(Duration::from_millis(200));
        let conn = t.connect(&addr, &d).unwrap();
        assert!(conn.is_clean());
        // Unreachable port: the connect deadline turns into an error.
        drop(l);
        assert!(t.connect(&addr, &d).is_err());
    }

    #[test]
    fn recv_timeout_is_not_retryable() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let mut conn = TcpTransport
            .connect(&addr, &Deadlines::uniform(Duration::from_millis(50)))
            .unwrap();
        conn.send("GET", "/health", &[], b"").unwrap();
        // Nobody answers (the accept side sits in the backlog): the
        // read deadline fires and must NOT be classified retryable.
        let err = conn.recv(1024).unwrap_err();
        assert!(!err.retryable, "{}", err.msg);
    }
}
