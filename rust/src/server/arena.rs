//! Reusable per-thread word buffers for the request path.
//!
//! Every `/v1/eval` and `/v1/batch` request used to allocate fresh
//! `Vec`s for the decoded input words and (on the fan-out path) the
//! merged shard outputs. This module replaces those with a per-thread
//! arena slot: two `i64` buffers that are checked out at request start,
//! grown on demand, and returned — **never shrunk** — so a warm thread
//! serves requests with zero heap allocation on the word path.
//!
//! Thread affinity is the unit of reuse: under the threaded backend a
//! connection is pinned to one pool thread, so the slot is effectively
//! per-connection; under the reactor backend dispatch also runs on the
//! worker pool, so the slot is per-worker (same steady-state: at most
//! `workers + max_connections` slots exist, each converging to the
//! largest request it has served).
//!
//! Accounting (exported as `/metrics` families by the API layer):
//! * `checkouts` — word-buffer checkouts (== requests on the path);
//! * `allocs`    — checkouts that had to grow a buffer. Once warm this
//!   stays flat, which is exactly what `tests/zero_copy.rs` asserts;
//! * `bytes`     — live bytes across all slots (gauge; slot drops
//!   subtract their capacity).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Word-buffer checkouts since process start.
static CHECKOUTS: AtomicU64 = AtomicU64::new(0);
/// Checkouts (of either buffer) that grew the slot's capacity.
static ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Live arena bytes across all thread slots.
static BYTES: AtomicU64 = AtomicU64::new(0);

/// One thread's buffers plus the capacities already accounted in
/// [`BYTES`]. The `Cell<Vec<_>>` holders let a checkout `take` the
/// buffer without holding any borrow across the request (the fan-out
/// path re-enters the arena from the same thread for its merge buffer).
struct Slot {
    words: Cell<Vec<i64>>,
    merge: Cell<Vec<i64>>,
    words_cap: Cell<usize>,
    merge_cap: Cell<usize>,
}

impl Drop for Slot {
    fn drop(&mut self) {
        let bytes = 8 * (self.words_cap.get() + self.merge_cap.get()) as u64;
        BYTES.fetch_sub(bytes, Ordering::Relaxed);
    }
}

thread_local! {
    static SLOT: Slot = Slot {
        words: Cell::new(Vec::new()),
        merge: Cell::new(Vec::new()),
        words_cap: Cell::new(0),
        merge_cap: Cell::new(0),
    };
}

/// Check out this thread's request word buffer (cleared, capacity
/// preserved). Pair with [`put_words`].
pub fn take_words() -> Vec<i64> {
    CHECKOUTS.fetch_add(1, Ordering::Relaxed);
    SLOT.with(|s| {
        let mut v = s.words.take();
        v.clear();
        v
    })
}

/// Return the request word buffer, folding any growth into the stats.
pub fn put_words(buf: Vec<i64>) {
    SLOT.with(|s| {
        account_growth(buf.capacity(), &s.words_cap);
        s.words.set(buf);
    });
}

/// Check out this thread's merge buffer (the fan-out shard-merge
/// scratch — a second buffer so it can coexist with the word buffer
/// within one request). Pair with [`put_merge`].
pub fn take_merge() -> Vec<i64> {
    SLOT.with(|s| {
        let mut v = s.merge.take();
        v.clear();
        v
    })
}

/// Return the merge buffer, folding any growth into the stats.
pub fn put_merge(buf: Vec<i64>) {
    SLOT.with(|s| {
        account_growth(buf.capacity(), &s.merge_cap);
        s.merge.set(buf);
    });
}

fn account_growth(cap: usize, accounted: &Cell<usize>) {
    let old = accounted.get();
    if cap > old {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(8 * (cap - old) as u64, Ordering::Relaxed);
        accounted.set(cap);
    }
}

/// (checkouts, allocs, live bytes) for `/metrics`.
pub fn stats() -> (u64, u64, u64) {
    (
        CHECKOUTS.load(Ordering::Relaxed),
        ALLOCS.load(Ordering::Relaxed),
        BYTES.load(Ordering::Relaxed),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test function: the counters are process-global, so separate
    // #[test]s (which run on parallel threads) would race on them.
    #[test]
    fn lifecycle_reuse_and_accounting() {
        // Warm reuse: after the first growth to the high-water mark,
        // further checkouts from this thread must not count allocs.
        let (c0, a0, _) = stats();
        let mut v = take_words();
        v.extend(0..1000);
        put_words(v);
        let (_, a1, b1) = stats();
        assert!(a1 > a0, "first growth must be counted");
        assert!(b1 >= 8000);
        for _ in 0..10 {
            let mut v = take_words();
            assert!(v.is_empty(), "checkout must be cleared");
            assert!(v.capacity() >= 1000, "capacity must be retained");
            v.extend(0..1000);
            put_words(v);
        }
        let (c1, a2, b2) = stats();
        assert_eq!(a1, a2, "warm reuse must not allocate");
        assert_eq!(b1, b2, "warm reuse must not grow the arena");
        assert_eq!(c1, c0 + 11, "every checkout is counted");

        // Both buffers coexist within one request.
        let mut w = take_words();
        let mut m = take_merge();
        w.push(1);
        m.extend(0..500);
        put_merge(m);
        put_words(w);
        let m = take_merge();
        assert!(m.is_empty() && m.capacity() >= 500);
        put_merge(m);

        // A dying thread's slot returns its bytes to the gauge.
        let (_, _, before) = stats();
        std::thread::spawn(|| {
            let mut v = take_words();
            v.extend(0..4096);
            put_words(v);
        })
        .join()
        .unwrap();
        let (_, _, after) = stats();
        assert_eq!(before, after);
    }
}
