//! Closed-loop HTTP load generator + tiny blocking client helpers.
//!
//! ## Why closed-loop
//!
//! Each connection thread sends `POST /v1/batch` requests back-to-back
//! on one keep-alive connection: the next request leaves only after the
//! previous response has fully arrived. A closed loop cannot overrun
//! the server — offered load self-limits to (connections / latency) —
//! which makes it the right shape for *capacity* measurement: observed
//! req/s is the service rate at that concurrency, and latency
//! percentiles are honest (no coordinated-omission skew from a
//! timer-driven open loop silently queueing send times).
//!
//! ## Workload shape
//!
//! * [`LoadgenConfig::models`] is cycled per request (offset by the
//!   connection index), so a two-route server sees genuinely
//!   mixed-precision traffic and a cluster front sees keys that hash
//!   to different owners. With [`LoadgenConfig::zipf_s`] `> 0` the
//!   cycle is replaced by a seeded Zipf rank draw (`models[0]`
//!   hottest) — the skewed-popularity profile that drives hot-route
//!   replica expansion.
//! * [`LoadgenConfig::addrs`] may list several fronts: connections are
//!   dealt round-robin across them, so one run drives a whole cluster
//!   through every entry point at once.
//! * Words are drawn uniformly from `[-word_range, word_range)` by the
//!   crate's deterministic [`Rng`] (seeded per connection), keeping
//!   runs reproducible.
//!
//! ## Outputs
//!
//! [`LoadReport`] carries req/s, words/s, failure count, and
//! nearest-rank p50/p95/p99/max latency; [`LoadReport::render`] is the
//! human line, [`LoadReport::to_json`] the machine record persisted by
//! the `http_serving` bench into `BENCH_http_serving.json`. Consumers:
//! the `loadgen` CLI subcommand, the bench, the serving example, and
//! the e2e tests.
//!
//! The single-shot helpers at the bottom ([`http_get`],
//! [`http_post_json`], [`eval_words`]) are the blocking client surface
//! shared by tests, examples, and the CI smoke scripts.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats::percentile;

use super::http::HttpConn;
use super::trace;

/// Workload description for [`run`].
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server addresses, e.g. `["127.0.0.1:8787"]`. With several
    /// entries (a cluster of fronts) connections are dealt round-robin
    /// across them, so the whole cluster is driven from one run.
    pub addrs: Vec<String>,
    /// Concurrent keep-alive connections.
    pub connections: usize,
    /// Requests each connection sends.
    pub requests_per_connection: usize,
    /// Words per `POST /v1/batch` request.
    pub words_per_request: usize,
    /// Model names cycled per request (mixed-precision traffic).
    pub models: Vec<String>,
    /// Input words drawn uniformly from `[-word_range, word_range)`
    /// (keep within the smallest route's input format).
    pub word_range: i64,
    pub seed: u64,
    /// Record the server-assigned trace ID of every Nth request per
    /// connection (0 disables sampling). The report then fetches the
    /// slowest sampled request's span tree from `/debug/trace/{id}`.
    pub trace_sample: usize,
    /// Zipf exponent for model selection. `0.0` (the default) keeps
    /// the legacy behavior: models cycled per request, offset by the
    /// connection index. Positive values draw the model *rank* from a
    /// Zipf(s) distribution over `models` (rank 0 = `models[0]` is the
    /// hottest), the skewed-popularity profile that exercises the
    /// hot-route replica controller.
    pub zipf_s: f64,
}

impl LoadgenConfig {
    pub fn new(addr: impl Into<String>, models: &[&str]) -> LoadgenConfig {
        LoadgenConfig {
            addrs: vec![addr.into()],
            connections: 4,
            requests_per_connection: 100,
            words_per_request: 64,
            models: models.iter().map(|m| m.to_string()).collect(),
            word_range: 128,
            seed: 42,
            trace_sample: 0,
            zipf_s: 0.0,
        }
    }
}

/// Precomputed Zipf(s) CDF over `n` ranks: rank `k` (0-based) carries
/// probability proportional to `1/(k+1)^s`. Sampling is one uniform
/// draw plus a binary search, so the per-request cost is independent
/// of the model count.
struct ZipfCdf {
    cdf: Vec<f64>,
}

impl ZipfCdf {
    fn new(n: usize, s: f64) -> ZipfCdf {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc.max(f64::MIN_POSITIVE);
        for c in &mut cdf {
            *c /= total;
        }
        ZipfCdf { cdf }
    }

    /// Draw a rank in `[0, n)` from one uniform sample.
    fn draw(&self, u: f64) -> usize {
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// Aggregated result of one load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub requests: u64,
    pub failures: u64,
    pub words: u64,
    pub wall: Duration,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Trace ID of the slowest sampled request (trace sampling on).
    pub slowest_trace_id: Option<String>,
    /// That trace's span tree as served by `/debug/trace/{id}`.
    pub slowest_trace: Option<Json>,
}

impl LoadReport {
    pub fn req_per_s(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn words_per_s(&self) -> f64 {
        self.words as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn render(&self) -> String {
        format!(
            "{} reqs ({} failed) in {:?}: {:.0} req/s, {:.2e} words/s, \
             p50 {} us, p95 {} us, p99 {} us, max {} us",
            self.requests,
            self.failures,
            self.wall,
            self.req_per_s(),
            self.words_per_s(),
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.max_us
        )
    }

    /// Machine-readable form: the perf-trajectory record the
    /// `http_serving` bench persists to `BENCH_http_serving.json`.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failures", Json::Num(self.failures as f64)),
            ("words", Json::Num(self.words as f64)),
            ("wall_s", Json::Num(self.wall.as_secs_f64())),
            ("rps", Json::Num(self.req_per_s())),
            ("words_per_s", Json::Num(self.words_per_s())),
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p95_us", Json::Num(self.p95_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
            ("max_us", Json::Num(self.max_us as f64)),
        ];
        if let Some(id) = &self.slowest_trace_id {
            fields.push(("slowest_trace_id", Json::Str(id.clone())));
        }
        if let Some(tree) = &self.slowest_trace {
            fields.push(("slowest_trace", tree.clone()));
        }
        Json::Obj(
            fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }
}

/// Run the closed-loop workload to completion.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport, String> {
    if cfg.models.is_empty() || cfg.connections == 0 || cfg.addrs.is_empty() {
        return Err(
            "loadgen needs at least one model, connection, and address".into(),
        );
    }
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for ci in 0..cfg.connections {
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(
            move || -> Result<ConnResult, String> { connection_loop(&cfg, ci) },
        ));
    }
    let mut words = 0u64;
    let mut failures = 0u64;
    let mut lats: Vec<u64> = Vec::new();
    let mut sampled: Vec<(u64, String)> = Vec::new();
    for h in handles {
        let (w, f, l, t) =
            h.join().map_err(|_| "loadgen thread panicked".to_string())??;
        words += w;
        failures += f;
        lats.extend(l);
        sampled.extend(t);
    }
    let wall = t0.elapsed();
    // Slowest sampled request: fetch its span tree from the first
    // front so the report carries one concrete worst-case breakdown.
    // Best-effort — a 404/410 (evicted under load) just drops the tree.
    let slowest = sampled.into_iter().max_by_key(|(us, _)| *us);
    let (slowest_trace_id, slowest_trace) = match slowest {
        Some((_, id)) => {
            let tree =
                http_get(&cfg.addrs[0], &format!("/debug/trace/{id}"))
                    .ok()
                    .filter(|(status, _)| *status == 200)
                    .and_then(|(_, body)| json::parse(&body).ok());
            (Some(id), tree)
        }
        None => (None, None),
    };
    // Nearest-rank percentiles via the shared helper (the old local
    // picker truncated the rank and under-reported p95/p99).
    lats.sort_unstable();
    Ok(LoadReport {
        requests: lats.len() as u64 + failures,
        failures,
        words,
        wall,
        p50_us: percentile(&lats, 0.50),
        p95_us: percentile(&lats, 0.95),
        p99_us: percentile(&lats, 0.99),
        max_us: lats.last().copied().unwrap_or(0),
        slowest_trace_id,
        slowest_trace,
    })
}

/// Per-connection totals: (words, failures, latencies, sampled
/// latency/trace-ID pairs).
type ConnResult = (u64, u64, Vec<u64>, Vec<(u64, String)>);

fn connection_loop(
    cfg: &LoadgenConfig,
    ci: usize,
) -> Result<ConnResult, String> {
    let addr = &cfg.addrs[ci % cfg.addrs.len()];
    let stream = TcpStream::connect(addr)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let mut conn = HttpConn::new(stream);
    let mut rng = Rng::new(cfg.seed ^ (ci as u64).wrapping_mul(0x9E3779B9));
    let mut lats = Vec::with_capacity(cfg.requests_per_connection);
    let mut sampled: Vec<(u64, String)> = Vec::new();
    let mut failures = 0u64;
    let mut words_done = 0u64;
    let zipf = (cfg.zipf_s > 0.0)
        .then(|| ZipfCdf::new(cfg.models.len(), cfg.zipf_s));
    for r in 0..cfg.requests_per_connection {
        let model = match &zipf {
            // Skewed profile: models[0] is the hot key. The rank draw
            // shares the connection's seeded RNG, so runs replay.
            Some(z) => &cfg.models[z.draw(rng.f64())],
            None => &cfg.models[(ci + r) % cfg.models.len()],
        };
        let words: Vec<Json> = (0..cfg.words_per_request)
            .map(|_| {
                Json::Num(rng.range_i64(-cfg.word_range, cfg.word_range) as f64)
            })
            .collect();
        let body = json::write(&Json::Obj(
            [
                ("model".to_string(), Json::Str(model.clone())),
                ("words".to_string(), Json::Arr(words)),
            ]
            .into_iter()
            .collect(),
        ));
        let t = Instant::now();
        conn.write_request("POST", "/v1/batch", body.as_bytes())
            .map_err(|e| format!("write: {e}"))?;
        let (status, headers, _) =
            conn.read_response(1 << 22).map_err(|e| format!("read: {e}"))?;
        if status == 200 {
            let lat_us = t.elapsed().as_micros() as u64;
            lats.push(lat_us);
            words_done += cfg.words_per_request as u64;
            if cfg.trace_sample > 0 && r % cfg.trace_sample == 0 {
                if let Some(id) = headers.get(trace::TRACE_HEADER) {
                    sampled.push((lat_us, id.clone()));
                }
            }
        } else {
            failures += 1;
        }
    }
    Ok((words_done, failures, lats, sampled))
}

// ---------------------------------------------------------------------
// One-shot client helpers (tests, examples)
// ---------------------------------------------------------------------

fn connect(addr: &str) -> Result<HttpConn, String> {
    let stream =
        TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    Ok(HttpConn::new(stream))
}

/// Blocking GET; returns (status, body text).
pub fn http_get(addr: &str, path: &str) -> Result<(u16, String), String> {
    let mut conn = connect(addr)?;
    conn.write_request("GET", path, b"").map_err(|e| e.to_string())?;
    let (status, _, body) =
        conn.read_response(1 << 22).map_err(|e| e.to_string())?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

/// Blocking POST of a JSON value; returns (status, parsed JSON body).
pub fn http_post_json(
    addr: &str,
    path: &str,
    body: &Json,
) -> Result<(u16, Json), String> {
    let mut conn = connect(addr)?;
    conn.write_request("POST", path, json::write(body).as_bytes())
        .map_err(|e| e.to_string())?;
    let (status, _, resp) =
        conn.read_response(1 << 22).map_err(|e| e.to_string())?;
    let text = String::from_utf8_lossy(&resp);
    let parsed = json::parse(&text)
        .map_err(|e| format!("non-JSON response ({status}): {e}: {text}"))?;
    Ok((status, parsed))
}

/// Evaluate a word batch over HTTP; errors on any non-200.
pub fn eval_words(
    addr: &str,
    model: &str,
    words: &[i32],
) -> Result<Vec<i32>, String> {
    let body = Json::Obj(
        [
            ("model".to_string(), Json::Str(model.to_string())),
            (
                "words".to_string(),
                Json::Arr(words.iter().map(|&w| Json::Num(w as f64)).collect()),
            ),
        ]
        .into_iter()
        .collect(),
    );
    let (status, resp) = http_post_json(addr, "/v1/batch", &body)?;
    if status != 200 {
        return Err(format!("{status}: {}", json::write(&resp)));
    }
    resp.get("words")
        .and_then(Json::as_i64_vec)
        .map(|v| v.into_iter().map(|w| w as i32).collect())
        .ok_or_else(|| "response missing words".into())
}
