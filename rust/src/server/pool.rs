//! Per-peer keep-alive connection pool for the cluster's client legs.
//!
//! Before this module every proxied request, health probe, and gossip
//! exchange paid a fresh `TcpStream::connect` — a full TCP handshake on
//! the hot forward path. The pool amortizes that: after a successful
//! round trip the connection is checked back in and the next request to
//! the same peer reuses it.
//!
//! Design:
//!
//! * **Transport-generic.** The pool dials through a
//!   [`Transport`] and stores boxed [`Connection`]s: production uses
//!   [`TcpTransport`], the deterministic cluster simulation
//!   ([`super::sim`]) injects its virtual-time transport, and the pool
//!   bookkeeping (and every caller above it) is identical for both.
//! * **Bounded idle list per peer.** At most
//!   [`ConnPool::idle_per_peer`] connections are kept per address;
//!   checking in beyond the bound evicts the *least-recently-used*
//!   idle connection (the one most likely to have been dropped by the
//!   peer's keep-alive timer). `idle_per_peer == 0` disables pooling
//!   entirely — every checkout dials, every check-in discards — which
//!   is the control arm of the pooled-vs-unpooled bench point.
//! * **LIFO reuse.** [`ConnPool::checkout`] pops the most-recently-used
//!   idle connection, maximizing the chance it is still open on the
//!   peer side.
//! * **Clean connections only.** A connection is re-admitted only when
//!   it sits between messages ([`Connection::is_clean`]) and the
//!   peer didn't announce `Connection: close`; anything else is
//!   discarded so a desynchronized byte stream can never be handed to
//!   the next request.
//! * **Discard-and-redial is the caller's loop.**
//!   [`super::cluster::Cluster`] retries a failed round trip on a
//!   *reused* connection exactly once with a freshly dialed one — a
//!   pooled connection may have been closed by the peer at any time,
//!   so its first failure is expected background noise, while a fresh
//!   dial's failure is a real transport error.
//! * **Counters, not logs.** Hits/misses/discards/evictions are
//!   surfaced on `/metrics` (`tanhvf_cluster_pool_*`), so the reuse
//!   rate is observable in production.
//!
//! The pool is transport-only: it knows nothing about rings, health,
//! or request semantics. Those live in [`super::cluster`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::transport::{Connection, Deadlines, TcpTransport, Transport};
use crate::coordinator::metrics::Histogram;

/// Pool observability counters, surfaced on `/metrics`.
#[derive(Default)]
pub struct PoolStats {
    /// Checkouts served by an idle pooled connection.
    pub hits: AtomicU64,
    /// Checkouts that had to dial a fresh connection.
    pub misses: AtomicU64,
    /// Connections dropped instead of re-admitted (broken mid-request,
    /// dirty parser state, peer sent `Connection: close`, pool
    /// disabled).
    pub discards: AtomicU64,
    /// Idle connections evicted by the per-peer bound (LRU).
    pub evictions: AtomicU64,
    /// Wall-clock latency of fresh dials (pool misses and redials) —
    /// `tanhvf_cluster_pool_dial_seconds` on `/metrics`.
    pub dial_hist: Histogram,
}

/// A checked-out connection plus its provenance: `reused` tells the
/// caller whether a transport failure should trigger the
/// discard-and-redial retry (pooled connections fail benignly; fresh
/// ones don't).
pub struct Checked {
    pub conn: Box<dyn Connection>,
    pub reused: bool,
}

/// Keep-alive connection pool keyed by peer address.
pub struct ConnPool {
    idle_per_peer: usize,
    transport: Arc<dyn Transport>,
    /// Idle connections per peer, in last-used order (reuse pops the
    /// tail, eviction removes the front).
    idle: Mutex<HashMap<String, Vec<Box<dyn Connection>>>>,
    pub stats: PoolStats,
}

impl ConnPool {
    /// TCP-backed pool. `idle_per_peer` bounds the idle list per
    /// address; `0` disables pooling (every checkout dials fresh).
    pub fn new(idle_per_peer: usize) -> ConnPool {
        ConnPool::with_transport(idle_per_peer, Arc::new(TcpTransport))
    }

    /// Pool over an explicit transport (the simulation harness injects
    /// its virtual-time one here).
    pub fn with_transport(
        idle_per_peer: usize,
        transport: Arc<dyn Transport>,
    ) -> ConnPool {
        ConnPool {
            idle_per_peer,
            transport,
            idle: Mutex::new(HashMap::new()),
            stats: PoolStats::default(),
        }
    }

    /// The configured per-peer idle bound.
    pub fn idle_per_peer(&self) -> usize {
        self.idle_per_peer
    }

    /// Idle connections currently pooled (all peers) — the `/metrics`
    /// gauge.
    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().values().map(Vec::len).sum()
    }

    /// Get a connection to `addr`: the most-recently-used idle one if
    /// available (hit), else a fresh dial (miss). Deadlines are
    /// (re)applied on every checkout, so probe and proxy legs can
    /// share pooled connections under different budgets.
    pub fn checkout(
        &self,
        addr: &str,
        deadlines: &Deadlines,
    ) -> Result<Checked, String> {
        if let Some(mut conn) = self.pop_idle(addr) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            conn.set_deadlines(deadlines);
            return Ok(Checked { conn, reused: true });
        }
        self.dial_fresh(addr, deadlines)
    }

    /// Dial a fresh connection, bypassing the idle list — the redial
    /// half of discard-and-redial (counted as a miss).
    pub fn dial_fresh(
        &self,
        addr: &str,
        deadlines: &Deadlines,
    ) -> Result<Checked, String> {
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        let started = Instant::now();
        let conn = self.transport.connect(addr, deadlines)?;
        self.stats.dial_hist.observe(started.elapsed());
        Ok(Checked { conn, reused: false })
    }

    /// Return a connection after a successful round trip. Re-admits
    /// only clean connections; beyond the per-peer bound the
    /// least-recently-used idle connection is evicted.
    pub fn check_in(&self, addr: &str, conn: Box<dyn Connection>) {
        if self.idle_per_peer == 0 || !conn.is_clean() {
            self.stats.discards.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut idle = self.idle.lock().unwrap();
        let list = idle.entry(addr.to_string()).or_default();
        list.push(conn);
        if list.len() > self.idle_per_peer {
            // Entries are appended in last_used order and only popped
            // from the tail, so the front is always the LRU — and one
            // push can overshoot the cap by at most one.
            list.remove(0);
            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a connection dropped instead of returned (broken on the
    /// wire). The caller just drops the connection; this keeps the
    /// counter honest.
    pub fn note_discard(&self) {
        self.stats.discards.fetch_add(1, Ordering::Relaxed);
    }

    /// Drop every idle connection to `addr` (the peer was evicted from
    /// routing — its pooled connections are dead weight). Returns how
    /// many were dropped.
    pub fn purge(&self, addr: &str) -> usize {
        let purged = self
            .idle
            .lock()
            .unwrap()
            .remove(addr)
            .map(|l| l.len())
            .unwrap_or(0);
        self.stats.discards.fetch_add(purged as u64, Ordering::Relaxed);
        purged
    }

    fn pop_idle(&self, addr: &str) -> Option<Box<dyn Connection>> {
        let mut idle = self.idle.lock().unwrap();
        let list = idle.get_mut(addr)?;
        let conn = list.pop();
        if list.is_empty() {
            idle.remove(addr);
        }
        conn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::transport::TcpConnection;
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    fn budget() -> Deadlines {
        Deadlines::uniform(Duration::from_secs(1))
    }

    /// A loopback socket wrapped as a clean connection (the accept side
    /// is parked in the listener's backlog; these tests only exercise
    /// pool bookkeeping, not the wire).
    fn loopback_conn(l: &TcpListener) -> Box<dyn Connection> {
        let s = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        Box::new(TcpConnection::from_stream(s))
    }

    #[test]
    fn checkin_caps_idle_list_and_evicts_lru() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let pool = ConnPool::new(2);
        for _ in 0..3 {
            pool.check_in("peer-a", loopback_conn(&l));
        }
        assert_eq!(pool.idle_count(), 2);
        assert_eq!(pool.stats.evictions.load(Ordering::Relaxed), 1);
        // A different peer has its own bound.
        pool.check_in("peer-b", loopback_conn(&l));
        assert_eq!(pool.idle_count(), 3);
    }

    #[test]
    fn zero_cap_disables_pooling() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let pool = ConnPool::new(0);
        pool.check_in("peer", loopback_conn(&l));
        assert_eq!(pool.idle_count(), 0);
        assert_eq!(pool.stats.discards.load(Ordering::Relaxed), 1);
        // And checkout always dials (against the live listener).
        let addr = l.local_addr().unwrap().to_string();
        let c = pool.checkout(&addr, &budget()).unwrap();
        assert!(!c.reused);
        assert_eq!(pool.stats.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn checkout_prefers_pooled_connection() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let pool = ConnPool::new(4);
        pool.check_in(&addr, loopback_conn(&l));
        let c = pool.checkout(&addr, &budget()).unwrap();
        assert!(c.reused);
        assert_eq!(pool.stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(pool.stats.misses.load(Ordering::Relaxed), 0);
        assert_eq!(pool.idle_count(), 0);
    }

    #[test]
    fn purge_drops_all_idle_for_a_peer() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let pool = ConnPool::new(4);
        pool.check_in("dead", loopback_conn(&l));
        pool.check_in("dead", loopback_conn(&l));
        pool.check_in("live", loopback_conn(&l));
        assert_eq!(pool.purge("dead"), 2);
        assert_eq!(pool.idle_count(), 1);
        assert_eq!(pool.stats.discards.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn unresolvable_address_is_an_error() {
        let pool = ConnPool::new(1);
        assert!(pool
            .checkout(
                "definitely-not-a-host:0",
                &Deadlines::uniform(Duration::from_millis(50))
            )
            .is_err());
    }
}
