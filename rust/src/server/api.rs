//! JSON API endpoints over [`crate::coordinator::router::Router`].
//!
//! Endpoint layout follows the OpenAI-compatible serving shape of the
//! related inference-endpoint repos: model listing + health + metrics
//! next to the eval routes, with per-request model (= precision) names:
//!
//! * `GET  /health`     — liveness + uptime + build/backend identity.
//! * `GET  /v1/models`  — the route table, name-sorted.
//! * `POST /v1/eval`    — one word (or a float `x`) through one route.
//! * `POST /v1/batch`   — a packed word batch through one route.
//! * `GET  /metrics`    — Prometheus text: per-route coordinator
//!   [`Snapshot`](crate::coordinator::Snapshot)s + HTTP counters +
//!   latency histograms.
//! * `GET  /debug/trace/{id}` — the span tree this node holds for one
//!   trace ([`super::trace`]): 404 never seen, 410 evicted.
//!
//! The eval routes are traced: each dispatch opens a server span
//! (joining the sender's trace when `x-tanhvf-trace` is present),
//! every proxy forward and fan-out shard records a client-leg span,
//! and the response echoes the bare trace ID.
//!
//! Coordinator backpressure ("queue full") surfaces as 503 so closed-loop
//! clients can shed load; malformed bodies are 400, unknown models 404.
//!
//! In cluster mode ([`super::Server::start_cluster`]) the eval routes
//! first consult the consistent-hash ring: models owned by a peer are
//! proxied there (transport failures fail over along the ring, ending
//! in local service — this node is always its own live candidate),
//! models owned here — and every request already tagged as forwarded —
//! run through the local router unchanged. Two cluster-only behaviours
//! layer on top:
//!
//! * `POST /v1/gossip` — the membership exchange endpoint
//!   ([`super::gossip`]): merge the sender's member table, answer with
//!   ours. 404 outside cluster mode.
//! * **Batch read fan-out** — when a route's effective replica count
//!   exceeds one (base `--replicas`, or a hot-route expansion gossiped
//!   by the load-adaptive controller), a `/v1/batch`
//!   whose words outnumber the live replica set splits into contiguous
//!   shards, evaluates one shard per replica concurrently (the local
//!   shard on this thread), and merges in order. Bit-exactness makes
//!   the merge trivial: every replica computes the identical
//!   fixed-point function, so the split is invisible to the client.
//!   Any shard failure falls back to serving the whole batch locally.
//!
//! The eval routes parse bodies on a zero-copy path: the `words` array
//! (the dominant payload) streams straight into a reusable per-thread
//! [`arena`] buffer instead of materializing per-element [`Json`]
//! nodes, and the 200 batch body is written from that buffer without
//! an intermediate tree. Arena accounting surfaces in `/metrics` as
//! the `tanhvf_word_arena_*` families.

use std::fmt::Write as _;
use std::sync::atomic::Ordering;
use std::time::Instant;

use crate::coordinator::metrics::{HistSnapshot, HIST_BOUNDS_US};
use crate::coordinator::router::RouteInfo;
use crate::fixed::Round;
use crate::util::json::{self, Json, WordsField};

use super::arena;
use super::cluster::{self, Node};
use super::gossip;
use super::http::{Request, Response};
use super::trace::{self, Span, TraceQuery};
use super::AppState;

/// Route an HTTP request to its handler.
pub(crate) fn dispatch(state: &AppState, req: &Request) -> Response {
    match (req.method.as_str(), req.path()) {
        ("GET", "/health") => health(state),
        ("GET", "/v1/models") => models(state),
        ("GET", "/metrics") => render_metrics(state),
        ("POST", "/v1/eval") => traced(state, req, eval),
        ("POST", "/v1/batch") => traced(state, req, batch),
        ("POST", "/v1/gossip") => gossip_exchange(state, req),
        ("GET", path) if path.starts_with("/debug/trace/") => {
            debug_trace(state, path)
        }
        (_, "/health" | "/v1/models" | "/metrics") => {
            error_resp(405, "method_not_allowed", "endpoint is GET-only")
        }
        (_, "/v1/eval" | "/v1/batch" | "/v1/gossip") => {
            error_resp(405, "method_not_allowed", "endpoint is POST-only")
        }
        (_, path) => {
            error_resp(404, "not_found", &format!("no endpoint at {path}"))
        }
    }
}

/// Per-request trace context threaded through the routing shims: the
/// trace this request joined (or started) and the server span every
/// client leg nests under.
struct TraceCtx {
    trace: trace::TraceId,
    span: u64,
}

/// Tracing shim around the eval endpoints: open a server span —
/// joining the sender's trace when the request carries
/// [`trace::TRACE_HEADER`], else minting a fresh trace ID — run the
/// cluster routing shim under it, and stamp the bare trace ID on the
/// response so clients can fetch the tree from `/debug/trace/{id}`.
fn traced(
    state: &AppState,
    req: &Request,
    local: fn(&AppState, &ReqBody) -> Response,
) -> Response {
    let (trace_id, parent) = req
        .header(trace::TRACE_HEADER)
        .and_then(trace::decode_header)
        .unwrap_or_else(|| (state.trace.new_trace_id(), 0));
    let ctx = TraceCtx {
        trace: trace_id,
        span: state.trace.next_span_id(),
    };
    let mut span = Span::new(trace_id, ctx.span, parent, "server", req.path());
    span.start_us = state.clock.now_us();
    let resp = clustered(state, req, &ctx, local);
    span.end_us = state.clock.now_us();
    span.status = resp.status;
    // Slow-request logging keys on the client-facing root only —
    // proxied legs already surface as the caller's child spans.
    let is_root = parent == 0;
    if is_root {
        state.trace.push(span.clone());
        state.trace.maybe_log_slow(&span);
    } else {
        state.trace.push(span);
    }
    resp.with_header(trace::TRACE_HEADER, &trace_id.hex())
}

/// A request body parsed once per eval dispatch: the JSON document
/// (carrying an empty placeholder array under `words`), where the
/// `words` field went during parsing, and the arena-checked-out buffer
/// holding the decoded words themselves. [`clustered`] owns the
/// checkout/return lifecycle; handlers only borrow.
struct ReqBody {
    json: Json,
    words: WordsField,
    word_buf: Vec<i64>,
}

/// Parse an eval-route body on the zero-copy path: the `words` array
/// streams directly into this thread's arena buffer. Error responses
/// are byte-identical to the old `json_body()`-based path.
fn parse_body(raw: &[u8]) -> Result<ReqBody, Response> {
    let Ok(text) = std::str::from_utf8(raw) else {
        return Err(error_resp(
            400,
            "bad_request",
            "body: body is not valid UTF-8",
        ));
    };
    let mut word_buf = arena::take_words();
    match json::parse_request_words(text, &mut word_buf) {
        Ok((json, words)) => Ok(ReqBody { json, words, word_buf }),
        Err(e) => {
            arena::put_words(word_buf);
            Err(error_resp(400, "bad_request", &format!("body: {e}")))
        }
    }
}

/// Cluster routing shim around an eval endpoint: parse the body once
/// (words into the arena), route, and return the buffer whatever the
/// outcome.
fn clustered(
    state: &AppState,
    req: &Request,
    ctx: &TraceCtx,
    local: fn(&AppState, &ReqBody) -> Response,
) -> Response {
    let body = match parse_body(&req.body) {
        Ok(b) => b,
        Err(resp) => return resp,
    };
    let resp = routed(state, req, ctx, local, &body);
    arena::put_words(body.word_buf);
    resp
}

/// Serve a request through the local router while feeding the node's
/// load gauges: queue depth is the number of requests currently inside
/// this wrapper, and the measured wall time folds into the EWMA
/// latency that gossip advertises to peers (see
/// [`cluster::NodeLoad`]). Every local serving decision in [`routed`]
/// funnels through here so the advertised load can't silently drift
/// from reality.
fn serve_local(
    state: &AppState,
    cl: &cluster::Cluster,
    local: fn(&AppState, &ReqBody) -> Response,
    body: &ReqBody,
) -> Response {
    cl.load().begin_request();
    let start = state.clock.now_us();
    let resp = local(state, body);
    let end = state.clock.now_us();
    cl.load().end_request(end.saturating_sub(start));
    resp
}

/// The routing decision proper: serve locally when the ring says so
/// (or when not clustered), else forward to the owning peer, failing
/// over along the ring on transport errors.
fn routed(
    state: &AppState,
    req: &Request,
    ctx: &TraceCtx,
    local: fn(&AppState, &ReqBody) -> Response,
    body: &ReqBody,
) -> Response {
    let Some(cl) = state.cluster.as_ref() else {
        return local(state, body);
    };
    // Loop guard: a request that already crossed one hop is answered
    // here no matter what this node's ring says — transient ring
    // disagreement between fronts can cost one extra hop, never a
    // cycle.
    if req.header(cluster::PROXIED_HEADER).is_some() {
        cl.stats.proxied_in.fetch_add(1, Ordering::Relaxed);
        return serve_local(state, cl, local, body);
    }
    // The ring keys on the model name; bodies without one fall through
    // to the local handler, whose 400 is exact.
    let model = match body.json.get("model").and_then(Json::as_str) {
        Some(m) => m.to_string(),
        None => return serve_local(state, cl, local, body),
    };
    // Hot-route accounting: only client-facing requests count (the
    // proxied-in branch above returns before reaching here), so the
    // demand signal survives replica expansion instead of diluting
    // across the nodes the expansion recruited.
    cl.note_route_request(&model);
    // Replicated routes: a large-enough batch splits across the live
    // replica set instead of going to one owner. Returns None when the
    // fan-out doesn't apply (or can't complete) — the plain walk below
    // is the universal fallback. The gate reads the *effective*
    // replica count, so a hot-route expansion turns fan-out on for a
    // route even when the cluster started with `--replicas 1`.
    if req.path() == "/v1/batch" && cl.effective_replicas(&model) > 1 {
        if let Some(resp) = fanout_batch(state, cl, ctx, &model, body) {
            return resp;
        }
    }
    let mut failed_hops = 0u64;
    for node in cl.candidates(&model) {
        match node {
            Node::Local => {
                cl.stats.local.fetch_add(1, Ordering::Relaxed);
                if failed_hops > 0 {
                    cl.stats.failovers.fetch_add(1, Ordering::Relaxed);
                }
                return serve_local(state, cl, local, body);
            }
            Node::Peer(addr) => {
                // Bounded outbound-proxy concurrency: a forward blocks
                // this worker thread, and with every worker blocked on
                // forwards two fronts proxying to each other would
                // deadlock until the proxy timeout.
                let Some(_permit) = cl.try_forward_permit() else {
                    // Past the bound, prefer degrading to local
                    // bit-exact service (every node normally serves
                    // the full route table) over shedding; 503 only
                    // when this node really can't answer.
                    if state.router.route_info(&model).is_some() {
                        cl.stats.local.fetch_add(1, Ordering::Relaxed);
                        cl.stats.failovers.fetch_add(1, Ordering::Relaxed);
                        return serve_local(state, cl, local, body);
                    }
                    return error_resp(
                        503,
                        "overloaded",
                        "proxy capacity exhausted, retry later",
                    );
                };
                let fwd_id = state.trace.next_span_id();
                let hdr = trace::encode_header(ctx.trace, fwd_id);
                let mut fspan = Span::new(
                    ctx.trace,
                    fwd_id,
                    ctx.span,
                    "forward",
                    req.path(),
                );
                fspan.peer = addr.clone();
                if failed_hops > 0 {
                    fspan.note = format!("failover hop {failed_hops}");
                }
                fspan.start_us = state.clock.now_us();
                let started = Instant::now();
                let result = cl.forward(
                    &addr,
                    req.path(),
                    &req.body,
                    &[(trace::TRACE_HEADER, &hdr)],
                );
                cl.stats.forward_hist.observe(started.elapsed());
                fspan.end_us = state.clock.now_us();
                match result {
                    Ok(resp) => {
                        // HTTP-level statuses (including the peer's own
                        // 4xx/5xx) pass through untouched; only
                        // transport failures fail over.
                        fspan.status = resp.status;
                        state.trace.push(fspan);
                        cl.record_success(&addr);
                        cl.stats.proxied.fetch_add(1, Ordering::Relaxed);
                        if failed_hops > 0 {
                            cl.stats.failovers.fetch_add(1, Ordering::Relaxed);
                        }
                        return resp;
                    }
                    Err(e) => {
                        // Transport failure: status 0 marks a leg that
                        // died below HTTP; the next attempt is a
                        // sibling span annotated with its hop count.
                        if fspan.note.is_empty() {
                            fspan.note = e;
                        } else {
                            let _ = write!(fspan.note, ": {e}");
                        }
                        state.trace.push(fspan);
                        cl.stats.proxy_errors.fetch_add(1, Ordering::Relaxed);
                        cl.record_failure(&addr);
                        failed_hops += 1;
                    }
                }
            }
        }
    }
    // The ring always contains this node and Local is never filtered,
    // so the walk above always returns from inside the loop; this tail
    // is a defensive fallback, not a reachable error path.
    cl.stats.local.fetch_add(1, Ordering::Relaxed);
    serve_local(state, cl, local, body)
}

/// Split a `/v1/batch` across the live replica set and merge in order.
///
/// Returns `None` whenever the fan-out doesn't apply — fewer than two
/// live replicas, too few words to split, a body the plain path should
/// reject with its exact error, or no spare forward permits — and the
/// caller falls back to the ordinary ring walk. Mid-flight shard
/// failures degrade to serving the whole batch locally (every node
/// carries the full route table, and bit-exactness makes local service
/// indistinguishable).
fn fanout_batch(
    state: &AppState,
    cl: &cluster::Cluster,
    ctx: &TraceCtx,
    model: &str,
    body: &ReqBody,
) -> Option<Response> {
    // Anything other than a non-empty integer array is the plain
    // path's problem (its 400s are exact).
    let words: &[i64] = match body.words {
        WordsField::Ints { len } if len > 0 => &body.word_buf[..],
        _ => return None,
    };
    let info = state.router.route_info(model)?;
    if words.len() > info.batch_capacity {
        return None;
    }
    let reps = cl.live_replicas(model);
    if reps.len() < 2 || words.len() < reps.len() {
        return None;
    }
    let chunk = words.len().div_ceil(reps.len());
    let shards: Vec<&[i64]> = words.chunks(chunk).collect();
    // `chunks` can yield fewer shards than replicas; surplus replicas
    // simply sit this request out.
    let pairs: Vec<(&Node, &[i64])> =
        reps.iter().zip(shards).collect();
    // One permit per shard that actually goes remote, or no fan-out at
    // all (the plain walk degrades more gracefully under forward
    // pressure).
    let remote_shards = pairs
        .iter()
        .filter(|(n, _)| **n != Node::Local)
        .count();
    let mut permits = Vec::with_capacity(remote_shards);
    for _ in 0..remote_shards {
        permits.push(cl.try_forward_permit()?);
    }
    // Shard span IDs are allocated here, in shard order, before any
    // shard thread spawns — the ID stream is shared mutable state, and
    // a deterministic replay needs a deterministic allocation order.
    let shard_ids: Vec<u64> =
        pairs.iter().map(|_| state.trace.next_span_id()).collect();
    // Local shards keep their coordinator output; remote shards hand
    // back the raw response body, parsed into the merge buffer after
    // the join (the arena is thread-local, so shard threads can't
    // stream into it directly).
    enum ShardOut {
        Local(Vec<i32>),
        Remote(Vec<u8>),
    }
    let mut results: Vec<Option<ShardOut>> =
        (0..pairs.len()).map(|_| None).collect();
    // The local shard (shard 0 whenever this node is a replica —
    // live_replicas puts Local first) computes before the remote
    // shards spawn: local compute is microseconds against a remote
    // leg's network round trip, and running it first keeps its span
    // timestamps off the simulator's in-flight virtual clock, so a
    // replayed seed renders a bit-identical span tree.
    for (i, (node, words)) in pairs.iter().enumerate() {
        if matches!(node, Node::Local) {
            let mut lspan = Span::new(
                ctx.trace,
                shard_ids[i],
                ctx.span,
                "local",
                "/v1/batch",
            );
            lspan.note = format!("shard {i}");
            lspan.start_us = state.clock.now_us();
            // Straight into range-check + submit: the model resolved
            // above and a shard of an integer batch is an integer
            // batch within capacity.
            let out = run_batch_words(state, &info, words);
            lspan.end_us = state.clock.now_us();
            match out {
                Ok(ws) => {
                    lspan.status = 200;
                    results[i] = Some(ShardOut::Local(ws));
                }
                Err(resp) => lspan.status = resp.status,
            }
            state.trace.push(lspan);
        }
    }
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (i, (node, words)) in pairs.iter().enumerate() {
            if let Node::Peer(addr) = node {
                let wire = shard_wire(model, words);
                let span_id = shard_ids[i];
                handles.push((
                    i,
                    s.spawn(move || {
                        let hdr = trace::encode_header(ctx.trace, span_id);
                        let mut sspan = Span::new(
                            ctx.trace,
                            span_id,
                            ctx.span,
                            "shard",
                            "/v1/batch",
                        );
                        sspan.peer = addr.clone();
                        sspan.note = format!("shard {i}");
                        sspan.start_us = state.clock.now_us();
                        let started = Instant::now();
                        let result = cl.forward(
                            addr,
                            "/v1/batch",
                            wire.as_bytes(),
                            &[(trace::TRACE_HEADER, &hdr)],
                        );
                        cl.stats.shard_hist.observe(started.elapsed());
                        sspan.end_us = state.clock.now_us();
                        let out = match result {
                            Ok(resp) if resp.status == 200 => {
                                sspan.status = resp.status;
                                cl.record_success(addr);
                                cl.stats
                                    .proxied
                                    .fetch_add(1, Ordering::Relaxed);
                                // Body validity is checked at merge
                                // time, on the requesting thread.
                                Some(resp.body)
                            }
                            Ok(resp) => {
                                sspan.status = resp.status;
                                None
                            }
                            Err(e) => {
                                sspan.note = format!("shard {i}: {e}");
                                cl.stats
                                    .proxy_errors
                                    .fetch_add(1, Ordering::Relaxed);
                                cl.record_failure(addr);
                                None
                            }
                        };
                        state.trace.push(sspan);
                        out
                    }),
                ));
            }
        }
        for (i, h) in handles {
            results[i] = h.join().unwrap_or(None).map(ShardOut::Remote);
        }
    });
    drop(permits);
    // Merge in shard order into the thread's reusable merge buffer
    // (remote bodies parse here, so wrong counts and garbage bodies
    // surface as fallbacks exactly as before).
    let mut merged = arena::take_merge();
    let mut complete = true;
    for (i, r) in results.iter().enumerate() {
        let want = pairs[i].1.len();
        let ok = match r {
            Some(ShardOut::Local(ws)) => {
                merged.extend(ws.iter().map(|&w| w as i64));
                true // the coordinator answers word-for-word
            }
            Some(ShardOut::Remote(raw)) => {
                append_shard_words(raw, want, &mut merged)
            }
            None => false,
        };
        if !ok {
            complete = false;
            break;
        }
    }
    // The `local` path counter ticks at most once per client request
    // (the per-shard `proxied` ticks are real extra round trips, but a
    // locally computed shard plus a local fallback is still one local
    // serving decision).
    if !complete {
        // A shard failed: serve the whole batch locally, bit-exact.
        arena::put_merge(merged);
        cl.stats.fanout_fallbacks.fetch_add(1, Ordering::Relaxed);
        cl.stats.local.fetch_add(1, Ordering::Relaxed);
        return Some(batch(state, body));
    }
    cl.stats.fanout_batches.fetch_add(1, Ordering::Relaxed);
    if pairs.iter().any(|(n, _)| matches!(n, Node::Local)) {
        cl.stats.local.fetch_add(1, Ordering::Relaxed);
    }
    let resp =
        batch_ok_response(model, merged.len(), merged.iter().copied());
    arena::put_merge(merged);
    Some(resp)
}

/// The wire body for one remote shard, written straight from the word
/// slice (byte-identical to serializing the equivalent `Json` tree).
fn shard_wire(model: &str, words: &[i64]) -> String {
    let mut s = String::with_capacity(24 + model.len() + 8 * words.len());
    s.push_str("{\"model\":");
    s.push_str(&json::write(&Json::Str(model.to_string())));
    s.push_str(",\"words\":");
    json::write_i64_array(words, &mut s);
    s.push('}');
    s
}

/// Parse a successful shard response and append its words (which must
/// number `want` — a replica answering with the wrong count is treated
/// as a failure) to the merge buffer. Leaves the buffer untouched on
/// failure.
fn append_shard_words(raw: &[u8], want: usize, sink: &mut Vec<i64>) -> bool {
    let Ok(text) = std::str::from_utf8(raw) else {
        return false;
    };
    let start = sink.len();
    match json::parse_request_words(text, sink) {
        Ok((_, WordsField::Ints { len })) if len == want => true,
        _ => {
            sink.truncate(start);
            false
        }
    }
}

/// `POST /v1/gossip`: merge the sender's member table, answer with
/// ours (see [`super::gossip`] for the merge rules). 404 outside
/// cluster mode so a plain `serve-http` node is visibly not a gossip
/// participant.
fn gossip_exchange(state: &AppState, req: &Request) -> Response {
    let Some(cl) = state.cluster.as_ref() else {
        return error_resp(
            404,
            "not_found",
            "gossip requires cluster mode (serve-cluster)",
        );
    };
    // Gossip is a control-plane message with a known maximal size; the
    // server-wide body limit is sized for eval batches and far too
    // generous here.
    if req.body.len() > gossip::MAX_GOSSIP_BODY {
        return error_resp(
            413,
            "payload_too_large",
            &format!(
                "gossip body {} bytes exceeds the {} cap",
                req.body.len(),
                gossip::MAX_GOSSIP_BODY
            ),
        );
    }
    let body = match req.json_body() {
        Ok(b) => b,
        Err(e) => {
            return error_resp(400, "bad_request", &format!("body: {e}"))
        }
    };
    let msg = match gossip::decode(&body) {
        Ok(m) => m,
        Err(e) => return error_resp(400, "bad_request", &e),
    };
    cl.stats.gossip_in.fetch_add(1, Ordering::Relaxed);
    cl.apply_remote_members(&msg.members);
    cl.apply_remote_routes(&msg.routes);
    Response::json(
        200,
        &gossip::encode(
            cl.self_name(),
            &cl.member_entries(),
            &cl.route_overrides_wire(),
        ),
    )
}

// ---------------------------------------------------------------------
// Handlers
// ---------------------------------------------------------------------

/// `GET /debug/trace/{id}`: whatever span tree this node still holds
/// for one trace. 404 for IDs never seen here, 410 once the ring has
/// evicted every span of a trace it did see.
fn debug_trace(state: &AppState, path: &str) -> Response {
    let hex = &path["/debug/trace/".len()..];
    let Some(id) = trace::TraceId::parse(hex) else {
        return error_resp(
            400,
            "bad_request",
            "trace id must be 32 hex characters",
        );
    };
    match state.trace.lookup(id) {
        TraceQuery::Found(spans) => Response::json(
            200,
            &obj([
                ("trace_id", Json::Str(id.hex())),
                ("span_count", Json::Num(spans.len() as f64)),
                ("spans", trace::span_tree_json(&spans)),
            ]),
        ),
        TraceQuery::Evicted => error_resp(
            410,
            "gone",
            "spans for this trace were evicted from the ring buffer",
        ),
        TraceQuery::Unknown => error_resp(
            404,
            "not_found",
            "no spans recorded here for this trace id",
        ),
    }
}

fn health(state: &AppState) -> Response {
    let uptime = state.started.elapsed().as_secs() as f64;
    let mut fields = vec![
        ("status", Json::Str("ok".into())),
        ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
        ("backend", Json::Str(state.backend.into())),
        // `uptime_s` predates `uptime_seconds`; both stay because
        // external checks grep for either spelling.
        ("uptime_s", Json::Num(uptime)),
        ("uptime_seconds", Json::Num(uptime)),
        ("routes", Json::Num(state.router.route_infos().len() as f64)),
    ];
    if let Some(cl) = &state.cluster {
        fields.push((
            "cluster_nodes",
            Json::Num(cl.ring().nodes().len() as f64),
        ));
        fields.push((
            "cluster_live_peers",
            Json::Num(cl.healthy_peers() as f64),
        ));
        fields.push((
            "cluster_members",
            Json::Num(cl.alive_members() as f64),
        ));
        fields.push((
            "cluster_membership_version",
            Json::Num(cl.membership_version() as f64),
        ));
        // The peer table: gossip-convergence checks read this.
        fields.push((
            "cluster_peers",
            Json::Obj(
                cl.peer_health()
                    .into_iter()
                    .map(|(a, h)| (a, Json::Str(h.name().into())))
                    .collect(),
            ),
        ));
    }
    Response::json(200, &obj(fields))
}

fn models(state: &AppState) -> Response {
    let cl = state.cluster.as_ref();
    let data: Vec<Json> = state
        .router
        .route_infos()
        .iter()
        .map(|i| {
            let mut fields = vec![
                ("id", Json::Str(i.name.clone())),
                ("object", Json::Str("model".into())),
                ("backend", Json::Str(i.kind.into())),
                ("detail", Json::Str(i.detail.clone())),
                ("batch_capacity", Json::Num(i.batch_capacity as f64)),
                ("workers", Json::Num(i.workers as f64)),
                ("queue_limit", Json::Num(i.queue_limit as f64)),
            ];
            if let Some(cl) = cl {
                // Peer-aware: where the ring currently routes this
                // model (liveness applied), and whether that is here.
                let owner =
                    cl.owner_name(&i.name).unwrap_or_else(|| "none".into());
                fields.push((
                    "local",
                    Json::Bool(owner == cl.self_name()),
                ));
                fields.push(("owner", Json::Str(owner)));
                fields.push((
                    "replicas",
                    Json::Arr(
                        cl.replica_set(&i.name)
                            .into_iter()
                            .map(Json::Str)
                            .collect(),
                    ),
                ));
            }
            obj(fields)
        })
        .collect();
    let mut top = vec![
        ("object", Json::Str("list".into())),
        ("data", Json::Arr(data)),
    ];
    if let Some(cl) = cl {
        let peers: Vec<Json> = cl
            .peer_health()
            .into_iter()
            .map(|(addr, h)| {
                obj([
                    ("addr", Json::Str(addr)),
                    ("health", Json::Str(h.name().into())),
                ])
            })
            .collect();
        top.push((
            "cluster",
            obj([
                ("self", Json::Str(cl.self_name().into())),
                (
                    "nodes",
                    Json::Arr(
                        cl.ring()
                            .nodes()
                            .iter()
                            .map(|n| Json::Str(n.clone()))
                            .collect(),
                    ),
                ),
                ("peers", Json::Arr(peers)),
                (
                    "virtual_nodes",
                    Json::Num(cl.config().virtual_nodes as f64),
                ),
                ("replicas", Json::Num(cl.config().replicas as f64)),
                (
                    "membership_version",
                    Json::Num(cl.membership_version() as f64),
                ),
            ]),
        ));
    }
    Response::json(200, &obj(top))
}

fn eval(state: &AppState, body: &ReqBody) -> Response {
    let body = &body.json;
    let info = match resolve_model(state, body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let word = match (body.get("word"), body.get("x")) {
        (Some(w), None) => match as_exact_i64(w) {
            Some(w) => w,
            None => {
                return error_resp(400, "bad_request", "word must be an integer")
            }
        },
        (None, Some(x)) => {
            let Some(x) = x.as_f64() else {
                return error_resp(400, "bad_request", "x must be a number");
            };
            let Some(cfg) = info.native_cfg else {
                return error_resp(
                    400,
                    "bad_request",
                    "float x needs a native route (send a fixed-point word)",
                );
            };
            cfg.in_format().quantize(x, Round::Nearest)
        }
        _ => {
            return error_resp(
                400,
                "bad_request",
                "body needs exactly one of word (int) or x (float)",
            )
        }
    };
    if let Some(resp) = check_words(&info, &[word]) {
        return resp;
    }
    match submit(state, &info.name, vec![word as i32]) {
        Err(resp) => resp,
        Ok(out) => {
            let y_word = out[0] as i64;
            let mut fields = vec![
                ("model", Json::Str(info.name.clone())),
                ("word", Json::Num(word as f64)),
                ("y_word", Json::Num(y_word as f64)),
            ];
            if let Some(cfg) = info.native_cfg {
                fields.push((
                    "y",
                    Json::Num(cfg.out_format().dequantize(y_word)),
                ));
            }
            Response::json(200, &obj(fields))
        }
    }
}

fn batch(state: &AppState, body: &ReqBody) -> Response {
    let info = match resolve_model(state, &body.json) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    // Error precedence matches the old tree-walking validator exactly:
    // array-ness, emptiness, capacity (on the raw element count), then
    // element types.
    let len = match body.words {
        WordsField::Absent | WordsField::NotArray => {
            return error_resp(400, "bad_request", "words must be an array")
        }
        WordsField::NotInt { len } | WordsField::Ints { len } => len,
    };
    if len == 0 {
        return error_resp(400, "bad_request", "words must be non-empty");
    }
    if len > info.batch_capacity {
        return error_resp(
            400,
            "bad_request",
            &format!(
                "{len} words exceeds batch_capacity {} of model '{}'",
                info.batch_capacity, info.name
            ),
        );
    }
    if !matches!(body.words, WordsField::Ints { .. }) {
        return error_resp(400, "bad_request", "words must all be integers");
    }
    match run_batch_words(state, &info, &body.word_buf) {
        Err(resp) => resp,
        Ok(out) => batch_ok_response(
            &info.name,
            out.len(),
            out.iter().map(|&w| w as i64),
        ),
    }
}

/// The post-validation core of [`batch`]: range-check and submit a
/// word slice (shared with the per-shard local path of
/// [`fanout_batch`], which has already validated shape and capacity).
fn run_batch_words(
    state: &AppState,
    info: &RouteInfo,
    words: &[i64],
) -> Result<Vec<i32>, Response> {
    if let Some(resp) = check_words(info, words) {
        return Err(resp);
    }
    let words32: Vec<i32> = words.iter().map(|&w| w as i32).collect();
    submit(state, &info.name, words32)
}

/// The 200 batch body, written straight from the output words — no
/// intermediate `Json` tree. Field order (alphabetical) and number
/// formatting are byte-identical to the old `BTreeMap`-backed writer;
/// the multi-node CI byte-compares fan-out responses against
/// single-node ones, so this parity is load-bearing.
fn batch_ok_response(
    model: &str,
    count: usize,
    words: impl Iterator<Item = i64>,
) -> Response {
    let mut body = String::with_capacity(48 + model.len() + 8 * count);
    body.push_str("{\"count\":");
    let _ = write!(body, "{count}");
    body.push_str(",\"model\":");
    body.push_str(&json::write(&Json::Str(model.to_string())));
    body.push_str(",\"words\":[");
    for (i, w) in words.enumerate() {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(body, "{w}");
    }
    body.push_str("]}");
    Response {
        status: 200,
        content_type: "application/json".into(),
        body: body.into_bytes(),
        headers: Vec::new(),
    }
}

/// Write one metric family's `# HELP`/`# TYPE` preamble. Prometheus
/// exposition requires the pair once per family, before its samples;
/// the wire test in `server_e2e` asserts the pairing for every family.
fn family(s: &mut String, name: &str, typ: &str, help: &str) {
    let _ = writeln!(s, "# HELP {name} {help}");
    let _ = writeln!(s, "# TYPE {name} {typ}");
}

/// Write one histogram's samples: cumulative `_bucket`s over the fixed
/// log-spaced bounds (`le` in seconds), the `+Inf` bucket, `_sum`, and
/// `_count`. `labels` is either empty or a ready `k="v"` list without
/// braces. The caller emits the `family` preamble once per family.
fn hist_samples(
    s: &mut String,
    name: &str,
    labels: &str,
    snap: &HistSnapshot,
) {
    let sep = if labels.is_empty() { "" } else { "," };
    let mut cum = 0u64;
    for (i, &bound_us) in HIST_BOUNDS_US.iter().enumerate() {
        cum += snap.buckets[i];
        let _ = writeln!(
            s,
            "{name}_bucket{{{labels}{sep}le=\"{}\"}} {cum}",
            bound_us as f64 / 1e6
        );
    }
    let _ = writeln!(
        s,
        "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}",
        cum + snap.inf
    );
    let sum_s = snap.sum_us as f64 / 1e6;
    if labels.is_empty() {
        let _ = writeln!(s, "{name}_sum {sum_s}");
        let _ = writeln!(s, "{name}_count {}", snap.count);
    } else {
        let _ = writeln!(s, "{name}_sum{{{labels}}} {sum_s}");
        let _ = writeln!(s, "{name}_count{{{labels}}} {}", snap.count);
    }
}

pub(crate) fn render_metrics(state: &AppState) -> Response {
    let mut s = String::new();
    let h = &state.http;
    family(
        &mut s,
        "tanhvf_http_connections_total",
        "counter",
        "TCP connections accepted by the front end.",
    );
    let _ = writeln!(
        s,
        "tanhvf_http_connections_total {}",
        h.connections.load(Ordering::Relaxed)
    );
    family(
        &mut s,
        "tanhvf_http_rejected_connections_total",
        "counter",
        "Connections answered 503 at the open-connection limit.",
    );
    let _ = writeln!(
        s,
        "tanhvf_http_rejected_connections_total {}",
        h.rejected_connections.load(Ordering::Relaxed)
    );
    family(
        &mut s,
        "tanhvf_http_requests_total",
        "counter",
        "HTTP requests parsed and dispatched.",
    );
    let _ = writeln!(
        s,
        "tanhvf_http_requests_total {}",
        h.requests.load(Ordering::Relaxed)
    );
    family(
        &mut s,
        "tanhvf_http_responses_total",
        "counter",
        "HTTP responses by status class.",
    );
    for (class, v) in [
        ("2xx", &h.responses_2xx),
        ("4xx", &h.responses_4xx),
        ("5xx", &h.responses_5xx),
    ] {
        let _ = writeln!(
            s,
            "tanhvf_http_responses_total{{class=\"{class}\"}} {}",
            v.load(Ordering::Relaxed)
        );
    }
    family(
        &mut s,
        "tanhvf_uptime_seconds",
        "gauge",
        "Seconds since this server started.",
    );
    let _ = writeln!(
        s,
        "tanhvf_uptime_seconds {}",
        state.started.elapsed().as_secs()
    );

    // Per-route coordinator metrics: family preamble once, then one
    // sample per route.
    let snaps = state.router.snapshots();
    family(
        &mut s,
        "tanhvf_requests_submitted_total",
        "counter",
        "Eval words admitted to a route's queue.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_requests_submitted_total{{route=\"{route}\"}} {}",
            snap.submitted
        );
    }
    family(
        &mut s,
        "tanhvf_requests_completed_total",
        "counter",
        "Requests completed by a route's workers.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_requests_completed_total{{route=\"{route}\"}} {}",
            snap.completed
        );
    }
    family(
        &mut s,
        "tanhvf_requests_rejected_total",
        "counter",
        "Requests shed by queue-limit backpressure.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_requests_rejected_total{{route=\"{route}\"}} {}",
            snap.rejected
        );
    }
    family(
        &mut s,
        "tanhvf_batches_total",
        "counter",
        "Packed batches executed by a route's backend.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_batches_total{{route=\"{route}\"}} {}",
            snap.batches
        );
    }
    family(
        &mut s,
        "tanhvf_batch_fill_ratio",
        "gauge",
        "Mean fraction of batch capacity used.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_batch_fill_ratio{{route=\"{route}\"}} {:.4}",
            snap.mean_batch_fill
        );
    }
    family(
        &mut s,
        "tanhvf_latency_microseconds",
        "gauge",
        "Request latency quantiles over the retained window.",
    );
    for (route, snap) in &snaps {
        for (q, v) in [
            ("0.5", snap.p50_latency_us),
            ("0.95", snap.p95_latency_us),
            ("0.99", snap.p99_latency_us),
            ("1.0", snap.max_latency_us),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_latency_microseconds{{route=\"{route}\",quantile=\"{q}\"}} {v}"
            );
        }
    }
    family(
        &mut s,
        "tanhvf_request_duration_seconds",
        "histogram",
        "End-to-end request latency through a route's coordinator.",
    );
    for (route, snap) in &snaps {
        hist_samples(
            &mut s,
            "tanhvf_request_duration_seconds",
            &format!("route=\"{route}\""),
            &snap.latency_hist,
        );
    }

    // Trace-store accounting: present on every node (single-node
    // fronts trace too).
    family(
        &mut s,
        "tanhvf_spans_dropped_total",
        "counter",
        "Trace spans evicted by the bounded span ring.",
    );
    let _ = writeln!(
        s,
        "tanhvf_spans_dropped_total {}",
        state.trace.spans_dropped()
    );
    family(
        &mut s,
        "tanhvf_trace_store_bytes",
        "gauge",
        "Approximate bytes currently held by the trace span ring.",
    );
    let _ = writeln!(s, "tanhvf_trace_store_bytes {}", state.trace.bytes());

    // Request-arena accounting: the zero-copy word path. A warm server
    // shows checkouts rising with request count while allocs stay flat
    // — that flatness is what `tests/zero_copy.rs` asserts.
    let (checkouts, allocs, bytes) = arena::stats();
    family(
        &mut s,
        "tanhvf_word_arena_checkouts_total",
        "counter",
        "Word-buffer checkouts by the eval routes (one per request).",
    );
    let _ = writeln!(s, "tanhvf_word_arena_checkouts_total {checkouts}");
    family(
        &mut s,
        "tanhvf_word_arena_allocs_total",
        "counter",
        "Checkouts that grew an arena buffer (flat once warm).",
    );
    let _ = writeln!(s, "tanhvf_word_arena_allocs_total {allocs}");
    family(
        &mut s,
        "tanhvf_word_arena_bytes",
        "gauge",
        "Bytes currently held by all per-thread word arenas.",
    );
    let _ = writeln!(s, "tanhvf_word_arena_bytes {bytes}");

    if let Some(cl) = &state.cluster {
        family(
            &mut s,
            "tanhvf_cluster_peer_up",
            "gauge",
            "1 when the peer is routable, 0 when evicted or dead.",
        );
        for (addr, h) in cl.peer_health() {
            let up = (h != cluster::PeerHealth::Down) as u32;
            let _ = writeln!(
                s,
                "tanhvf_cluster_peer_up{{peer=\"{addr}\",state=\"{}\"}} {up}",
                h.name()
            );
        }
        family(
            &mut s,
            "tanhvf_cluster_ring_nodes",
            "gauge",
            "Nodes currently hashed onto the ring (alive members).",
        );
        let _ = writeln!(
            s,
            "tanhvf_cluster_ring_nodes {}",
            cl.ring().nodes().len()
        );
        family(
            &mut s,
            "tanhvf_cluster_members",
            "gauge",
            "Gossip member table entries by liveness.",
        );
        let members = cl.members();
        let alive = members.values().filter(|m| m.alive).count();
        let _ = writeln!(
            s,
            "tanhvf_cluster_members{{state=\"alive\"}} {alive}"
        );
        let _ = writeln!(
            s,
            "tanhvf_cluster_members{{state=\"dead\"}} {}",
            members.len() - alive
        );
        family(
            &mut s,
            "tanhvf_cluster_membership_version",
            "gauge",
            "Ring rebuild count (bumps on join, death, resurrection).",
        );
        let _ = writeln!(
            s,
            "tanhvf_cluster_membership_version {}",
            cl.membership_version()
        );
        let st = &cl.stats;
        family(
            &mut s,
            "tanhvf_cluster_requests_total",
            "counter",
            "Eval/batch requests by serving path.",
        );
        for (name, v) in [
            ("local", &st.local),
            ("proxied", &st.proxied),
            ("proxied_in", &st.proxied_in),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_requests_total{{path=\"{name}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        for (name, v, help) in [
            (
                "tanhvf_cluster_proxy_errors_total",
                &st.proxy_errors,
                "Transport failures on the proxy leg.",
            ),
            (
                "tanhvf_cluster_failovers_total",
                &st.failovers,
                "Requests served by a non-first ring candidate.",
            ),
            (
                "tanhvf_cluster_evictions_total",
                &st.evictions,
                "Peer transitions into routing eviction.",
            ),
            (
                "tanhvf_cluster_readmissions_total",
                &st.readmissions,
                "Evicted peers re-admitted to routing.",
            ),
            (
                "tanhvf_cluster_fanout_batches_total",
                &st.fanout_batches,
                "Batches served by splitting across replicas.",
            ),
            (
                "tanhvf_cluster_fanout_fallbacks_total",
                &st.fanout_fallbacks,
                "Fan-outs abandoned and served whole locally.",
            ),
            (
                "tanhvf_cluster_gossip_refutations_total",
                &st.gossip_refutations,
                "Dead reports about this node refuted with a bumped incarnation.",
            ),
            (
                "tanhvf_cluster_tombstone_evictions_total",
                &st.tombstone_evictions,
                "Tombstones evicted to admit joins at the member-table bound.",
            ),
        ] {
            family(&mut s, name, "counter", help);
            let _ = writeln!(s, "{name} {}", v.load(Ordering::Relaxed));
        }
        family(
            &mut s,
            "tanhvf_cluster_gossip_total",
            "counter",
            "Gossip exchanges by direction and outcome.",
        );
        for (event, v) in [
            ("sent_ok", &st.gossip_ok),
            ("sent_fail", &st.gossip_fail),
            ("received", &st.gossip_in),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_gossip_total{{event=\"{event}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        family(
            &mut s,
            "tanhvf_cluster_membership_events_total",
            "counter",
            "Member table changes by kind.",
        );
        for (event, v) in [
            ("join", &st.members_joined),
            ("death", &st.members_died),
            ("resurrection", &st.members_resurrected),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_membership_events_total{{event=\"{event}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        // Load-adaptive routing: effective per-route replica counts
        // (base `--replicas` plus any hot-route expansion), the p2c
        // selection split, the queue depth p2c observed on its chosen
        // replicas, and this node's own advertised load stanza.
        family(
            &mut s,
            "tanhvf_route_replicas",
            "gauge",
            "Effective replica count per route (base + hot-route expansion).",
        );
        for info in state.router.route_infos() {
            let _ = writeln!(
                s,
                "tanhvf_route_replicas{{route=\"{}\"}} {}",
                info.name,
                cl.effective_replicas(&info.name)
            );
        }
        family(
            &mut s,
            "tanhvf_p2c_selections_total",
            "counter",
            "Read-routing decisions by mode (local-first, p2c, rotation).",
        );
        for (mode, v) in [
            ("local", &st.p2c_local_picks),
            ("load", &st.p2c_load_picks),
            ("rotation", &st.p2c_rotation_picks),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_p2c_selections_total{{mode=\"{mode}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        family(
            &mut s,
            "tanhvf_p2c_chosen_queue_depth",
            "histogram",
            "Advertised queue depth of the replica p2c selected.",
        );
        {
            let (cum, count, sum) = st.p2c_depth_hist.snapshot();
            for (i, b) in cluster::DEPTH_BOUNDS.iter().enumerate() {
                let _ = writeln!(
                    s,
                    "tanhvf_p2c_chosen_queue_depth_bucket{{le=\"{b}\"}} {}",
                    cum[i]
                );
            }
            let _ = writeln!(
                s,
                "tanhvf_p2c_chosen_queue_depth_bucket{{le=\"+Inf\"}} {count}"
            );
            let _ = writeln!(s, "tanhvf_p2c_chosen_queue_depth_sum {sum}");
            let _ = writeln!(s, "tanhvf_p2c_chosen_queue_depth_count {count}");
        }
        family(
            &mut s,
            "tanhvf_cluster_route_transitions_total",
            "counter",
            "Hot-route replica-count transitions by direction.",
        );
        for (kind, v) in [
            ("expand", &st.route_expansions),
            ("shrink", &st.route_shrinks),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_route_transitions_total{{kind=\"{kind}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        family(
            &mut s,
            "tanhvf_cluster_node_load",
            "gauge",
            "This node's advertised load stanza (what gossip carries).",
        );
        {
            let l = cl.load().peek();
            for (kind, v) in [
                ("queue_depth", l.queue_depth),
                ("ewma_latency_us", l.ewma_latency_us),
                ("arena_bytes", l.arena_bytes),
            ] {
                let _ = writeln!(
                    s,
                    "tanhvf_cluster_node_load{{kind=\"{kind}\"}} {v}"
                );
            }
        }
        let ps = &cl.pool.stats;
        family(
            &mut s,
            "tanhvf_cluster_pool_checkouts_total",
            "counter",
            "Connection-pool checkouts by outcome (hit = reused).",
        );
        for (result, v) in [("hit", &ps.hits), ("miss", &ps.misses)] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_pool_checkouts_total{{result=\"{result}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        for (name, v, help) in [
            (
                "tanhvf_cluster_pool_discards_total",
                &ps.discards,
                "Pooled connections dropped instead of re-admitted.",
            ),
            (
                "tanhvf_cluster_pool_evictions_total",
                &ps.evictions,
                "Idle connections evicted by the per-peer bound.",
            ),
        ] {
            family(&mut s, name, "counter", help);
            let _ = writeln!(s, "{name} {}", v.load(Ordering::Relaxed));
        }
        family(
            &mut s,
            "tanhvf_cluster_pool_idle_connections",
            "gauge",
            "Idle keep-alive connections currently pooled.",
        );
        let _ = writeln!(
            s,
            "tanhvf_cluster_pool_idle_connections {}",
            cl.pool.idle_count()
        );
        // Client-leg latency histograms: one family per leg kind.
        for (name, hist, help) in [
            (
                "tanhvf_cluster_forward_duration_seconds",
                &st.forward_hist,
                "Proxy-forward round trips to the ring owner.",
            ),
            (
                "tanhvf_cluster_shard_duration_seconds",
                &st.shard_hist,
                "Remote fan-out shard round trips.",
            ),
            (
                "tanhvf_cluster_gossip_round_duration_seconds",
                &st.gossip_round_hist,
                "Full outbound gossip rounds (all fan-out targets).",
            ),
            (
                "tanhvf_cluster_pool_dial_seconds",
                &ps.dial_hist,
                "Fresh connection dials (pool misses and redials).",
            ),
        ] {
            family(&mut s, name, "histogram", help);
            hist_samples(&mut s, name, "", &hist.snapshot());
        }
    }
    Response::text(200, &s)
}

// ---------------------------------------------------------------------
// Shared pieces
// ---------------------------------------------------------------------

/// Resolve a parsed body's `model` to a route (the body is parsed once
/// in [`clustered`], before any routing decision).
fn resolve_model(
    state: &AppState,
    body: &Json,
) -> Result<RouteInfo, Response> {
    let Some(model) = body.get("model").and_then(Json::as_str) else {
        return Err(error_resp(400, "bad_request", "model (string) required"));
    };
    state.router.route_info(model).ok_or_else(|| {
        error_resp(
            404,
            "unknown_model",
            &format!("no model '{model}' (see /v1/models)"),
        )
    })
}

/// Range-check words against the route's input format, when known. The
/// memoized native unit indexes a full table, so out-of-range words must
/// be rejected here rather than trusted to the backend.
fn check_words(info: &RouteInfo, words: &[i64]) -> Option<Response> {
    let limit = match info.native_cfg {
        Some(cfg) => 1i64 << cfg.mag_bits(),
        None => 1i64 << 31, // pjrt: anything that fits the i32 wire type
    };
    for &w in words {
        if w < -limit || w >= limit {
            return Some(error_resp(
                400,
                "bad_request",
                &format!(
                    "word {w} outside [{}, {}) for model '{}'",
                    -limit, limit, info.name
                ),
            ));
        }
    }
    None
}

/// Submit to the router and map failures to HTTP statuses.
fn submit(
    state: &AppState,
    route: &str,
    words: Vec<i32>,
) -> Result<Vec<i32>, Response> {
    let rx = state
        .router
        .submit(route, words)
        .map_err(|e| error_resp(404, "unknown_model", &e))?;
    match rx.recv_timeout(state.request_timeout) {
        None => Err(error_resp(
            504,
            "timeout",
            "backend did not answer in time",
        )),
        Some(Err(e)) if e.contains("queue full") => Err(error_resp(
            503,
            "overloaded",
            "route queue is full, retry later",
        )),
        Some(Err(e)) if e.contains("outside 1..=") => {
            Err(error_resp(400, "bad_request", &e))
        }
        Some(Err(e)) => Err(error_resp(500, "backend_error", &e)),
        Some(Ok(out)) => Ok(out),
    }
}

/// Integer-valued JSON number (rejects 1.5 and non-numbers). Shares
/// [`json::exact_i64`] so the scalar `word` field and the streamed
/// `words` array agree on what counts as an integer.
fn as_exact_i64(v: &Json) -> Option<i64> {
    json::exact_i64(v)
}

fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Uniform error body: `{"error":{"code":...,"message":...}}`.
pub(crate) fn error_resp(status: u16, code: &str, message: &str) -> Response {
    Response::json(
        status,
        &obj([(
            "error",
            obj([
                ("code", Json::Str(code.into())),
                ("message", Json::Str(message.into())),
                ("status", Json::Num(status as f64)),
            ]),
        )]),
    )
}
