//! JSON API endpoints over [`crate::coordinator::router::Router`].
//!
//! Endpoint layout follows the OpenAI-compatible serving shape of the
//! related inference-endpoint repos: model listing + health + metrics
//! next to the eval routes, with per-request model (= precision) names:
//!
//! * `GET  /health`     — liveness + uptime.
//! * `GET  /v1/models`  — the route table, name-sorted.
//! * `POST /v1/eval`    — one word (or a float `x`) through one route.
//! * `POST /v1/batch`   — a packed word batch through one route.
//! * `GET  /metrics`    — Prometheus text: per-route coordinator
//!   [`Snapshot`](crate::coordinator::Snapshot)s + HTTP counters.
//!
//! Coordinator backpressure ("queue full") surfaces as 503 so closed-loop
//! clients can shed load; malformed bodies are 400, unknown models 404.
//!
//! In cluster mode ([`super::Server::start_cluster`]) the eval routes
//! first consult the consistent-hash ring: models owned by a peer are
//! proxied there (transport failures fail over along the ring, ending
//! in local service — this node is always its own live candidate),
//! models owned here — and every request already tagged as forwarded —
//! run through the local router unchanged. Two cluster-only behaviours
//! layer on top:
//!
//! * `POST /v1/gossip` — the membership exchange endpoint
//!   ([`super::gossip`]): merge the sender's member table, answer with
//!   ours. 404 outside cluster mode.
//! * **Batch read fan-out** — with `--replicas N > 1`, a `/v1/batch`
//!   whose words outnumber the live replica set splits into contiguous
//!   shards, evaluates one shard per replica concurrently (the local
//!   shard on this thread), and merges in order. Bit-exactness makes
//!   the merge trivial: every replica computes the identical
//!   fixed-point function, so the split is invisible to the client.
//!   Any shard failure falls back to serving the whole batch locally.

use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use crate::coordinator::router::RouteInfo;
use crate::fixed::Round;
use crate::util::json::{self, Json};

use super::cluster::{self, Node};
use super::gossip;
use super::http::{Request, Response};
use super::AppState;

/// Route an HTTP request to its handler.
pub(crate) fn dispatch(state: &AppState, req: &Request) -> Response {
    match (req.method.as_str(), req.path()) {
        ("GET", "/health") => health(state),
        ("GET", "/v1/models") => models(state),
        ("GET", "/metrics") => render_metrics(state),
        ("POST", "/v1/eval") => clustered(state, req, eval),
        ("POST", "/v1/batch") => clustered(state, req, batch),
        ("POST", "/v1/gossip") => gossip_exchange(state, req),
        (_, "/health" | "/v1/models" | "/metrics") => {
            error_resp(405, "method_not_allowed", "endpoint is GET-only")
        }
        (_, "/v1/eval" | "/v1/batch" | "/v1/gossip") => {
            error_resp(405, "method_not_allowed", "endpoint is POST-only")
        }
        (_, path) => {
            error_resp(404, "not_found", &format!("no endpoint at {path}"))
        }
    }
}

/// Cluster routing shim around an eval endpoint: parse the body once,
/// serve locally when the ring says so (or when not clustered), else
/// forward to the owning peer, failing over along the ring on
/// transport errors.
fn clustered(
    state: &AppState,
    req: &Request,
    local: fn(&AppState, &Json) -> Response,
) -> Response {
    let body = match req.json_body() {
        Ok(b) => b,
        Err(e) => {
            return error_resp(400, "bad_request", &format!("body: {e}"))
        }
    };
    let Some(cl) = state.cluster.as_ref() else {
        return local(state, &body);
    };
    // Loop guard: a request that already crossed one hop is answered
    // here no matter what this node's ring says — transient ring
    // disagreement between fronts can cost one extra hop, never a
    // cycle.
    if req.header(cluster::PROXIED_HEADER).is_some() {
        cl.stats.proxied_in.fetch_add(1, Ordering::Relaxed);
        return local(state, &body);
    }
    // The ring keys on the model name; bodies without one fall through
    // to the local handler, whose 400 is exact.
    let model = match body.get("model").and_then(Json::as_str) {
        Some(m) => m.to_string(),
        None => return local(state, &body),
    };
    // Replicated routes: a large-enough batch splits across the live
    // replica set instead of going to one owner. Returns None when the
    // fan-out doesn't apply (or can't complete) — the plain walk below
    // is the universal fallback.
    if req.path() == "/v1/batch" && cl.config().replicas > 1 {
        if let Some(resp) = fanout_batch(state, cl, &model, &body) {
            return resp;
        }
    }
    let mut failed_hops = 0u64;
    for node in cl.candidates(&model) {
        match node {
            Node::Local => {
                cl.stats.local.fetch_add(1, Ordering::Relaxed);
                if failed_hops > 0 {
                    cl.stats.failovers.fetch_add(1, Ordering::Relaxed);
                }
                return local(state, &body);
            }
            Node::Peer(addr) => {
                // Bounded outbound-proxy concurrency: a forward blocks
                // this worker thread, and with every worker blocked on
                // forwards two fronts proxying to each other would
                // deadlock until the proxy timeout.
                let Some(_permit) = cl.try_forward_permit() else {
                    // Past the bound, prefer degrading to local
                    // bit-exact service (every node normally serves
                    // the full route table) over shedding; 503 only
                    // when this node really can't answer.
                    if state.router.route_info(&model).is_some() {
                        cl.stats.local.fetch_add(1, Ordering::Relaxed);
                        cl.stats.failovers.fetch_add(1, Ordering::Relaxed);
                        return local(state, &body);
                    }
                    return error_resp(
                        503,
                        "overloaded",
                        "proxy capacity exhausted, retry later",
                    );
                };
                match cl.forward(&addr, req.path(), &req.body) {
                    Ok(resp) => {
                        // HTTP-level statuses (including the peer's own
                        // 4xx/5xx) pass through untouched; only
                        // transport failures fail over.
                        cl.record_success(&addr);
                        cl.stats.proxied.fetch_add(1, Ordering::Relaxed);
                        if failed_hops > 0 {
                            cl.stats.failovers.fetch_add(1, Ordering::Relaxed);
                        }
                        return resp;
                    }
                    Err(_) => {
                        cl.stats.proxy_errors.fetch_add(1, Ordering::Relaxed);
                        cl.record_failure(&addr);
                        failed_hops += 1;
                    }
                }
            }
        }
    }
    // The ring always contains this node and Local is never filtered,
    // so the walk above always returns from inside the loop; this tail
    // is a defensive fallback, not a reachable error path.
    cl.stats.local.fetch_add(1, Ordering::Relaxed);
    local(state, &body)
}

/// Split a `/v1/batch` across the live replica set and merge in order.
///
/// Returns `None` whenever the fan-out doesn't apply — fewer than two
/// live replicas, too few words to split, a body the plain path should
/// reject with its exact error, or no spare forward permits — and the
/// caller falls back to the ordinary ring walk. Mid-flight shard
/// failures degrade to serving the whole batch locally (every node
/// carries the full route table, and bit-exactness makes local service
/// indistinguishable).
fn fanout_batch(
    state: &AppState,
    cl: &cluster::Cluster,
    model: &str,
    body: &Json,
) -> Option<Response> {
    let arr = body.get("words").and_then(Json::as_arr)?;
    let info = state.router.route_info(model)?;
    if arr.is_empty() || arr.len() > info.batch_capacity {
        return None;
    }
    let reps = cl.live_replicas(model);
    if reps.len() < 2 || arr.len() < reps.len() {
        return None;
    }
    let chunk = arr.len().div_ceil(reps.len());
    let shards: Vec<&[Json]> = arr.chunks(chunk).collect();
    // `chunks` can yield fewer shards than replicas; surplus replicas
    // simply sit this request out.
    let pairs: Vec<(&Node, &&[Json])> =
        reps.iter().zip(&shards).collect();
    // One permit per shard that actually goes remote, or no fan-out at
    // all (the plain walk degrades more gracefully under forward
    // pressure).
    let remote_shards = pairs
        .iter()
        .filter(|(n, _)| **n != Node::Local)
        .count();
    let mut permits = Vec::with_capacity(remote_shards);
    for _ in 0..remote_shards {
        permits.push(cl.try_forward_permit()?);
    }
    let mut results: Vec<Option<Vec<Json>>> = vec![None; pairs.len()];
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (i, (node, words)) in pairs.iter().enumerate() {
            if let Node::Peer(addr) = node {
                let wire = json::write(&obj([
                    ("model", Json::Str(model.to_string())),
                    ("words", Json::Arr(words.to_vec())),
                ]));
                let want = words.len();
                handles.push((
                    i,
                    s.spawn(move || {
                        match cl.forward(addr, "/v1/batch", wire.as_bytes())
                        {
                            Ok(resp) if resp.status == 200 => {
                                cl.record_success(addr);
                                cl.stats
                                    .proxied
                                    .fetch_add(1, Ordering::Relaxed);
                                shard_words(&resp.body, want)
                            }
                            Ok(_) => None,
                            Err(_) => {
                                cl.stats
                                    .proxy_errors
                                    .fetch_add(1, Ordering::Relaxed);
                                cl.record_failure(addr);
                                None
                            }
                        }
                    }),
                ));
            }
        }
        // The local shard (shard 0 whenever this node is a replica —
        // live_replicas puts Local first) computes on this thread
        // while the remote shards are in flight.
        for (i, (node, words)) in pairs.iter().enumerate() {
            if matches!(node, Node::Local) {
                let sub = obj([
                    ("model", Json::Str(model.to_string())),
                    ("words", Json::Arr(words.to_vec())),
                ]);
                let resp = batch(state, &sub);
                if resp.status == 200 {
                    results[i] = shard_words(&resp.body, words.len());
                }
            }
        }
        for (i, h) in handles {
            results[i] = h.join().unwrap_or(None);
        }
    });
    drop(permits);
    // The `local` path counter ticks at most once per client request
    // (the per-shard `proxied` ticks are real extra round trips, but a
    // locally computed shard plus a local fallback is still one local
    // serving decision).
    if results.iter().any(Option::is_none) {
        // A shard failed: serve the whole batch locally, bit-exact.
        cl.stats.fanout_fallbacks.fetch_add(1, Ordering::Relaxed);
        cl.stats.local.fetch_add(1, Ordering::Relaxed);
        return Some(batch(state, body));
    }
    cl.stats.fanout_batches.fetch_add(1, Ordering::Relaxed);
    if pairs.iter().any(|(n, _)| matches!(n, Node::Local)) {
        cl.stats.local.fetch_add(1, Ordering::Relaxed);
    }
    let words: Vec<Json> = results.into_iter().flatten().flatten().collect();
    Some(Response::json(
        200,
        &obj([
            ("model", Json::Str(model.to_string())),
            ("count", Json::Num(words.len() as f64)),
            ("words", Json::Arr(words)),
        ]),
    ))
}

/// Extract a successful shard response's word array (length-checked —
/// a replica answering with the wrong count is treated as a failure).
fn shard_words(body: &[u8], want: usize) -> Option<Vec<Json>> {
    let text = std::str::from_utf8(body).ok()?;
    let v = json::parse(text).ok()?;
    let words = v.get("words")?.as_arr()?;
    if words.len() != want {
        return None;
    }
    Some(words.to_vec())
}

/// `POST /v1/gossip`: merge the sender's member table, answer with
/// ours (see [`super::gossip`] for the merge rules). 404 outside
/// cluster mode so a plain `serve-http` node is visibly not a gossip
/// participant.
fn gossip_exchange(state: &AppState, req: &Request) -> Response {
    let Some(cl) = state.cluster.as_ref() else {
        return error_resp(
            404,
            "not_found",
            "gossip requires cluster mode (serve-cluster)",
        );
    };
    // Gossip is a control-plane message with a known maximal size; the
    // server-wide body limit is sized for eval batches and far too
    // generous here.
    if req.body.len() > gossip::MAX_GOSSIP_BODY {
        return error_resp(
            413,
            "payload_too_large",
            &format!(
                "gossip body {} bytes exceeds the {} cap",
                req.body.len(),
                gossip::MAX_GOSSIP_BODY
            ),
        );
    }
    let body = match req.json_body() {
        Ok(b) => b,
        Err(e) => {
            return error_resp(400, "bad_request", &format!("body: {e}"))
        }
    };
    let msg = match gossip::decode(&body) {
        Ok(m) => m,
        Err(e) => return error_resp(400, "bad_request", &e),
    };
    cl.stats.gossip_in.fetch_add(1, Ordering::Relaxed);
    cl.apply_remote_members(&msg.members);
    Response::json(
        200,
        &gossip::encode(cl.self_name(), &cl.member_entries()),
    )
}

// ---------------------------------------------------------------------
// Handlers
// ---------------------------------------------------------------------

fn health(state: &AppState) -> Response {
    let mut fields = vec![
        ("status", Json::Str("ok".into())),
        ("uptime_s", Json::Num(state.started.elapsed().as_secs() as f64)),
        ("routes", Json::Num(state.router.route_infos().len() as f64)),
    ];
    if let Some(cl) = &state.cluster {
        fields.push((
            "cluster_nodes",
            Json::Num(cl.ring().nodes().len() as f64),
        ));
        fields.push((
            "cluster_live_peers",
            Json::Num(cl.healthy_peers() as f64),
        ));
        fields.push((
            "cluster_members",
            Json::Num(cl.alive_members() as f64),
        ));
        fields.push((
            "cluster_membership_version",
            Json::Num(cl.membership_version() as f64),
        ));
        // The peer table: gossip-convergence checks read this.
        fields.push((
            "cluster_peers",
            Json::Obj(
                cl.peer_health()
                    .into_iter()
                    .map(|(a, h)| (a, Json::Str(h.name().into())))
                    .collect(),
            ),
        ));
    }
    Response::json(200, &obj(fields))
}

fn models(state: &AppState) -> Response {
    let cl = state.cluster.as_ref();
    let data: Vec<Json> = state
        .router
        .route_infos()
        .iter()
        .map(|i| {
            let mut fields = vec![
                ("id", Json::Str(i.name.clone())),
                ("object", Json::Str("model".into())),
                ("backend", Json::Str(i.kind.into())),
                ("detail", Json::Str(i.detail.clone())),
                ("batch_capacity", Json::Num(i.batch_capacity as f64)),
                ("workers", Json::Num(i.workers as f64)),
                ("queue_limit", Json::Num(i.queue_limit as f64)),
            ];
            if let Some(cl) = cl {
                // Peer-aware: where the ring currently routes this
                // model (liveness applied), and whether that is here.
                let owner =
                    cl.owner_name(&i.name).unwrap_or_else(|| "none".into());
                fields.push((
                    "local",
                    Json::Bool(owner == cl.self_name()),
                ));
                fields.push(("owner", Json::Str(owner)));
                fields.push((
                    "replicas",
                    Json::Arr(
                        cl.replica_set(&i.name)
                            .into_iter()
                            .map(Json::Str)
                            .collect(),
                    ),
                ));
            }
            obj(fields)
        })
        .collect();
    let mut top = vec![
        ("object", Json::Str("list".into())),
        ("data", Json::Arr(data)),
    ];
    if let Some(cl) = cl {
        let peers: Vec<Json> = cl
            .peer_health()
            .into_iter()
            .map(|(addr, h)| {
                obj([
                    ("addr", Json::Str(addr)),
                    ("health", Json::Str(h.name().into())),
                ])
            })
            .collect();
        top.push((
            "cluster",
            obj([
                ("self", Json::Str(cl.self_name().into())),
                (
                    "nodes",
                    Json::Arr(
                        cl.ring()
                            .nodes()
                            .iter()
                            .map(|n| Json::Str(n.clone()))
                            .collect(),
                    ),
                ),
                ("peers", Json::Arr(peers)),
                (
                    "virtual_nodes",
                    Json::Num(cl.config().virtual_nodes as f64),
                ),
                ("replicas", Json::Num(cl.config().replicas as f64)),
                (
                    "membership_version",
                    Json::Num(cl.membership_version() as f64),
                ),
            ]),
        ));
    }
    Response::json(200, &obj(top))
}

fn eval(state: &AppState, body: &Json) -> Response {
    let info = match resolve_model(state, body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let word = match (body.get("word"), body.get("x")) {
        (Some(w), None) => match as_exact_i64(w) {
            Some(w) => w,
            None => {
                return error_resp(400, "bad_request", "word must be an integer")
            }
        },
        (None, Some(x)) => {
            let Some(x) = x.as_f64() else {
                return error_resp(400, "bad_request", "x must be a number");
            };
            let Some(cfg) = info.native_cfg else {
                return error_resp(
                    400,
                    "bad_request",
                    "float x needs a native route (send a fixed-point word)",
                );
            };
            cfg.in_format().quantize(x, Round::Nearest)
        }
        _ => {
            return error_resp(
                400,
                "bad_request",
                "body needs exactly one of word (int) or x (float)",
            )
        }
    };
    if let Some(resp) = check_words(&info, &[word]) {
        return resp;
    }
    match submit(state, &info.name, vec![word as i32]) {
        Err(resp) => resp,
        Ok(out) => {
            let y_word = out[0] as i64;
            let mut fields = vec![
                ("model", Json::Str(info.name.clone())),
                ("word", Json::Num(word as f64)),
                ("y_word", Json::Num(y_word as f64)),
            ];
            if let Some(cfg) = info.native_cfg {
                fields.push((
                    "y",
                    Json::Num(cfg.out_format().dequantize(y_word)),
                ));
            }
            Response::json(200, &obj(fields))
        }
    }
}

fn batch(state: &AppState, body: &Json) -> Response {
    let info = match resolve_model(state, body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(arr) = body.get("words").and_then(Json::as_arr) else {
        return error_resp(400, "bad_request", "words must be an array");
    };
    if arr.is_empty() {
        return error_resp(400, "bad_request", "words must be non-empty");
    }
    if arr.len() > info.batch_capacity {
        return error_resp(
            400,
            "bad_request",
            &format!(
                "{} words exceeds batch_capacity {} of model '{}'",
                arr.len(),
                info.batch_capacity,
                info.name
            ),
        );
    }
    let mut words = Vec::with_capacity(arr.len());
    for v in arr {
        match as_exact_i64(v) {
            Some(w) => words.push(w),
            None => {
                return error_resp(
                    400,
                    "bad_request",
                    "words must all be integers",
                )
            }
        }
    }
    if let Some(resp) = check_words(&info, &words) {
        return resp;
    }
    let words32: Vec<i32> = words.iter().map(|&w| w as i32).collect();
    match submit(state, &info.name, words32) {
        Err(resp) => resp,
        Ok(out) => Response::json(
            200,
            &obj([
                ("model", Json::Str(info.name.clone())),
                ("count", Json::Num(out.len() as f64)),
                (
                    "words",
                    Json::Arr(
                        out.iter().map(|&w| Json::Num(w as f64)).collect(),
                    ),
                ),
            ]),
        ),
    }
}

/// Write one metric family's `# HELP`/`# TYPE` preamble. Prometheus
/// exposition requires the pair once per family, before its samples;
/// the wire test in `server_e2e` asserts the pairing for every family.
fn family(s: &mut String, name: &str, typ: &str, help: &str) {
    let _ = writeln!(s, "# HELP {name} {help}");
    let _ = writeln!(s, "# TYPE {name} {typ}");
}

pub(crate) fn render_metrics(state: &AppState) -> Response {
    let mut s = String::new();
    let h = &state.http;
    family(
        &mut s,
        "tanhvf_http_connections_total",
        "counter",
        "TCP connections accepted by the front end.",
    );
    let _ = writeln!(
        s,
        "tanhvf_http_connections_total {}",
        h.connections.load(Ordering::Relaxed)
    );
    family(
        &mut s,
        "tanhvf_http_rejected_connections_total",
        "counter",
        "Connections answered 503 at the open-connection limit.",
    );
    let _ = writeln!(
        s,
        "tanhvf_http_rejected_connections_total {}",
        h.rejected_connections.load(Ordering::Relaxed)
    );
    family(
        &mut s,
        "tanhvf_http_requests_total",
        "counter",
        "HTTP requests parsed and dispatched.",
    );
    let _ = writeln!(
        s,
        "tanhvf_http_requests_total {}",
        h.requests.load(Ordering::Relaxed)
    );
    family(
        &mut s,
        "tanhvf_http_responses_total",
        "counter",
        "HTTP responses by status class.",
    );
    for (class, v) in [
        ("2xx", &h.responses_2xx),
        ("4xx", &h.responses_4xx),
        ("5xx", &h.responses_5xx),
    ] {
        let _ = writeln!(
            s,
            "tanhvf_http_responses_total{{class=\"{class}\"}} {}",
            v.load(Ordering::Relaxed)
        );
    }
    family(
        &mut s,
        "tanhvf_uptime_seconds",
        "gauge",
        "Seconds since this server started.",
    );
    let _ = writeln!(
        s,
        "tanhvf_uptime_seconds {}",
        state.started.elapsed().as_secs()
    );

    // Per-route coordinator metrics: family preamble once, then one
    // sample per route.
    let snaps = state.router.snapshots();
    family(
        &mut s,
        "tanhvf_requests_submitted_total",
        "counter",
        "Eval words admitted to a route's queue.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_requests_submitted_total{{route=\"{route}\"}} {}",
            snap.submitted
        );
    }
    family(
        &mut s,
        "tanhvf_requests_completed_total",
        "counter",
        "Requests completed by a route's workers.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_requests_completed_total{{route=\"{route}\"}} {}",
            snap.completed
        );
    }
    family(
        &mut s,
        "tanhvf_requests_rejected_total",
        "counter",
        "Requests shed by queue-limit backpressure.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_requests_rejected_total{{route=\"{route}\"}} {}",
            snap.rejected
        );
    }
    family(
        &mut s,
        "tanhvf_batches_total",
        "counter",
        "Packed batches executed by a route's backend.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_batches_total{{route=\"{route}\"}} {}",
            snap.batches
        );
    }
    family(
        &mut s,
        "tanhvf_batch_fill_ratio",
        "gauge",
        "Mean fraction of batch capacity used.",
    );
    for (route, snap) in &snaps {
        let _ = writeln!(
            s,
            "tanhvf_batch_fill_ratio{{route=\"{route}\"}} {:.4}",
            snap.mean_batch_fill
        );
    }
    family(
        &mut s,
        "tanhvf_latency_microseconds",
        "gauge",
        "Request latency quantiles over the retained window.",
    );
    for (route, snap) in &snaps {
        for (q, v) in [
            ("0.5", snap.p50_latency_us),
            ("0.95", snap.p95_latency_us),
            ("0.99", snap.p99_latency_us),
            ("1.0", snap.max_latency_us),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_latency_microseconds{{route=\"{route}\",quantile=\"{q}\"}} {v}"
            );
        }
    }

    if let Some(cl) = &state.cluster {
        family(
            &mut s,
            "tanhvf_cluster_peer_up",
            "gauge",
            "1 when the peer is routable, 0 when evicted or dead.",
        );
        for (addr, h) in cl.peer_health() {
            let up = (h != cluster::PeerHealth::Down) as u32;
            let _ = writeln!(
                s,
                "tanhvf_cluster_peer_up{{peer=\"{addr}\",state=\"{}\"}} {up}",
                h.name()
            );
        }
        family(
            &mut s,
            "tanhvf_cluster_ring_nodes",
            "gauge",
            "Nodes currently hashed onto the ring (alive members).",
        );
        let _ = writeln!(
            s,
            "tanhvf_cluster_ring_nodes {}",
            cl.ring().nodes().len()
        );
        family(
            &mut s,
            "tanhvf_cluster_members",
            "gauge",
            "Gossip member table entries by liveness.",
        );
        let members = cl.members();
        let alive = members.values().filter(|m| m.alive).count();
        let _ = writeln!(
            s,
            "tanhvf_cluster_members{{state=\"alive\"}} {alive}"
        );
        let _ = writeln!(
            s,
            "tanhvf_cluster_members{{state=\"dead\"}} {}",
            members.len() - alive
        );
        family(
            &mut s,
            "tanhvf_cluster_membership_version",
            "gauge",
            "Ring rebuild count (bumps on join, death, resurrection).",
        );
        let _ = writeln!(
            s,
            "tanhvf_cluster_membership_version {}",
            cl.membership_version()
        );
        let st = &cl.stats;
        family(
            &mut s,
            "tanhvf_cluster_requests_total",
            "counter",
            "Eval/batch requests by serving path.",
        );
        for (name, v) in [
            ("local", &st.local),
            ("proxied", &st.proxied),
            ("proxied_in", &st.proxied_in),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_requests_total{{path=\"{name}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        for (name, v, help) in [
            (
                "tanhvf_cluster_proxy_errors_total",
                &st.proxy_errors,
                "Transport failures on the proxy leg.",
            ),
            (
                "tanhvf_cluster_failovers_total",
                &st.failovers,
                "Requests served by a non-first ring candidate.",
            ),
            (
                "tanhvf_cluster_evictions_total",
                &st.evictions,
                "Peer transitions into routing eviction.",
            ),
            (
                "tanhvf_cluster_readmissions_total",
                &st.readmissions,
                "Evicted peers re-admitted to routing.",
            ),
            (
                "tanhvf_cluster_fanout_batches_total",
                &st.fanout_batches,
                "Batches served by splitting across replicas.",
            ),
            (
                "tanhvf_cluster_fanout_fallbacks_total",
                &st.fanout_fallbacks,
                "Fan-outs abandoned and served whole locally.",
            ),
            (
                "tanhvf_cluster_gossip_refutations_total",
                &st.gossip_refutations,
                "Dead reports about this node refuted with a bumped incarnation.",
            ),
            (
                "tanhvf_cluster_tombstone_evictions_total",
                &st.tombstone_evictions,
                "Tombstones evicted to admit joins at the member-table bound.",
            ),
        ] {
            family(&mut s, name, "counter", help);
            let _ = writeln!(s, "{name} {}", v.load(Ordering::Relaxed));
        }
        family(
            &mut s,
            "tanhvf_cluster_gossip_total",
            "counter",
            "Gossip exchanges by direction and outcome.",
        );
        for (event, v) in [
            ("sent_ok", &st.gossip_ok),
            ("sent_fail", &st.gossip_fail),
            ("received", &st.gossip_in),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_gossip_total{{event=\"{event}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        family(
            &mut s,
            "tanhvf_cluster_membership_events_total",
            "counter",
            "Member table changes by kind.",
        );
        for (event, v) in [
            ("join", &st.members_joined),
            ("death", &st.members_died),
            ("resurrection", &st.members_resurrected),
        ] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_membership_events_total{{event=\"{event}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        let ps = &cl.pool.stats;
        family(
            &mut s,
            "tanhvf_cluster_pool_checkouts_total",
            "counter",
            "Connection-pool checkouts by outcome (hit = reused).",
        );
        for (result, v) in [("hit", &ps.hits), ("miss", &ps.misses)] {
            let _ = writeln!(
                s,
                "tanhvf_cluster_pool_checkouts_total{{result=\"{result}\"}} {}",
                v.load(Ordering::Relaxed)
            );
        }
        for (name, v, help) in [
            (
                "tanhvf_cluster_pool_discards_total",
                &ps.discards,
                "Pooled connections dropped instead of re-admitted.",
            ),
            (
                "tanhvf_cluster_pool_evictions_total",
                &ps.evictions,
                "Idle connections evicted by the per-peer bound.",
            ),
        ] {
            family(&mut s, name, "counter", help);
            let _ = writeln!(s, "{name} {}", v.load(Ordering::Relaxed));
        }
        family(
            &mut s,
            "tanhvf_cluster_pool_idle_connections",
            "gauge",
            "Idle keep-alive connections currently pooled.",
        );
        let _ = writeln!(
            s,
            "tanhvf_cluster_pool_idle_connections {}",
            cl.pool.idle_count()
        );
    }
    Response::text(200, &s)
}

// ---------------------------------------------------------------------
// Shared pieces
// ---------------------------------------------------------------------

/// Resolve a parsed body's `model` to a route (the body is parsed once
/// in [`clustered`], before any routing decision).
fn resolve_model(
    state: &AppState,
    body: &Json,
) -> Result<RouteInfo, Response> {
    let Some(model) = body.get("model").and_then(Json::as_str) else {
        return Err(error_resp(400, "bad_request", "model (string) required"));
    };
    state.router.route_info(model).ok_or_else(|| {
        error_resp(
            404,
            "unknown_model",
            &format!("no model '{model}' (see /v1/models)"),
        )
    })
}

/// Range-check words against the route's input format, when known. The
/// memoized native unit indexes a full table, so out-of-range words must
/// be rejected here rather than trusted to the backend.
fn check_words(info: &RouteInfo, words: &[i64]) -> Option<Response> {
    let limit = match info.native_cfg {
        Some(cfg) => 1i64 << cfg.mag_bits(),
        None => 1i64 << 31, // pjrt: anything that fits the i32 wire type
    };
    for &w in words {
        if w < -limit || w >= limit {
            return Some(error_resp(
                400,
                "bad_request",
                &format!(
                    "word {w} outside [{}, {}) for model '{}'",
                    -limit, limit, info.name
                ),
            ));
        }
    }
    None
}

/// Submit to the router and map failures to HTTP statuses.
fn submit(
    state: &AppState,
    route: &str,
    words: Vec<i32>,
) -> Result<Vec<i32>, Response> {
    let rx = state
        .router
        .submit(route, words)
        .map_err(|e| error_resp(404, "unknown_model", &e))?;
    match rx.recv_timeout(state.request_timeout) {
        None => Err(error_resp(
            504,
            "timeout",
            "backend did not answer in time",
        )),
        Some(Err(e)) if e.contains("queue full") => Err(error_resp(
            503,
            "overloaded",
            "route queue is full, retry later",
        )),
        Some(Err(e)) if e.contains("outside 1..=") => {
            Err(error_resp(400, "bad_request", &e))
        }
        Some(Err(e)) => Err(error_resp(500, "backend_error", &e)),
        Some(Ok(out)) => Ok(out),
    }
}

/// Integer-valued JSON number (rejects 1.5 and non-numbers).
fn as_exact_i64(v: &Json) -> Option<i64> {
    match v {
        Json::Num(n) if n.fract() == 0.0 && n.abs() <= 9e15 => Some(*n as i64),
        _ => None,
    }
}

fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Uniform error body: `{"error":{"code":...,"message":...}}`.
pub(crate) fn error_resp(status: u16, code: &str, message: &str) -> Response {
    Response::json(
        status,
        &obj([(
            "error",
            obj([
                ("code", Json::Str(code.into())),
                ("message", Json::Str(message.into())),
                ("status", Json::Num(status as f64)),
            ]),
        )]),
    )
}
