//! Deterministic cluster simulation: a virtual-time, in-process
//! network implementing [`super::transport::Transport`], plus seeded
//! fault injection and the invariant checkers the `sim_*` test suites
//! assert over thousands of schedules.
//!
//! Everything the cluster tier sends — proxied evals, health probes,
//! gossip exchanges — goes through the transport seam, so an N-node
//! cluster can run entirely inside one process with **no real sockets
//! and no real time**: every [`Cluster`] is started with
//! [`manual_rounds`](super::cluster::ClusterConfig::manual_rounds) and
//! a [`SimTransport`], the test
//! driver steps [`Cluster::membership_round`] explicitly, and waiting
//! for a deadline merely advances the shared [`SimNet`] clock by that
//! many virtual milliseconds. A thousand multi-round schedules finish
//! in seconds.
//!
//! ## Fault model
//!
//! Faults are scripted per *directed* link (`from -> to`) or per node:
//!
//! * [`SimNet::partition`] — blackhole: dialing costs the full connect
//!   deadline and fails; requests already in flight on the link time
//!   out at the read deadline (not retryable — exactly like a real
//!   blackholed TCP connection). One-sided calls give asymmetric
//!   partitions; [`SimNet::partition_pair`] cuts both directions.
//! * [`SimNet::crash`] / [`SimNet::restart`] — a crashed node refuses
//!   dials instantly; a restart bumps its connection generation, so
//!   every *pooled* connection to it fails retryably on next use (the
//!   stale-keep-alive signature the discard-and-redial retry exists
//!   for).
//! * [`SimNet::drop_requests`] / [`SimNet::drop_responses`] — lose the
//!   next `n` messages on a link. A dropped request never executes and
//!   surfaces as a (non-retryable) response timeout; a dropped
//!   response *executes on the peer* and surfaces as a retryable
//!   "closed before response" — the dangerous half of the
//!   re-execution space.
//! * [`SimNet::set_delay`] / [`SimNet::set_slow`] — add per-link or
//!   per-node response latency in virtual ms; a response slower than
//!   the caller's read deadline becomes a timeout.
//!
//! Randomized schedules draw from [`SplitMix64`] seeded per scenario;
//! every invariant panic embeds the seed, and
//! `TANHVF_SIM_SEED=<seed> cargo test -q sim_<name>` replays exactly
//! one schedule. `TANHVF_SIM_BASE_SEED` shifts whole suites (the CI
//! randomized pass logs it).
//!
//! ## Determinism rule
//!
//! The transport itself never draws randomness — all faults are staged
//! by the single-threaded driver *between* operations, so concurrent
//! phases (the `/v1/batch` fan-out spawns scoped threads) stay
//! reproducible: thread interleaving can reorder clock ticks but never
//! outcomes.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::util::json;
use crate::util::rng::SplitMix64;

use super::cluster::Cluster;
use super::gossip::{self, Member};
use super::transport::{
    Connection, Deadlines, Transport, TransportError,
};

/// An inbound request handler: `(method, path, headers, body)` to
/// `(status, response body)` — the sim-level stand-in for one node's
/// HTTP front end.
pub type Handler = Arc<
    dyn Fn(&str, &str, &[(&str, &str)], &[u8]) -> (u16, Vec<u8>)
        + Send
        + Sync,
>;

struct NodeState {
    handler: Handler,
    up: bool,
    /// Bumped on restart: connections dialed before the bump fail
    /// retryably on next use, like keep-alive sockets into a restarted
    /// process.
    generation: u64,
    /// Extra response latency for everything this node serves.
    slow_ms: u64,
    /// Requests that actually reached the handler (executions).
    executions: u64,
}

#[derive(Default)]
struct LinkState {
    partitioned: bool,
    delay_ms: u64,
    drop_requests: u64,
    drop_responses: u64,
}

#[derive(Default)]
struct NetState {
    nodes: BTreeMap<String, NodeState>,
    links: BTreeMap<(String, String), LinkState>,
}

/// The in-process network: registered nodes, directed link faults, and
/// the virtual clock.
pub struct SimNet {
    clock_ms: AtomicU64,
    state: Mutex<NetState>,
}

impl SimNet {
    /// A fresh net at virtual time zero (shared: every node's
    /// transport and the test driver hold the same `Arc`).
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<SimNet> {
        Arc::new(SimNet {
            clock_ms: AtomicU64::new(0),
            state: Mutex::new(NetState::default()),
        })
    }

    /// Current virtual time in milliseconds.
    pub fn now_ms(&self) -> u64 {
        self.clock_ms.load(Ordering::SeqCst)
    }

    /// Advance the virtual clock (ops advance it themselves; drivers
    /// use this for idle time between rounds).
    pub fn advance(&self, ms: u64) {
        self.clock_ms.fetch_add(ms, Ordering::SeqCst);
    }

    /// Register (or replace) a node's request handler; the node starts
    /// up.
    pub fn register(&self, addr: &str, handler: Handler) {
        let mut st = self.state.lock().unwrap();
        let generation = st
            .nodes
            .get(addr)
            .map(|n| n.generation + 1)
            .unwrap_or(0);
        st.nodes.insert(
            addr.to_string(),
            NodeState {
                handler,
                up: true,
                generation,
                slow_ms: 0,
                executions: 0,
            },
        );
    }

    /// Register a [`Cluster`] node: serves `GET /health` and
    /// `POST /v1/gossip` exactly like the HTTP endpoint (including the
    /// oversized-body 413). Holds only a `Weak` reference — a dropped
    /// cluster answers 503 rather than keeping itself alive through
    /// the net.
    pub fn register_cluster(&self, addr: &str, cluster: &Arc<Cluster>) {
        let weak: Weak<Cluster> = Arc::downgrade(cluster);
        self.register(
            addr,
            Arc::new(move |method, path, _headers, body| {
                let Some(cl) = weak.upgrade() else {
                    return (503, Vec::new());
                };
                match (method, path) {
                    ("GET", "/health") => {
                        (200, br#"{"status":"ok"}"#.to_vec())
                    }
                    ("POST", gossip::GOSSIP_PATH) => {
                        if body.len() > gossip::MAX_GOSSIP_BODY {
                            return (413, Vec::new());
                        }
                        let parsed = std::str::from_utf8(body)
                            .map_err(|e| e.to_string())
                            .and_then(|t| {
                                json::parse(t).map_err(|e| e.to_string())
                            })
                            .and_then(|v| gossip::decode(&v));
                        match parsed {
                            Ok(msg) => {
                                cl.apply_remote_members(&msg.members);
                                cl.apply_remote_routes(&msg.routes);
                                let reply = json::write(&gossip::encode(
                                    cl.self_name(),
                                    &cl.member_entries(),
                                    &cl.route_overrides_wire(),
                                ));
                                (200, reply.into_bytes())
                            }
                            Err(_) => (400, Vec::new()),
                        }
                    }
                    _ => (404, Vec::new()),
                }
            }),
        );
    }

    /// A transport dialing out of `from` over this net (one per node).
    pub fn transport(self: &Arc<Self>, from: &str) -> Arc<SimTransport> {
        Arc::new(SimTransport { net: self.clone(), from: from.to_string() })
    }

    /// Requests that actually reached `addr`'s handler.
    pub fn executions(&self, addr: &str) -> u64 {
        self.state
            .lock()
            .unwrap()
            .nodes
            .get(addr)
            .map(|n| n.executions)
            .unwrap_or(0)
    }

    /// Take the node down: new dials are refused instantly, requests
    /// on existing connections fail retryably.
    pub fn crash(&self, addr: &str) {
        if let Some(n) = self.state.lock().unwrap().nodes.get_mut(addr) {
            n.up = false;
        }
    }

    /// Bring a crashed node back with a new connection generation:
    /// connections pooled before the restart fail retryably on next
    /// use.
    pub fn restart(&self, addr: &str) {
        if let Some(n) = self.state.lock().unwrap().nodes.get_mut(addr) {
            n.up = true;
            n.generation += 1;
        }
    }

    pub fn is_up(&self, addr: &str) -> bool {
        self.state
            .lock()
            .unwrap()
            .nodes
            .get(addr)
            .map(|n| n.up)
            .unwrap_or(false)
    }

    /// Blackhole the directed link `from -> to`.
    pub fn partition(&self, from: &str, to: &str) {
        self.link(from, to, |l| l.partitioned = true);
    }

    /// Blackhole both directions between `a` and `b`.
    pub fn partition_pair(&self, a: &str, b: &str) {
        self.partition(a, b);
        self.partition(b, a);
    }

    /// Heal the directed link `from -> to`.
    pub fn heal(&self, from: &str, to: &str) {
        self.link(from, to, |l| l.partitioned = false);
    }

    /// Heal every partition (link delays and pending drops persist).
    pub fn heal_all(&self) {
        for l in self.state.lock().unwrap().links.values_mut() {
            l.partitioned = false;
        }
    }

    /// Add `ms` of virtual latency to responses on `from -> to`.
    pub fn set_delay(&self, from: &str, to: &str, ms: u64) {
        self.link(from, to, |l| l.delay_ms = ms);
    }

    /// Drop the next `n` requests on `from -> to` (never executed;
    /// the caller sees a response timeout).
    pub fn drop_requests(&self, from: &str, to: &str, n: u64) {
        self.link(from, to, |l| l.drop_requests += n);
    }

    /// Drop the next `n` responses on `from -> to` (executed on the
    /// peer; the caller sees a retryable close).
    pub fn drop_responses(&self, from: &str, to: &str, n: u64) {
        self.link(from, to, |l| l.drop_responses += n);
    }

    /// Add `ms` of virtual latency to everything `addr` serves.
    pub fn set_slow(&self, addr: &str, ms: u64) {
        if let Some(n) = self.state.lock().unwrap().nodes.get_mut(addr) {
            n.slow_ms = ms;
        }
    }

    fn link(&self, from: &str, to: &str, f: impl FnOnce(&mut LinkState)) {
        let mut st = self.state.lock().unwrap();
        f(st.links
            .entry((from.to_string(), to.to_string()))
            .or_default());
    }
}

/// [`Transport`] over a [`SimNet`], dialing out of one node identity.
pub struct SimTransport {
    net: Arc<SimNet>,
    from: String,
}

impl Transport for SimTransport {
    fn connect(
        &self,
        addr: &str,
        deadlines: &Deadlines,
    ) -> Result<Box<dyn Connection>, String> {
        let (partitioned, generation) = {
            let st = self.net.state.lock().unwrap();
            let key = (self.from.clone(), addr.to_string());
            let partitioned =
                st.links.get(&key).map(|l| l.partitioned).unwrap_or(false);
            let generation = st
                .nodes
                .get(addr)
                .and_then(|n| if n.up { Some(n.generation) } else { None });
            (partitioned, generation)
        };
        if partitioned {
            // A blackholed dial burns the whole connect budget.
            self.net.advance(deadlines.connect.as_millis() as u64);
            return Err(format!("connect {addr}: timed out (partitioned)"));
        }
        let Some(generation) = generation else {
            self.net.advance(1);
            return Err(format!("connect {addr}: connection refused"));
        };
        self.net.advance(1);
        Ok(Box::new(SimConnection {
            net: self.net.clone(),
            from: self.from.clone(),
            to: addr.to_string(),
            generation,
            deadlines: *deadlines,
            clean: true,
            pending: None,
        }))
    }
}

enum Pending {
    /// The request vanished (partition or request loss): it never
    /// executed, and the caller can only time out — which is exactly
    /// why response timeouts must never be retried blindly.
    RequestLost,
    /// The peer executed the request but its response was lost: the
    /// retryable "closed before response" signature.
    ResponseLost,
    Ready { delay_ms: u64, status: u16, body: Vec<u8> },
}

/// One established sim connection (poolable, like its TCP twin).
pub struct SimConnection {
    net: Arc<SimNet>,
    from: String,
    to: String,
    generation: u64,
    deadlines: Deadlines,
    clean: bool,
    pending: Option<Pending>,
}

impl Connection for SimConnection {
    fn set_deadlines(&mut self, deadlines: &Deadlines) {
        self.deadlines = *deadlines;
    }

    fn send(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<(), TransportError> {
        self.clean = false;
        self.pending = None;
        let (handler, response_lost, delay_ms) = {
            let mut st = self.net.state.lock().unwrap();
            let key = (self.from.clone(), self.to.clone());
            let link = st.links.entry(key).or_default();
            if link.partitioned {
                self.pending = Some(Pending::RequestLost);
                drop(st);
                self.net.advance(1);
                return Ok(());
            }
            if link.drop_requests > 0 {
                link.drop_requests -= 1;
                self.pending = Some(Pending::RequestLost);
                drop(st);
                self.net.advance(1);
                return Ok(());
            }
            let response_lost = if link.drop_responses > 0 {
                link.drop_responses -= 1;
                true
            } else {
                false
            };
            let link_delay = link.delay_ms;
            let Some(node) = st.nodes.get_mut(&self.to) else {
                return Err(TransportError::new(
                    true,
                    "connection reset (node gone)",
                ));
            };
            if !node.up || node.generation != self.generation {
                return Err(TransportError::new(
                    true,
                    "connection closed by peer",
                ));
            }
            node.executions += 1;
            (
                node.handler.clone(),
                response_lost,
                1 + link_delay + node.slow_ms,
            )
        };
        // Handler runs outside the net lock: a fan-out shard's handler
        // does real router work and must not serialize the whole net.
        let (status, resp_body) = handler(method, path, headers, body);
        self.pending = Some(if response_lost {
            Pending::ResponseLost
        } else {
            Pending::Ready { delay_ms, status, body: resp_body }
        });
        self.net.advance(1);
        Ok(())
    }

    fn recv(
        &mut self,
        _max_body: usize,
    ) -> Result<(u16, BTreeMap<String, String>, Vec<u8>), TransportError>
    {
        let read_ms = self.deadlines.read.as_millis() as u64;
        match self.pending.take() {
            None => Err(TransportError::new(
                false,
                "recv with no request in flight",
            )),
            Some(Pending::RequestLost) => {
                self.net.advance(read_ms);
                Err(TransportError::new(
                    false,
                    "timed out waiting for response",
                ))
            }
            Some(Pending::ResponseLost) => {
                self.net.advance(1);
                Err(TransportError::new(true, "closed before response"))
            }
            Some(Pending::Ready { delay_ms, status, body }) => {
                if delay_ms > read_ms {
                    self.net.advance(read_ms);
                    return Err(TransportError::new(
                        false,
                        "timed out waiting for response (slow peer)",
                    ));
                }
                self.net.advance(delay_ms);
                self.clean = true;
                let mut headers = BTreeMap::new();
                headers.insert(
                    "content-type".to_string(),
                    "application/json".to_string(),
                );
                Ok((status, headers, body))
            }
        }
    }

    fn is_clean(&self) -> bool {
        self.clean
    }
}

// ---------------------------------------------------------------------
// Seeds
// ---------------------------------------------------------------------

/// The seed list for one scenario: `count` consecutive seeds from
/// `default_base`, overridable for reproduction —
/// `TANHVF_SIM_SEED=<seed>` replays exactly that schedule,
/// `TANHVF_SIM_BASE_SEED=<base>` shifts the whole suite (the CI
/// randomized pass sets it and logs the value).
pub fn schedule_seeds(default_base: u64, count: u64) -> Vec<u64> {
    if let Some(one) = env_u64("TANHVF_SIM_SEED") {
        return vec![one];
    }
    let base = env_u64("TANHVF_SIM_BASE_SEED").unwrap_or(default_base);
    (0..count).map(|i| base.wrapping_add(i)).collect()
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok()?.trim().parse().ok()
}

/// A scenario-local RNG forked from the schedule seed.
pub fn scenario_rng(seed: u64) -> SplitMix64 {
    SplitMix64::new(seed)
}

// ---------------------------------------------------------------------
// Invariants
// ---------------------------------------------------------------------

/// Check the post-heal convergence invariants over the up-node set;
/// `None` means converged, `Some(why)` names the first violation.
///
/// * **I1 (ring agreement):** every up node's ring node-set equals the
///   up set exactly.
/// * **I2 (observer agreement):** all observers agree on
///   `(incarnation, alive)` for every third-party member. A member's
///   *own* self-entry is exempt: a probe-driven resurrection bumps its
///   incarnation at the observers, and gossip merges never overwrite a
///   node's live self-entry (only a refutation does), so the member's
///   table may lag behind what the rest of the cluster agrees on.
/// * **I4 (refutation):** every up node is alive in every up observer's
///   table (a running node never stays dead once partitions heal).
pub fn converged(
    clusters: &[Arc<Cluster>],
    up: &std::collections::BTreeSet<String>,
) -> Option<String> {
    let tables: BTreeMap<&str, BTreeMap<String, Member>> = clusters
        .iter()
        .filter(|c| up.contains(c.self_name()))
        .map(|c| (c.self_name(), c.members()))
        .collect();
    for c in clusters.iter().filter(|c| up.contains(c.self_name())) {
        let ring: std::collections::BTreeSet<String> =
            c.ring().nodes().iter().cloned().collect();
        let want: std::collections::BTreeSet<String> = up.clone();
        if ring != want {
            return Some(format!(
                "I1: ring of {} is {ring:?}, want {want:?}",
                c.self_name()
            ));
        }
        for m in up {
            if m == c.self_name() {
                continue;
            }
            match tables[c.self_name()].get(m) {
                Some(e) if e.alive => {}
                other => {
                    return Some(format!(
                        "I4: up member {m} is {other:?} at {}",
                        c.self_name()
                    ))
                }
            }
        }
    }
    // I2: pairwise agreement on third-party entries.
    let observers: Vec<&str> = tables.keys().copied().collect();
    for (i, &a) in observers.iter().enumerate() {
        for &b in &observers[i + 1..] {
            for (m, ea) in &tables[a] {
                if m == a || m == b {
                    continue;
                }
                if let Some(eb) = tables[b].get(m) {
                    if ea != eb {
                        return Some(format!(
                            "I2: {a} sees {m} as {ea:?}, {b} sees {eb:?}"
                        ));
                    }
                }
            }
        }
    }
    None
}

/// Panic (embedding the seed for one-command reproduction) if the
/// cluster set has not converged.
pub fn assert_converged(
    clusters: &[Arc<Cluster>],
    up: &std::collections::BTreeSet<String>,
    seed: u64,
    ctx: &str,
) {
    if let Some(why) = converged(clusters, up) {
        panic!(
            "sim invariant violated [seed {seed}] {ctx}: {why} \
             (replay: TANHVF_SIM_SEED={seed} cargo test -q sim)"
        );
    }
}

/// Incremental observer: feeds on every node's member table once per
/// round and asserts **I3** — no observer ever sees a member's
/// incarnation decrease, nor flip dead -> alive at the same
/// incarnation (death certificates win ties). Also records the highest
/// death-certificate incarnation per member so the final refutation
/// check can assert the rejoin really outbid it.
#[derive(Default)]
pub struct IncarnationMonitor {
    seen: BTreeMap<(String, String), Member>,
    max_death_cert: BTreeMap<String, u64>,
}

impl IncarnationMonitor {
    pub fn new() -> IncarnationMonitor {
        IncarnationMonitor::default()
    }

    /// Ingest `observer`'s current table; panics (with the seed) on a
    /// monotonicity violation.
    pub fn observe(
        &mut self,
        observer: &str,
        table: &BTreeMap<String, Member>,
        seed: u64,
    ) {
        for (member, e) in table {
            if !e.alive {
                let cert = self.max_death_cert.entry(member.clone()).or_insert(0);
                *cert = (*cert).max(e.incarnation);
            }
            let key = (observer.to_string(), member.clone());
            if let Some(prev) = self.seen.get(&key) {
                let regressed = e.incarnation < prev.incarnation
                    || (e.incarnation == prev.incarnation
                        && !prev.alive
                        && e.alive);
                if regressed {
                    panic!(
                        "sim invariant violated [seed {seed}] I3: {observer} \
                         saw {member} go {prev:?} -> {e:?} \
                         (replay: TANHVF_SIM_SEED={seed} cargo test -q sim)"
                    );
                }
            }
            self.seen.insert(key, *e);
        }
    }

    /// Highest death-certificate incarnation ever observed for
    /// `member` (0 when never reported dead).
    pub fn death_cert(&self, member: &str) -> u64 {
        self.max_death_cert.get(member).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::{Route, Router};
    use crate::server::api;
    use crate::server::cluster::{ClusterConfig, Node};
    use crate::server::http::Request;
    use crate::server::pool::ConnPool;
    use crate::server::trace;
    use crate::server::{AppState, HttpCounters};
    use crate::tanh::TanhConfig;
    use crate::util::json::Json;
    use std::time::{Duration, Instant};

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    fn echo_handler() -> Handler {
        Arc::new(|_m, _p, _h, body: &[u8]| (200, body.to_vec()))
    }

    #[test]
    fn sim_round_trip_advances_virtual_clock_only() {
        let net = SimNet::new();
        net.register("a:1", echo_handler());
        let t = net.transport("cli:0");
        let d = Deadlines::uniform(ms(100));
        let mut c = t.connect("a:1", &d).unwrap();
        c.send("POST", "/x", &[], b"ping").unwrap();
        let (status, _h, body) = c.recv(1 << 20).unwrap();
        assert_eq!((status, body.as_slice()), (200, b"ping".as_slice()));
        assert!(c.is_clean());
        assert_eq!(net.executions("a:1"), 1);
        // connect(1) + send(1) + recv(1): three virtual ms, no real
        // sleeping anywhere.
        assert_eq!(net.now_ms(), 3);
    }

    #[test]
    fn sim_partition_costs_connect_deadline_and_heals() {
        let net = SimNet::new();
        net.register("a:1", echo_handler());
        let t = net.transport("cli:0");
        let d = Deadlines::split(ms(70), ms(10), ms(10));
        net.partition("cli:0", "a:1");
        let t0 = net.now_ms();
        assert!(t.connect("a:1", &d).is_err());
        assert_eq!(net.now_ms() - t0, 70, "blackhole burns connect budget");
        // Asymmetric: the reverse direction still works.
        let back = net.transport("a:1");
        assert!(back.connect("cli:0", &d).is_err(), "no handler at cli:0");
        net.heal("cli:0", "a:1");
        assert!(t.connect("a:1", &d).is_ok());
    }

    #[test]
    fn sim_request_loss_times_out_not_retryable() {
        let net = SimNet::new();
        net.register("a:1", echo_handler());
        let t = net.transport("cli:0");
        let d = Deadlines::uniform(ms(50));
        net.drop_requests("cli:0", "a:1", 1);
        let mut c = t.connect("a:1", &d).unwrap();
        c.send("POST", "/x", &[], b"lost").unwrap();
        let err = c.recv(1 << 20).unwrap_err();
        assert!(!err.retryable, "{}", err.msg);
        assert_eq!(net.executions("a:1"), 0, "dropped request must not run");
        // The next request goes through.
        c.send("POST", "/x", &[], b"ok").unwrap();
        assert!(c.recv(1 << 20).is_ok());
    }

    #[test]
    fn sim_response_loss_executes_and_is_retryable() {
        let net = SimNet::new();
        net.register("a:1", echo_handler());
        let t = net.transport("cli:0");
        let d = Deadlines::uniform(ms(50));
        net.drop_responses("cli:0", "a:1", 1);
        let mut c = t.connect("a:1", &d).unwrap();
        c.send("POST", "/x", &[], b"x").unwrap();
        let err = c.recv(1 << 20).unwrap_err();
        assert!(err.retryable, "{}", err.msg);
        assert_eq!(net.executions("a:1"), 1, "the peer DID execute it");
    }

    #[test]
    fn sim_slow_peer_exceeding_read_deadline_times_out() {
        let net = SimNet::new();
        net.register("a:1", echo_handler());
        net.set_slow("a:1", 500);
        let t = net.transport("cli:0");
        let mut c = t.connect("a:1", &Deadlines::uniform(ms(100))).unwrap();
        c.send("GET", "/x", &[], b"").unwrap();
        let t0 = net.now_ms();
        let err = c.recv(1 << 20).unwrap_err();
        assert!(!err.retryable);
        assert_eq!(net.now_ms() - t0, 100, "cost is the read deadline");
        // Within the deadline it is just latency.
        net.set_slow("a:1", 20);
        let mut c = t.connect("a:1", &Deadlines::uniform(ms(100))).unwrap();
        c.send("GET", "/x", &[], b"").unwrap();
        assert!(c.recv(1 << 20).is_ok());
    }

    #[test]
    fn sim_restart_invalidates_pooled_connections() {
        let net = SimNet::new();
        net.register("a:1", echo_handler());
        let t = net.transport("cli:0");
        let d = Deadlines::uniform(ms(50));
        let mut c = t.connect("a:1", &d).unwrap();
        c.send("GET", "/x", &[], b"").unwrap();
        c.recv(1 << 20).unwrap();
        net.crash("a:1");
        assert!(t.connect("a:1", &d).is_err(), "crashed node refuses");
        net.restart("a:1");
        // The pre-restart connection is stale: retryable failure.
        let err = c.send("GET", "/x", &[], b"").unwrap_err();
        assert!(err.retryable, "{}", err.msg);
        // A fresh dial works.
        let mut c2 = t.connect("a:1", &d).unwrap();
        c2.send("GET", "/x", &[], b"").unwrap();
        assert!(c2.recv(1 << 20).is_ok());
    }

    #[test]
    fn sim_pool_reuses_sim_connections() {
        let net = SimNet::new();
        net.register("a:1", echo_handler());
        let pool = ConnPool::with_transport(2, net.transport("cli:0"));
        let d = Deadlines::uniform(ms(50));
        let mut c = pool.checkout("a:1", &d).unwrap();
        assert!(!c.reused);
        c.conn.send("GET", "/x", &[], b"").unwrap();
        c.conn.recv(1 << 20).unwrap();
        pool.check_in("a:1", c.conn);
        let c2 = pool.checkout("a:1", &d).unwrap();
        assert!(c2.reused, "clean sim connection must be poolable");
    }

    #[test]
    fn sim_cluster_gossip_handler_round_trips() {
        let net = SimNet::new();
        let mk = |addr: &str, peer: &str, inc: u64| {
            Cluster::start_with_transport(
                ClusterConfig {
                    advertise: addr.into(),
                    peers: vec![peer.into()],
                    probe_timeout: ms(50),
                    probe_interval: ms(100),
                    incarnation: Some(inc),
                    manual_rounds: true,
                    ..Default::default()
                },
                net.transport(addr),
            )
            .unwrap()
        };
        let a = mk("a:1", "b:1", 10);
        let b = mk("b:1", "a:1", 20);
        net.register_cluster("a:1", &a);
        net.register_cluster("b:1", &b);
        assert!(a.gossip_with("b:1"), "gossip exchange over the sim net");
        // Both sides now know both real incarnations.
        assert_eq!(a.members()["b:1"].incarnation, 20);
        assert_eq!(b.members()["a:1"].incarnation, 10);
        // Oversized gossip is rejected with 413 (and counted a failed
        // exchange) without crashing anything.
        let big = vec![b'x'; gossip::MAX_GOSSIP_BODY + 1];
        let mut c = net
            .transport("a:1")
            .connect("b:1", &Deadlines::uniform(ms(50)))
            .unwrap();
        c.send("POST", gossip::GOSSIP_PATH, &[], &big).unwrap();
        let (status, _, _) = c.recv(1 << 20).unwrap();
        assert_eq!(status, 413);
    }

    // -- fan-out bit-exactness under shard failure ---------------------

    struct SimFront {
        state: Arc<AppState>,
        cluster: Arc<Cluster>,
    }

    /// Seed a front's trace/span-ID stream from its address: stable
    /// across runs (the determinism test replays it), distinct across
    /// fronts.
    fn trace_seed(addr: &str) -> u64 {
        addr.bytes()
            .fold(0x5eed_u64, |a, b| {
                a.wrapping_mul(31).wrapping_add(b as u64)
            })
    }

    fn start_front(
        net: &Arc<SimNet>,
        addr: &str,
        peers: Vec<String>,
        replicas: usize,
    ) -> SimFront {
        let cluster = Cluster::start_with_transport(
            ClusterConfig {
                advertise: addr.into(),
                peers,
                replicas,
                virtual_nodes: 16,
                probe_timeout: ms(50),
                probe_interval: ms(100),
                proxy_timeout: ms(200),
                incarnation: Some(100),
                manual_rounds: true,
                ..Default::default()
            },
            net.transport(addr),
        )
        .unwrap();
        let router =
            Router::start(vec![Route::native("s3_5", TanhConfig::s3_5())])
                .unwrap();
        let clock = {
            let net = Arc::clone(net);
            trace::Clock::virtual_ms(Arc::new(move || net.now_ms()))
        };
        let state = Arc::new(AppState {
            router,
            http: HttpCounters::default(),
            started: Instant::now(),
            request_timeout: Duration::from_secs(5),
            cluster: Some(cluster.clone()),
            trace: Arc::new(trace::TraceStore::new(
                trace::DEFAULT_SPAN_CAPACITY,
                trace_seed(addr),
                u64::MAX,
            )),
            clock,
            backend: "sim",
        });
        let weak = Arc::downgrade(&state);
        net.register(
            addr,
            Arc::new(move |method: &str,
                           path: &str,
                           headers: &[(&str, &str)],
                           body: &[u8]| {
                let Some(state) = weak.upgrade() else {
                    return (503, Vec::new());
                };
                let req = Request {
                    method: method.to_string(),
                    target: path.to_string(),
                    version: "HTTP/1.1".to_string(),
                    headers: headers
                        .iter()
                        .map(|(k, v)| {
                            (k.to_ascii_lowercase(), v.to_string())
                        })
                        .collect(),
                    body: body.to_vec(),
                };
                let resp = api::dispatch(&state, &req);
                (resp.status, resp.body)
            }),
        );
        SimFront { state, cluster }
    }

    fn batch_req(words: &[i64]) -> Request {
        let body = json::write(&Json::Obj(
            [
                ("model".to_string(), Json::Str("s3_5".into())),
                (
                    "words".to_string(),
                    Json::Arr(
                        words.iter().map(|&w| Json::Num(w as f64)).collect(),
                    ),
                ),
            ]
            .into_iter()
            .collect(),
        ));
        Request {
            method: "POST".into(),
            target: "/v1/batch".into(),
            version: "HTTP/1.1".into(),
            headers: BTreeMap::new(),
            body: body.into_bytes(),
        }
    }

    fn words_of(body: &[u8]) -> Vec<i64> {
        let v = json::parse(std::str::from_utf8(body).unwrap()).unwrap();
        v.get("words")
            .and_then(Json::as_arr)
            .expect("words array")
            .iter()
            .map(|w| w.as_f64().unwrap() as i64)
            .collect()
    }

    /// ≥ 64 seeded schedules: random batches fanned out across three
    /// replicas with a randomly injected shard fault (response loss,
    /// crash, or a healthy run) must merge bit-exactly with an
    /// unclustered single-node reference — shard failures degrade to
    /// whole-batch local service, never to wrong answers.
    #[test]
    fn sim_fanout_merge_is_bit_exact_under_shard_faults() {
        // Unclustered reference front.
        let reference = Arc::new(AppState {
            router: Router::start(vec![Route::native(
                "s3_5",
                TanhConfig::s3_5(),
            )])
            .unwrap(),
            http: HttpCounters::default(),
            started: Instant::now(),
            request_timeout: Duration::from_secs(5),
            cluster: None,
            trace: Arc::new(trace::TraceStore::new(
                trace::DEFAULT_SPAN_CAPACITY,
                7,
                u64::MAX,
            )),
            clock: trace::Clock::wall(),
            backend: "sim",
        });
        let addrs: Vec<String> =
            (1..=3).map(|i| format!("n{i}:1")).collect();
        for seed in schedule_seeds(0xfa0, 64) {
            let mut rng = scenario_rng(seed);
            let net = SimNet::new();
            let fronts: Vec<SimFront> = addrs
                .iter()
                .map(|a| {
                    let peers: Vec<String> = addrs
                        .iter()
                        .filter(|p| *p != a)
                        .cloned()
                        .collect();
                    start_front(&net, a, peers, 3)
                })
                .collect();
            // 3..=24 random in-range words for the s3_5 format
            // (mag_bits = 3 + 5 -> words in [-256, 256)).
            let n = 3 + rng.below(22) as usize;
            let words: Vec<i64> =
                (0..n).map(|_| rng.below(512) as i64 - 256).collect();
            // Stage at most one fault, chosen by the seed.
            match rng.below(4) {
                0 => {
                    let victim = &addrs[1 + rng.below(2) as usize];
                    net.drop_responses("n1:1", victim, 1);
                }
                1 => {
                    let victim = &addrs[1 + rng.below(2) as usize];
                    net.crash(victim);
                }
                2 => {
                    let victim = &addrs[1 + rng.below(2) as usize];
                    net.set_slow(victim, 1000); // beyond proxy read budget
                }
                _ => {}
            }
            let resp =
                api::dispatch(&fronts[0].state, &batch_req(&words));
            assert_eq!(
                resp.status, 200,
                "[seed {seed}] fan-out request failed: {}",
                String::from_utf8_lossy(&resp.body)
            );
            let want = api::dispatch(&reference, &batch_req(&words));
            assert_eq!(
                words_of(&resp.body),
                words_of(&want.body),
                "[seed {seed}] fan-out merge diverged from the \
                 single-node reference (replay: TANHVF_SIM_SEED={seed} \
                 cargo test -q sim_fanout)"
            );
            for f in &fronts {
                f.cluster.stop();
            }
        }
    }

    /// Healthy fan-out actually splits: with no faults and a local
    /// replica, the batch is served by shards (fanout_batches ticks)
    /// and remote peers execute.
    #[test]
    fn sim_fanout_splits_across_replicas_when_healthy() {
        let net = SimNet::new();
        let addrs: Vec<String> =
            (1..=3).map(|i| format!("m{i}:1")).collect();
        let fronts: Vec<SimFront> = addrs
            .iter()
            .map(|a| {
                let peers: Vec<String> =
                    addrs.iter().filter(|p| *p != a).cloned().collect();
                start_front(&net, a, peers, 3)
            })
            .collect();
        let words: Vec<i64> = (0..24).map(|i| i * 9 - 100).collect();
        let resp = api::dispatch(&fronts[0].state, &batch_req(&words));
        assert_eq!(resp.status, 200);
        assert_eq!(
            fronts[0]
                .cluster
                .stats
                .fanout_batches
                .load(Ordering::Relaxed),
            1
        );
        let remote_execs: u64 =
            addrs[1..].iter().map(|a| net.executions(a)).sum();
        assert!(
            remote_execs >= 2,
            "both remote replicas should serve a shard, got {remote_execs}"
        );
        // And every replica is in the live set seen by node 1.
        assert_eq!(fronts[0].cluster.live_replicas("s3_5")[0], Node::Local);
        for f in &fronts {
            f.cluster.stop();
        }
    }

    // -- trace determinism ---------------------------------------------

    fn get_req(path: &str) -> Request {
        Request {
            method: "GET".into(),
            target: path.into(),
            version: "HTTP/1.1".into(),
            headers: BTreeMap::new(),
            body: Vec::new(),
        }
    }

    /// Same build twice → bit-identical span trees: trace/span IDs come
    /// from pinned seeds, timestamps from the virtual clock, and the
    /// fan-out allocates shard span IDs (and runs its local shard)
    /// before any shard thread spawns, so nothing in the tree depends
    /// on thread interleaving.
    #[test]
    fn sim_trace_span_tree_is_deterministic() {
        let run = || {
            let net = SimNet::new();
            let addrs = ["t1:1".to_string(), "t2:1".to_string()];
            let fronts: Vec<SimFront> = addrs
                .iter()
                .map(|a| {
                    let peers: Vec<String> = addrs
                        .iter()
                        .filter(|p| *p != a)
                        .cloned()
                        .collect();
                    start_front(&net, a, peers, 2)
                })
                .collect();
            let words: Vec<i64> = (0..16).map(|i| i * 11 - 80).collect();
            let resp = api::dispatch(&fronts[0].state, &batch_req(&words));
            assert_eq!(resp.status, 200);
            let trace_hex = resp
                .headers
                .iter()
                .find(|(k, _)| k == trace::TRACE_HEADER)
                .map(|(_, v)| v.clone())
                .expect("traced response carries the trace header");
            let tree = api::dispatch(
                &fronts[0].state,
                &get_req(&format!("/debug/trace/{trace_hex}")),
            );
            assert_eq!(tree.status, 200);
            for f in &fronts {
                f.cluster.stop();
            }
            (trace_hex, String::from_utf8(tree.body).unwrap())
        };
        let (id1, tree1) = run();
        let (id2, tree2) = run();
        assert_eq!(id1, id2, "trace IDs must replay identically");
        assert_eq!(tree1, tree2, "span trees must replay bit-identically");
        // Structure: one server root whose children are the fan-out's
        // local shard and the remote shard leg.
        let v = json::parse(&tree1).unwrap();
        let roots = v.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(roots.len(), 1, "single server root");
        let root = &roots[0];
        assert_eq!(root.get("kind").unwrap().as_str().unwrap(), "server");
        let kids = root.get("children").unwrap().as_arr().unwrap();
        let kinds: Vec<&str> = kids
            .iter()
            .map(|k| k.get("kind").unwrap().as_str().unwrap())
            .collect();
        assert!(kinds.contains(&"local"), "local shard child: {kinds:?}");
        assert!(kinds.contains(&"shard"), "remote shard child: {kinds:?}");
        // Virtual-clock timestamps: the remote shard leg spans virtual
        // time (connect+send+recv each tick the clock), the server span
        // encloses its children.
        let root_start =
            root.get("start_us").unwrap().as_f64().unwrap() as u64;
        let root_end = root.get("end_us").unwrap().as_f64().unwrap() as u64;
        for k in kids {
            let ks = k.get("start_us").unwrap().as_f64().unwrap() as u64;
            let ke = k.get("end_us").unwrap().as_f64().unwrap() as u64;
            assert!(ks <= ke, "child span runs backwards");
            assert!(
                root_start <= ks && ke <= root_end,
                "child span escapes the server span"
            );
        }
    }
}
