//! Cluster tier: consistent-hash routing of model names across several
//! serving processes, with dynamic gossip membership, a health-checked
//! peer table, pooled proxy connections, and optional route
//! replication with read fan-out.
//!
//! The paper frames one datapath generator serving *many* precision
//! design points; the router (L3) places those points side by side in
//! one process, and this module shards them across processes. Each
//! node runs the same HTTP front end ([`super::Server`]); a node
//! started in cluster mode additionally owns:
//!
//! * [`HashRing`] — consistent hashing with virtual nodes over the
//!   dependency-free [`hash64`] (FNV-1a + splitmix64 finalizer). Every
//!   node hashes the same identifier set (the alive members of the
//!   gossip table), so converged fronts agree on ownership. A key's
//!   candidate order is the ring walk from its hash point: the owner
//!   first, then the nodes that would inherit it — which is exactly
//!   the failover order, so a dead node's keys move *only* to their
//!   next-in-ring successor and every other key keeps its owner. The
//!   ring is rebuilt only on *membership* changes (join, death,
//!   resurrection — see [`super::gossip`]); short outages are handled
//!   by liveness filtering at lookup time, so placement stays a pure
//!   function of the alive-member set.
//! * **Gossip membership** ([`super::gossip`]): the member table is
//!   exchanged with one peer per probe round over `POST /v1/gossip`,
//!   seeds from `--join` are contacted until merged, and `--peers`
//!   degenerates to the static-bootstrap special case. Sustained probe
//!   failure (`failure_threshold` × [`gossip::DEATH_FACTOR`]) declares
//!   a member dead; direct probe recovery or a higher incarnation
//!   resurrects it.
//! * A peer table with a background prober: `GET /health` every
//!   `probe_interval`, [`ClusterConfig::failure_threshold`] consecutive
//!   failures evict a peer from routing, and `recovery_threshold`
//!   consecutive successes re-admit it. Proxy traffic feeds the same
//!   accounting, so a dead peer is usually evicted by the first failed
//!   forward, not a probe tick later.
//! * A per-peer keep-alive connection pool ([`super::pool`]) under
//!   every client leg — proxy, probe, and gossip. A round trip that
//!   fails on a *reused* connection is retried once on a fresh dial
//!   (the peer may simply have closed the idle connection); pool
//!   hit/miss/discard/eviction counters surface on `/metrics`.
//! * Replicated routes: with [`ClusterConfig::replicas`] `= N > 1`, a
//!   key maps to the N successor nodes on the ring. Reads are served
//!   by *any* live replica (`/v1/eval` rotates across them;
//!   bit-exactness makes every replica equivalent), and `/v1/batch`
//!   requests can split across the replica set and merge (the fan-out
//!   itself lives in [`super::api`]).
//! * The proxy path: `/v1/eval` and `/v1/batch` bodies whose model is
//!   owned elsewhere are forwarded verbatim (the incremental parser
//!   has already decoded chunked or `Content-Length` framing, so the
//!   hop is a plain `Content-Length` POST) tagged with
//!   [`PROXIED_HEADER`]; tagged requests are always answered locally,
//!   which bounds any transient ring disagreement to one hop.
//! * **Load-adaptive routing (PR 10).** Every gossip exchange
//!   piggybacks this node's load ([`NodeLoad`]: run-queue depth, EWMA
//!   request latency, arena bytes) on its member entry, so each node
//!   holds a freshness-versioned load view of its peers. Reads whose
//!   replica set excludes the local node pick their first candidate by
//!   *power of two choices* over that view — two replicas drawn from
//!   the known-load set, lower queue depth wins (EWMA latency, then
//!   ring order, break ties) — which bounds herd effects without
//!   global coordination; peers with unknown load (pre-PR-10 nodes,
//!   or nothing learned yet) fall back to the rotation cursor. A
//!   hot-route controller, run by each route's ring owner once per
//!   membership round, raises the route's *effective replica count*
//!   when its request-rate EWMA crosses [`HOT_EXPAND_PER_ROUND`] and
//!   lowers it below [`HOT_SHRINK_PER_ROUND`], with a
//!   [`HOT_COOLDOWN_ROUNDS`] hysteresis window; each change is a
//!   monotone-epoch [`gossip::RouteClaim`] disseminated with the
//!   member table, so all nodes converge on one replica set even when
//!   both sides of a partition raised the same route.

use std::collections::BTreeMap;
use std::sync::atomic::{
    AtomicBool, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Histogram;
use crate::util::json;
use crate::util::log;
use crate::util::rng::SplitMix64;

use super::gossip::{
    self, LoadInfo, Member, MemberEntry, RouteClaim, RouteOverride,
};
use super::http::Response;
use super::pool::ConnPool;
use super::transport::{Deadlines, TcpTransport, Transport};

/// Header marking a request as already forwarded once: the receiving
/// node must answer locally, never re-proxy (loop guard).
pub const PROXIED_HEADER: &str = "x-tanhvf-proxied";

/// Response-size bound for the proxy leg (mirrors the loadgen client).
const MAX_PROXY_BODY: usize = 1 << 22;

/// Response-size bound for probe/gossip control traffic.
const MAX_CONTROL_BODY: usize = 1 << 20;

/// Hot-route controller: request-rate EWMA (client-facing requests per
/// membership round, as seen by the route's owner) at or above this
/// adds one effective replica.
pub const HOT_EXPAND_PER_ROUND: u64 = 32;

/// …and at or below this drops one (never below the configured base).
/// The wide gap between the two thresholds is the hysteresis band: a
/// route whose EWMA flaps inside `(8, 32)` never transitions at all.
pub const HOT_SHRINK_PER_ROUND: u64 = 8;

/// Minimum membership rounds between two replica-count transitions of
/// the same route — the second hysteresis stage, bounding transition
/// frequency even for loads that swing across both thresholds.
pub const HOT_COOLDOWN_ROUNDS: u64 = 3;

/// Route-traffic EWMA smoothing: `alpha = 1/2^ROUTE_EWMA_SHIFT` (1/4),
/// in x16 fixed point so small per-round counts don't truncate to 0.
pub const ROUTE_EWMA_SHIFT: u32 = 2;

/// Bound on distinct route names tracked for the hot-route controller;
/// requests for names past the cap are routed normally but never
/// tracked (crafted model names must not grow the table unboundedly).
pub const MAX_TRACKED_ROUTES: usize = 256;

/// `le` bounds of the p2c chosen-queue-depth histogram (requests, not
/// time — rendered by hand in [`super::api`], same exposition rules).
pub const DEPTH_BOUNDS: [u64; 10] = [0, 1, 2, 4, 8, 16, 32, 64, 128, 256];

/// FNV-1a 64-bit: the dependency-free byte hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Ring hash: FNV-1a with a splitmix64 finalizer (the same mixing
/// constants [`crate::util::rng`] seeds with). Raw FNV-1a is too
/// correlated on near-identical short strings — `addr#0`, `addr#1`, …
/// vnode labels land in clumps and the arc shares skew ~3x — and the
/// finalizer's avalanche restores an even spread.
pub fn hash64(bytes: &[u8]) -> u64 {
    let mut z = fnv1a64(bytes).wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------
// Hash ring
// ---------------------------------------------------------------------

/// Consistent-hash ring with virtual nodes.
///
/// Each instance is immutable; membership changes build a *new* ring
/// and swap it in atomically ([`Cluster::ring`] returns the current
/// snapshot). Short-lived
/// liveness changes (eviction, re-admission) never rebuild — they are
/// applied at lookup time by walking past unroutable nodes — so the
/// placement of keys on live nodes is a pure function of the
/// alive-member set.
pub struct HashRing {
    /// (hash point, node index), sorted by hash point.
    points: Vec<(u64, u32)>,
    nodes: Vec<String>,
}

impl HashRing {
    /// Build over the deduplicated, name-sorted node set; each node
    /// contributes `virtual_nodes` points.
    pub fn new(nodes: &[String], virtual_nodes: usize) -> HashRing {
        let mut uniq: Vec<String> = nodes.to_vec();
        uniq.sort();
        uniq.dedup();
        let vnodes = virtual_nodes.max(1);
        let mut points = Vec::with_capacity(uniq.len() * vnodes);
        for (i, n) in uniq.iter().enumerate() {
            for v in 0..vnodes {
                points.push((hash64(format!("{n}#{v}").as_bytes()), i as u32));
            }
        }
        points.sort_unstable();
        HashRing { points, nodes: uniq }
    }

    /// The configured node set (sorted, deduplicated).
    pub fn nodes(&self) -> &[String] {
        &self.nodes
    }

    /// Every node in ring-walk order from `key`'s hash point: the
    /// owner first, then successive inheritors. Deterministic for a
    /// given (node set, virtual_nodes, key).
    pub fn successors(&self, key: &str) -> Vec<&str> {
        if self.points.is_empty() {
            return Vec::new();
        }
        let h = hash64(key.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut seen = vec![false; self.nodes.len()];
        let mut out = Vec::with_capacity(self.nodes.len());
        for off in 0..self.points.len() {
            let (_, ni) = self.points[(start + off) % self.points.len()];
            let ni = ni as usize;
            if !seen[ni] {
                seen[ni] = true;
                out.push(self.nodes[ni].as_str());
                if out.len() == self.nodes.len() {
                    break;
                }
            }
        }
        out
    }

    /// The key's owner ignoring liveness.
    pub fn owner(&self, key: &str) -> Option<&str> {
        self.successors(key).first().copied()
    }
}

// ---------------------------------------------------------------------
// Peer table
// ---------------------------------------------------------------------

/// Routing view of one peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerHealth {
    /// Answering probes/proxies; routable.
    Healthy,
    /// Recent failures below the eviction threshold; still routable.
    Suspect,
    /// Evicted from routing until `recovery_threshold` consecutive
    /// successful probes (or tombstoned in the membership table).
    Down,
}

impl PeerHealth {
    pub fn name(&self) -> &'static str {
        match self {
            PeerHealth::Healthy => "healthy",
            PeerHealth::Suspect => "suspect",
            PeerHealth::Down => "down",
        }
    }
}

#[derive(Clone, Debug)]
struct PeerSlot {
    health: PeerHealth,
    consecutive_failures: u32,
    consecutive_successes: u32,
    /// Consecutive failed *probe rounds* (proxy traffic excluded):
    /// the death-declaration clock. Proxy failures arrive at request
    /// rate, so counting them would collapse the "sustained failure"
    /// margin from ~10 probe intervals to milliseconds under load.
    consecutive_probe_failures: u32,
    /// Mirror of "the member table holds a tombstone for this peer".
    /// Kept on the slot so the per-request success path can decide
    /// whether a resurrection is even possible without ever touching
    /// the membership mutex.
    dead: bool,
}

impl PeerSlot {
    fn new() -> PeerSlot {
        PeerSlot {
            health: PeerHealth::Healthy,
            consecutive_failures: 0,
            consecutive_successes: 0,
            consecutive_probe_failures: 0,
            dead: false,
        }
    }
}

/// Fixed-bucket histogram over small counts (queue depths), bounds in
/// [`DEPTH_BOUNDS`]. The latency [`Histogram`] is hard-wired to
/// microsecond bounds, so depth samples get their own shape; buckets
/// are stored per-bin and cumulated at snapshot time.
#[derive(Default)]
pub struct DepthHist {
    bins: [AtomicU64; DEPTH_BOUNDS.len()],
    overflow: AtomicU64,
    sum: AtomicU64,
}

impl DepthHist {
    pub fn observe(&self, depth: u64) {
        match DEPTH_BOUNDS.iter().position(|&b| depth <= b) {
            Some(i) => self.bins[i].fetch_add(1, Ordering::Relaxed),
            None => self.overflow.fetch_add(1, Ordering::Relaxed),
        };
        self.sum.fetch_add(depth, Ordering::Relaxed);
    }

    /// Cumulative counts per bound, then (total count, sum).
    pub fn snapshot(&self) -> ([u64; DEPTH_BOUNDS.len()], u64, u64) {
        let mut cum = [0u64; DEPTH_BOUNDS.len()];
        let mut total = 0u64;
        for (i, b) in self.bins.iter().enumerate() {
            total += b.load(Ordering::Relaxed);
            cum[i] = total;
        }
        total += self.overflow.load(Ordering::Relaxed);
        (cum, total, self.sum.load(Ordering::Relaxed))
    }
}

/// This node's self-reported load gauges — the source of the gossip
/// load stanza and the local side of every p2c comparison. All plain
/// atomics: the request path touches two per request and never a lock.
#[derive(Default)]
pub struct NodeLoad {
    /// Freshness stamp bumped once per outgoing gossip sample.
    version: AtomicU64,
    queue_depth: AtomicU64,
    ewma_latency_us: AtomicU64,
    arena_bytes: AtomicU64,
}

impl NodeLoad {
    /// A local request entered service (run-queue depth +1).
    pub fn begin_request(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// …and finished after `latency_us`. EWMA `alpha = 1/8`, integer:
    /// `new = (7*old + sample) / 8` (the multiply-first form keeps
    /// sub-8µs samples from vanishing into shift truncation).
    pub fn end_request(&self, latency_us: u64) {
        let _ = self.queue_depth.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |q| Some(q.saturating_sub(1)),
        );
        let old = self.ewma_latency_us.load(Ordering::Relaxed);
        let new = old.saturating_mul(7).saturating_add(latency_us) / 8;
        self.ewma_latency_us.store(new, Ordering::Relaxed);
    }

    /// Override the queue-depth gauge directly — the deterministic
    /// sim drivers model queues in virtual time and publish them here.
    pub fn set_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Stamp a fresh report: bump the freshness version and snapshot
    /// every gauge.
    fn stamp(&self) -> LoadInfo {
        let version = self.version.fetch_add(1, Ordering::Relaxed) + 1;
        LoadInfo {
            version,
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            ewma_latency_us: self.ewma_latency_us.load(Ordering::Relaxed),
            arena_bytes: self.arena_bytes.load(Ordering::Relaxed),
        }
    }

    /// Current gauges without a version bump (metrics display).
    pub fn peek(&self) -> LoadInfo {
        LoadInfo {
            version: self.version.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            ewma_latency_us: self.ewma_latency_us.load(Ordering::Relaxed),
            arena_bytes: self.arena_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Per-route traffic accounting for the hot-route controller.
#[derive(Default)]
struct RouteTraffic {
    /// Client-facing requests seen since the last controller round.
    count: u64,
    /// Request-rate EWMA in x16 fixed point (see [`ROUTE_EWMA_SHIFT`]).
    ewma_x16: u64,
    /// Controller round of the last replica-count transition (the
    /// cooldown clock).
    last_transition_round: u64,
}

/// Cluster-wide counters surfaced on `/metrics`.
#[derive(Default)]
pub struct ClusterStats {
    /// Eval/batch requests answered by the local router (owned here).
    pub local: AtomicU64,
    /// Requests forwarded to a peer (successful round trip).
    pub proxied: AtomicU64,
    /// Forwarded requests received from another front.
    pub proxied_in: AtomicU64,
    /// Transport failures on the proxy leg.
    pub proxy_errors: AtomicU64,
    /// Requests served by a non-first candidate after the owner failed.
    pub failovers: AtomicU64,
    /// Peer transitions into `Down`.
    pub evictions: AtomicU64,
    /// Peer transitions out of `Down`.
    pub readmissions: AtomicU64,
    /// Successful outbound gossip exchanges.
    pub gossip_ok: AtomicU64,
    /// Failed outbound gossip exchanges (transport, non-200, bad body).
    pub gossip_fail: AtomicU64,
    /// Inbound `POST /v1/gossip` messages merged.
    pub gossip_in: AtomicU64,
    /// Members added to the table alive (joins).
    pub members_joined: AtomicU64,
    /// Members tombstoned (local death declaration or gossiped
    /// certificate).
    pub members_died: AtomicU64,
    /// Tombstoned members brought back (direct probe recovery or a
    /// newer incarnation via gossip).
    pub members_resurrected: AtomicU64,
    /// Times this node saw itself reported dead and bumped its
    /// incarnation past the report.
    pub gossip_refutations: AtomicU64,
    /// Tombstones evicted from the member table to admit a join at the
    /// table bound.
    pub tombstone_evictions: AtomicU64,
    /// `/v1/batch` requests served by splitting across replicas.
    pub fanout_batches: AtomicU64,
    /// Fan-outs abandoned mid-flight and served whole locally.
    pub fanout_fallbacks: AtomicU64,
    /// Latency of proxy forward legs (the `clustered()` walk in
    /// [`super::api`] observes these; failures count too).
    pub forward_hist: Histogram,
    /// Latency of remote `/v1/batch` fan-out shard legs.
    pub shard_hist: Histogram,
    /// Wall time of one whole gossip round (all targets).
    pub gossip_round_hist: Histogram,
    /// First candidates resolved to the local node (a replica here
    /// always serves in place — no hop beats any queue).
    pub p2c_local_picks: AtomicU64,
    /// First candidates chosen by power-of-two-choices over known
    /// peer loads.
    pub p2c_load_picks: AtomicU64,
    /// First candidates that fell back to the rotation cursor (fewer
    /// than two replicas with known load, or `load_adaptive` off).
    pub p2c_rotation_picks: AtomicU64,
    /// Queue depth of the replica each p2c pick selected.
    pub p2c_depth_hist: DepthHist,
    /// Hot-route controller transitions raising a replica count.
    pub route_expansions: AtomicU64,
    /// …and lowering one (back toward the configured base).
    pub route_shrinks: AtomicU64,
}

/// Where a key's next candidate lives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Node {
    /// This process owns the key: serve through the local router.
    Local,
    /// A peer owns it: proxy to this address.
    Peer(String),
}

/// Tuning for one cluster node. `advertise` is the identity this node
/// hashes itself under — it must match what the other fronts know it
/// by, whether learned from their `--peers` flags or over gossip (an
/// empty string is filled with the bound address by
/// [`super::Server::start_cluster`]).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub advertise: String,
    /// Static bootstrap members (immediately part of the ring).
    pub peers: Vec<String>,
    /// Gossip seeds: contacted every round until they appear in the
    /// member table. Unlike `peers` they are *not* ring members until
    /// they actually answer.
    pub join: Vec<String>,
    /// Nodes each route key lives on (the key's N ring successors).
    /// `1` = classic single-owner sharding; `N > 1` lets any of the N
    /// serve reads and `/v1/batch` split across them.
    pub replicas: usize,
    /// Ring points per node; more points = tighter load spread per key
    /// at O(nodes * virtual_nodes * log) build cost.
    pub virtual_nodes: usize,
    pub probe_interval: Duration,
    /// Connect/read budget for one probe or gossip exchange.
    pub probe_timeout: Duration,
    /// Consecutive failures (probe or proxy) that evict a peer.
    pub failure_threshold: u32,
    /// Consecutive successful probes that re-admit an evicted peer.
    pub recovery_threshold: u32,
    /// End-to-end budget for one forwarded request.
    pub proxy_timeout: Duration,
    /// Bound on concurrent outbound forwards. A forward blocks the
    /// worker thread driving it, so an unbounded count lets two fronts
    /// proxying to each other fill both worker pools and deadlock
    /// until `proxy_timeout`; past the bound requests are shed with
    /// 503 instead. `0` means "derive from the server's worker count"
    /// ([`super::Server::start_cluster`] fills in `workers / 2`,
    /// minimum 1, so at least half the pool always stays available for
    /// local and proxied-in work).
    pub max_inflight_forwards: usize,
    /// Idle keep-alive connections kept per peer by the client-leg
    /// pool; `0` disables pooling (every request dials fresh).
    pub pool_idle_per_peer: usize,
    /// Test override for the gossip incarnation; `None` stamps the
    /// node with wall-clock millis at start.
    pub incarnation: Option<u64>,
    /// When true no membership thread is spawned — a deterministic
    /// driver (the [`super::sim`] harness) calls
    /// [`Cluster::membership_round`] itself, under virtual time.
    pub manual_rounds: bool,
    /// Load-adaptive routing master switch: p2c read selection and the
    /// hot-route controller. Off, reads use the fixed rotation cursor
    /// and replica counts never move — the frozen-ring baseline the
    /// sim scenarios compare against.
    pub load_adaptive: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            advertise: String::new(),
            peers: Vec::new(),
            join: Vec::new(),
            replicas: 1,
            virtual_nodes: 64,
            probe_interval: Duration::from_millis(500),
            probe_timeout: Duration::from_secs(1),
            failure_threshold: 3,
            recovery_threshold: 2,
            proxy_timeout: Duration::from_secs(10),
            max_inflight_forwards: 0,
            pool_idle_per_peer: 4,
            incarnation: None,
            manual_rounds: false,
            load_adaptive: true,
        }
    }
}

/// The gossip-owned membership view: who is in the cluster, under
/// which incarnation, and whether they are ring members (`alive`).
struct MembershipState {
    table: BTreeMap<String, Member>,
    self_inc: u64,
    /// Bumped on every ring rebuild; exposed on `/metrics` so
    /// convergence is observable.
    version: u64,
}

/// A running cluster view: membership + ring + peer table + pool +
/// prober/gossip thread.
pub struct Cluster {
    cfg: ClusterConfig,
    membership: Mutex<MembershipState>,
    ring: RwLock<Arc<HashRing>>,
    peers: Mutex<BTreeMap<String, PeerSlot>>,
    /// Keep-alive client-leg pool (proxy + probe + gossip).
    pub pool: ConnPool,
    pub stats: ClusterStats,
    /// Concurrent outbound forwards (bounded by
    /// `cfg.max_inflight_forwards`).
    inflight_forwards: AtomicUsize,
    /// Round-robin cursor over gossip targets.
    gossip_cursor: AtomicUsize,
    /// Gossip rounds completed (the clock for seed backoff).
    gossip_rounds: AtomicU64,
    /// Per-seed retry backoff: (consecutive failures, next round the
    /// seed may be contacted). A blackholed seed would otherwise cost
    /// a full connect timeout on the shared membership thread every
    /// round, forever.
    seed_backoff: Mutex<BTreeMap<String, (u32, u64)>>,
    /// Rotation cursor spreading replica reads.
    replica_cursor: AtomicUsize,
    /// This node's load gauges (the gossip load stanza's source).
    load: NodeLoad,
    /// Optional sampler refreshing the arena-bytes gauge right before
    /// each outgoing load report (installed by
    /// [`super::Server::start_cluster`]; absent in sims so load stays
    /// a pure function of what the driver injected).
    arena_sampler: Mutex<Option<Arc<dyn Fn() -> u64 + Send + Sync>>>,
    /// Last known load per peer, learned from gossip stanzas. The map
    /// is an immutable snapshot swapped on change: the per-request p2c
    /// read is an `Arc` clone, never a rebuild.
    peer_loads: RwLock<Arc<BTreeMap<String, LoadInfo>>>,
    /// Hot-route replica claims (gossiped join-semilattice state).
    route_claims: Mutex<BTreeMap<String, RouteClaim>>,
    /// Per-route traffic counters feeding the hot-route controller.
    route_traffic: Mutex<BTreeMap<String, RouteTraffic>>,
    /// Controller rounds completed (the cooldown clock).
    controller_rounds: AtomicU64,
    /// Deterministic p2c draw sequence (splitmix over a ticket
    /// counter — no wall-clock or OS randomness on the request path,
    /// so sim schedules replay bit-identically).
    p2c_ticket: AtomicU64,
    shutdown: Arc<AtomicBool>,
    prober: Mutex<Option<std::thread::JoinHandle<()>>>,
}

fn now_millis() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(1)
}

impl Cluster {
    /// Validate, build the bootstrap membership + ring, and launch the
    /// membership thread (probe + gossip rounds) over real TCP.
    pub fn start(cfg: ClusterConfig) -> Result<Arc<Cluster>, String> {
        Cluster::start_with_transport(cfg, Arc::new(TcpTransport))
    }

    /// [`Cluster::start`] with an explicit client-leg transport — the
    /// seam the deterministic simulation injects its virtual network
    /// through ([`super::sim::SimTransport`]).
    pub fn start_with_transport(
        mut cfg: ClusterConfig,
        transport: Arc<dyn Transport>,
    ) -> Result<Arc<Cluster>, String> {
        if cfg.advertise.is_empty() {
            return Err("cluster: advertise address must be set".into());
        }
        if cfg.peers.iter().any(|p| p == &cfg.advertise) {
            return Err(format!(
                "cluster: --peers must not include the node itself ({})",
                cfg.advertise
            ));
        }
        if cfg.join.iter().any(|p| p == &cfg.advertise) {
            return Err(format!(
                "cluster: --join must not include the node itself ({})",
                cfg.advertise
            ));
        }
        if cfg.failure_threshold == 0 || cfg.recovery_threshold == 0 {
            return Err("cluster: thresholds must be >= 1".into());
        }
        if cfg.replicas == 0 {
            return Err("cluster: --replicas must be >= 1".into());
        }
        if cfg.max_inflight_forwards == 0 {
            // "Auto" without a known worker count: effectively
            // unbounded. The HTTP server substitutes workers/2 before
            // starting the cluster.
            cfg.max_inflight_forwards = usize::MAX;
        }
        let self_inc = cfg.incarnation.unwrap_or_else(now_millis);
        let mut table = BTreeMap::new();
        table.insert(
            cfg.advertise.clone(),
            Member { incarnation: self_inc, alive: true },
        );
        for p in &cfg.peers {
            // Static peers bootstrap at incarnation 0: any gossip from
            // the real node supersedes the placeholder.
            table.insert(p.clone(), Member { incarnation: 0, alive: true });
        }
        let nodes: Vec<String> = table.keys().cloned().collect();
        let ring = Arc::new(HashRing::new(&nodes, cfg.virtual_nodes));
        let peers = cfg
            .peers
            .iter()
            .map(|p| (p.clone(), PeerSlot::new()))
            .collect::<BTreeMap<_, _>>();
        let pool =
            ConnPool::with_transport(cfg.pool_idle_per_peer, transport);
        let cluster = Arc::new(Cluster {
            membership: Mutex::new(MembershipState {
                table,
                self_inc,
                version: 0,
            }),
            ring: RwLock::new(ring),
            peers: Mutex::new(peers),
            pool,
            stats: ClusterStats::default(),
            inflight_forwards: AtomicUsize::new(0),
            gossip_cursor: AtomicUsize::new(0),
            gossip_rounds: AtomicU64::new(0),
            seed_backoff: Mutex::new(BTreeMap::new()),
            replica_cursor: AtomicUsize::new(0),
            load: NodeLoad::default(),
            arena_sampler: Mutex::new(None),
            peer_loads: RwLock::new(Arc::new(BTreeMap::new())),
            route_claims: Mutex::new(BTreeMap::new()),
            route_traffic: Mutex::new(BTreeMap::new()),
            controller_rounds: AtomicU64::new(0),
            p2c_ticket: AtomicU64::new(0),
            shutdown: Arc::new(AtomicBool::new(false)),
            prober: Mutex::new(None),
            cfg,
        });
        if cluster.cfg.manual_rounds {
            // Deterministic drivers own the round clock; spawning (and
            // later joining) a thread per simulated node would also
            // dominate the sim harness's wall time.
            return Ok(cluster);
        }
        // The membership thread always runs in cluster mode — even a
        // seed node with no peers and no joins must probe/gossip the
        // members that later announce themselves over /v1/gossip.
        //
        // It holds only a Weak reference: a Cluster whose owners all
        // drop without calling stop() still gets its Drop (the upgrade
        // fails and the thread exits) instead of an Arc cycle keeping
        // both alive forever.
        let weak: Weak<Cluster> = Arc::downgrade(&cluster);
        let shutdown = cluster.shutdown.clone();
        let interval = cluster.cfg.probe_interval;
        let t = std::thread::Builder::new()
            .name("tanhvf-cluster-prober".into())
            .spawn(move || loop {
                // Sleep first (in short slices so stop() is prompt):
                // freshly started peers keep the optimistic Healthy
                // default for one interval, and deterministic tests
                // see no startup probe race.
                let mut left = interval;
                while !left.is_zero() {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    let step = left.min(Duration::from_millis(25));
                    std::thread::sleep(step);
                    left -= step;
                }
                let Some(c) = weak.upgrade() else { return };
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                c.membership_round();
            })
            .map_err(|e| format!("spawn prober: {e}"))?;
        *cluster.prober.lock().unwrap() = Some(t);
        Ok(cluster)
    }

    /// Stop the membership thread and join it. Idempotent. Joining is
    /// skipped when called *from* that thread (possible when its
    /// transient strong reference is the last one and its drop runs
    /// this via `Drop for Cluster`) — the thread exits on its own
    /// right after.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let handle = self.prober.lock().unwrap().take();
        if let Some(t) = handle {
            if t.thread().id() != std::thread::current().id() {
                let _ = t.join();
            }
        }
    }

    /// Reserve one slot of outbound-forward concurrency, or `None` when
    /// the bound is reached (the caller sheds load). The permit returns
    /// its slot on drop.
    pub fn try_forward_permit(&self) -> Option<ForwardPermit<'_>> {
        let limit = self.cfg.max_inflight_forwards;
        let mut cur = self.inflight_forwards.load(Ordering::Relaxed);
        loop {
            if cur >= limit {
                return None;
            }
            match self.inflight_forwards.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(ForwardPermit(self)),
                Err(seen) => cur = seen,
            }
        }
    }

    /// This node's ring identity.
    pub fn self_name(&self) -> &str {
        &self.cfg.advertise
    }

    /// The current ring (an atomic snapshot: membership changes swap
    /// in a new ring rather than mutating this one).
    pub fn ring(&self) -> Arc<HashRing> {
        self.ring.read().unwrap().clone()
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    // -- membership ---------------------------------------------------

    /// Snapshot of the gossip member table (includes self and
    /// tombstones).
    pub fn members(&self) -> BTreeMap<String, Member> {
        self.membership.lock().unwrap().table.clone()
    }

    /// The member table as wire entries (what we gossip out). The
    /// local entry carries a freshly stamped load stanza; peer entries
    /// relay the freshest report we hold for them, so load spreads
    /// epidemic-style even between nodes that never exchange directly.
    pub fn member_entries(&self) -> Vec<MemberEntry> {
        let self_load = self.sample_self_load();
        let loads = self.peer_loads.read().unwrap().clone();
        self.membership
            .lock()
            .unwrap()
            .table
            .iter()
            .map(|(a, m)| MemberEntry {
                addr: a.clone(),
                incarnation: m.incarnation,
                alive: m.alive,
                load: if a == &self.cfg.advertise {
                    Some(self_load)
                } else {
                    loads.get(a).copied()
                },
            })
            .collect()
    }

    /// Refresh the arena gauge through the installed sampler (if any)
    /// and stamp a fresh self-load report.
    fn sample_self_load(&self) -> LoadInfo {
        let sampler = self.arena_sampler.lock().unwrap().clone();
        if let Some(f) = sampler {
            self.load.arena_bytes.store(f(), Ordering::Relaxed);
        }
        self.load.stamp()
    }

    /// Install the arena-bytes sampler (called once at server start;
    /// sims leave it unset so load signals stay driver-controlled).
    pub fn set_arena_sampler(&self, f: Arc<dyn Fn() -> u64 + Send + Sync>) {
        *self.arena_sampler.lock().unwrap() = Some(f);
    }

    /// This node's load gauges (the request path and sim drivers feed
    /// them; gossip samples them).
    pub fn load(&self) -> &NodeLoad {
        &self.load
    }

    /// Snapshot of the gossip-learned peer load view.
    pub fn peer_loads(&self) -> Arc<BTreeMap<String, LoadInfo>> {
        self.peer_loads.read().unwrap().clone()
    }

    /// Alive members (ring size).
    pub fn alive_members(&self) -> usize {
        self.membership
            .lock()
            .unwrap()
            .table
            .values()
            .filter(|m| m.alive)
            .count()
    }

    /// Monotonic counter of ring rebuilds — `/metrics` exposes it so
    /// convergence across fronts is observable.
    pub fn membership_version(&self) -> u64 {
        self.membership.lock().unwrap().version
    }

    /// Merge a remote member list (either side of a gossip exchange)
    /// into the local table, sync peer-health slots, and rebuild the
    /// ring if the alive set changed.
    ///
    /// Table mutation, slot sync, and ring rebuild all happen inside
    /// one membership critical section: two concurrent merges would
    /// otherwise interleave their slot updates out of order (e.g. a
    /// death's slot removal racing an earlier join's slot insertion,
    /// leaking a probed-forever slot for a tombstoned member).
    /// Stats and pool purges run after, outside the lock.
    pub fn apply_remote_members(&self, remote: &[MemberEntry]) {
        let mut st = self.membership.lock().unwrap();
        let mut self_inc = st.self_inc;
        let outcome = gossip::merge(
            &mut st.table,
            &self.cfg.advertise,
            &mut self_inc,
            remote,
        );
        st.self_inc = self_inc;
        if !outcome.added.is_empty()
            || !outcome.resurrected.is_empty()
            || !outcome.died.is_empty()
        {
            let mut peers = self.peers.lock().unwrap();
            // Health slots exist for routable members (and always for
            // static --peers, which may never gossip): joins and
            // gossip-driven resurrections get one; members imported
            // already-dead don't — they are not probed, they rejoin by
            // gossiping to us with a newer incarnation.
            for a in outcome.added.iter().chain(&outcome.resurrected) {
                if st.table.get(a).map(|m| m.alive).unwrap_or(false) {
                    let slot =
                        peers.entry(a.clone()).or_insert_with(PeerSlot::new);
                    // A resurrection claim clears the tombstone mirror
                    // and restarts the death clock — a static peer's
                    // slot survives its tombstone, and one
                    // stale-counter probe failure must not be able to
                    // re-tombstone a freshly rejoined member. (Routing
                    // health still waits for real probe successes
                    // before re-admission.)
                    slot.dead = false;
                    slot.consecutive_probe_failures = 0;
                }
            }
            for d in &outcome.died {
                sync_dead_slot(&mut peers, &self.cfg.peers, d);
            }
        }
        if outcome.ring_changed {
            self.rebuild_ring_locked(&mut st);
        }
        // Alive joins only — `added` also lists imported tombstones,
        // which are inherited history, not join events.
        let joined_addrs: Vec<&String> = outcome
            .added
            .iter()
            .filter(|a| st.table.get(*a).map(|m| m.alive).unwrap_or(false))
            .collect();
        drop(st);
        if !joined_addrs.is_empty() {
            self.stats
                .members_joined
                .fetch_add(joined_addrs.len() as u64, Ordering::Relaxed);
            for a in joined_addrs {
                log::info(
                    "cluster",
                    "member joined",
                    &[
                        ("peer", a.clone()),
                        ("node", self.cfg.advertise.clone()),
                    ],
                );
            }
        }
        if !outcome.resurrected.is_empty() {
            self.stats
                .members_resurrected
                .fetch_add(outcome.resurrected.len() as u64, Ordering::Relaxed);
        }
        if outcome.refuted {
            self.stats.gossip_refutations.fetch_add(1, Ordering::Relaxed);
            log::warn(
                "cluster",
                "refuted own death certificate",
                &[("node", self.cfg.advertise.clone())],
            );
        }
        if outcome.evicted_tombstones > 0 {
            self.stats
                .tombstone_evictions
                .fetch_add(outcome.evicted_tombstones, Ordering::Relaxed);
        }
        for d in &outcome.died {
            self.stats.members_died.fetch_add(1, Ordering::Relaxed);
            self.pool.purge(d);
            log::warn(
                "cluster",
                "member died (gossiped certificate)",
                &[("peer", d.clone()), ("node", self.cfg.advertise.clone())],
            );
        }
        self.merge_peer_loads(remote, &outcome.died);
    }

    /// Fold the load stanzas riding on a merged member list into the
    /// peer-load view (freshest version wins, see
    /// [`gossip::merge_loads`]), dropping reports for members that just
    /// died. The snapshot `Arc` is swapped only when something actually
    /// changed, so the p2c read path never sees churn from idle gossip.
    fn merge_peer_loads(&self, remote: &[MemberEntry], died: &[String]) {
        if died.is_empty() && remote.iter().all(|e| e.load.is_none()) {
            return;
        }
        let mut view = (**self.peer_loads.read().unwrap()).clone();
        let mut changed =
            gossip::merge_loads(&mut view, &self.cfg.advertise, remote);
        for d in died {
            changed |= view.remove(d).is_some();
        }
        if changed {
            *self.peer_loads.write().unwrap() = Arc::new(view);
        }
    }

    /// Merge remote hot-route replica claims (the other half of a
    /// gossip exchange). Lexicographic `(epoch, replicas)` max per
    /// route — see [`gossip::merge_route_claims`].
    pub fn apply_remote_routes(&self, remote: &[RouteOverride]) {
        if remote.is_empty() {
            return;
        }
        gossip::merge_route_claims(
            &mut self.route_claims.lock().unwrap(),
            remote,
        );
    }

    /// Current hot-route claims as wire entries (what we gossip out).
    pub fn route_overrides_wire(&self) -> Vec<RouteOverride> {
        self.route_claims
            .lock()
            .unwrap()
            .iter()
            .take(gossip::MAX_ROUTE_OVERRIDES)
            .map(|(r, c)| RouteOverride { route: r.clone(), claim: *c })
            .collect()
    }

    /// Snapshot of the hot-route claim table (metrics display).
    pub fn route_claims(&self) -> BTreeMap<String, RouteClaim> {
        self.route_claims.lock().unwrap().clone()
    }

    /// Rebuild the ring from the current alive-member set and swap it
    /// in, under the caller's membership lock. Holding the lock across
    /// the swap serializes rebuilds in version order — two concurrent
    /// rebuilds could otherwise install rings out of order, leaving
    /// routing permanently stale against the table. (No caller holds
    /// the ring lock while acquiring the membership lock, so the
    /// nesting cannot deadlock; the build itself is a few hundred hash
    /// points.)
    fn rebuild_ring_locked(&self, st: &mut MembershipState) {
        st.version += 1;
        let nodes: Vec<String> = st
            .table
            .iter()
            .filter(|(_, m)| m.alive)
            .map(|(a, _)| a.clone())
            .collect();
        let ring = Arc::new(HashRing::new(&nodes, self.cfg.virtual_nodes));
        *self.ring.write().unwrap() = ring;
    }

    /// Tombstone a member after sustained probe failure (the local
    /// node acts as the death certificate's origin).
    fn declare_dead(&self, addr: &str) {
        let mut st = self.membership.lock().unwrap();
        let changed = match st.table.get_mut(addr) {
            Some(m) if m.alive => {
                m.alive = false;
                true
            }
            _ => false,
        };
        if changed {
            sync_dead_slot(
                &mut self.peers.lock().unwrap(),
                &self.cfg.peers,
                addr,
            );
            self.rebuild_ring_locked(&mut st);
        }
        drop(st);
        if changed {
            self.stats.members_died.fetch_add(1, Ordering::Relaxed);
            self.pool.purge(addr);
            log::warn(
                "cluster",
                "member died (sustained probe failure)",
                &[
                    ("peer", addr.to_string()),
                    ("node", self.cfg.advertise.clone()),
                ],
            );
        }
    }

    /// Resurrect a tombstoned member on direct probe recovery. The
    /// incarnation is bumped past the death certificate so the
    /// resurrection wins merges everywhere — the prober acts as a
    /// proxy-refuter for peers that don't speak gossip themselves.
    fn resurrect(&self, addr: &str) {
        let mut st = self.membership.lock().unwrap();
        let changed = match st.table.get_mut(addr) {
            Some(m) if !m.alive => {
                m.alive = true;
                m.incarnation = m
                    .incarnation
                    .saturating_add(1)
                    .min(gossip::MAX_INCARNATION);
                true
            }
            Some(_) => false,
            None => {
                // The table entry was evicted (tombstone GC at the
                // table bound) while the probe slot survived: the peer
                // demonstrably answers at this address, so re-admit it
                // under a fresh wall-clock incarnation that outranks
                // any historical certificate.
                st.table.insert(
                    addr.to_string(),
                    Member { incarnation: now_millis(), alive: true },
                );
                true
            }
        };
        if changed {
            if let Some(s) = self.peers.lock().unwrap().get_mut(addr) {
                s.dead = false;
                s.consecutive_probe_failures = 0;
            }
            self.rebuild_ring_locked(&mut st);
        }
        drop(st);
        if changed {
            self.stats.members_resurrected.fetch_add(1, Ordering::Relaxed);
            log::info(
                "cluster",
                "member resurrected",
                &[
                    ("peer", addr.to_string()),
                    ("node", self.cfg.advertise.clone()),
                ],
            );
        }
    }

    // -- health -------------------------------------------------------

    /// Health of every known peer, name-sorted. Tombstoned members
    /// report `Down` regardless of their probe slot (they are not ring
    /// members, so they are categorically unroutable).
    pub fn peer_health(&self) -> BTreeMap<String, PeerHealth> {
        let dead: Vec<String> = {
            let st = self.membership.lock().unwrap();
            st.table
                .iter()
                .filter(|(_, m)| !m.alive)
                .map(|(a, _)| a.clone())
                .collect()
        };
        self.peers
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| {
                let h = if dead.contains(k) { PeerHealth::Down } else { v.health };
                (k.clone(), h)
            })
            .collect()
    }

    pub fn healthy_peers(&self) -> usize {
        self.peer_health()
            .values()
            .filter(|h| **h != PeerHealth::Down)
            .count()
    }

    /// One failed probe/proxy against `addr`. Reaching
    /// `failure_threshold` evicts the peer from routing. Death (the
    /// gossip tombstone) is driven only by the probe clock — see
    /// `PeerSlot::consecutive_probe_failures` — so proxy bursts can
    /// evict fast but never tombstone.
    pub fn record_failure(&self, addr: &str) {
        let newly_down = {
            let mut peers = self.peers.lock().unwrap();
            let Some(slot) = peers.get_mut(addr) else { return };
            slot.consecutive_successes = 0;
            slot.consecutive_failures =
                slot.consecutive_failures.saturating_add(1);
            let mut newly_down = false;
            if slot.health != PeerHealth::Down {
                if slot.consecutive_failures >= self.cfg.failure_threshold {
                    slot.health = PeerHealth::Down;
                    newly_down = true;
                } else {
                    slot.health = PeerHealth::Suspect;
                }
            }
            newly_down
        };
        if newly_down {
            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            // Idle connections to an evicted peer are dead weight.
            self.pool.purge(addr);
            log::warn(
                "cluster",
                "peer evicted from routing",
                &[
                    ("peer", addr.to_string()),
                    ("node", self.cfg.advertise.clone()),
                ],
            );
        }
    }

    /// One failed *probe round* against `addr`: the eviction
    /// accounting of [`Cluster::record_failure`] plus the death clock.
    /// Sustaining `failure_threshold * DEATH_FACTOR` consecutive
    /// failed probe rounds (≈ that many probe intervals) tombstones
    /// the member.
    fn record_probe_failure(&self, addr: &str) {
        self.record_failure(addr);
        let dead = {
            let mut peers = self.peers.lock().unwrap();
            let Some(slot) = peers.get_mut(addr) else { return };
            slot.consecutive_probe_failures =
                slot.consecutive_probe_failures.saturating_add(1);
            let death_threshold = self
                .cfg
                .failure_threshold
                .saturating_mul(gossip::DEATH_FACTOR);
            slot.consecutive_probe_failures >= death_threshold
        };
        if dead {
            self.declare_dead(addr);
        }
    }

    /// One successful probe/proxy against `addr`.
    pub fn record_success(&self, addr: &str) {
        let recovered = {
            let mut peers = self.peers.lock().unwrap();
            let Some(slot) = peers.get_mut(addr) else { return };
            slot.consecutive_failures = 0;
            slot.consecutive_probe_failures = 0;
            slot.consecutive_successes =
                slot.consecutive_successes.saturating_add(1);
            match slot.health {
                PeerHealth::Down => {
                    if slot.consecutive_successes >= self.cfg.recovery_threshold
                    {
                        slot.health = PeerHealth::Healthy;
                        self.stats.readmissions.fetch_add(1, Ordering::Relaxed);
                        log::info(
                            "cluster",
                            "peer readmitted to routing",
                            &[
                                ("peer", addr.to_string()),
                                ("node", self.cfg.advertise.clone()),
                            ],
                        );
                    }
                }
                PeerHealth::Suspect => slot.health = PeerHealth::Healthy,
                PeerHealth::Healthy => {}
            }
            // `dead` keeps the steady-state hot path (every successful
            // forward lands here) off the membership mutex: resurrect
            // is only consulted while THIS peer's member entry is a
            // tombstone, which the slot mirrors.
            slot.dead
                && slot.health == PeerHealth::Healthy
                && slot.consecutive_successes >= self.cfg.recovery_threshold
        };
        if recovered {
            self.resurrect(addr);
        }
    }

    // -- routing ------------------------------------------------------

    /// Map one ring node to a routable candidate — THE liveness filter,
    /// shared by every routing view so they cannot drift: this node is
    /// always `Local`; peers are skipped only when their health slot
    /// says `Down`; an unknown slot (transient ring/peer-table race) is
    /// treated optimistically. Tombstones never reach here: the ring
    /// holds only alive members.
    fn routable(
        &self,
        name: &str,
        peers: &BTreeMap<String, PeerSlot>,
    ) -> Option<Node> {
        if name == self.cfg.advertise {
            Some(Node::Local)
        } else {
            match peers.get(name) {
                Some(s) if s.health == PeerHealth::Down => None,
                _ => Some(Node::Peer(name.to_string())),
            }
        }
    }

    /// A key's effective replica count for a ring walk of `walk_len`
    /// nodes: the configured base, raised by any gossiped hot-route
    /// claim (claims never shrink below the base — a stale low claim
    /// must not undercut `--replicas`), clamped to the ring.
    fn effective_replicas_for(&self, key: &str, walk_len: usize) -> usize {
        let base = self.cfg.replicas;
        let claimed = self
            .route_claims
            .lock()
            .unwrap()
            .get(key)
            .map(|c| c.replicas as usize)
            .unwrap_or(base);
        claimed.max(base).min(walk_len)
    }

    /// The key's current effective replica count (base `--replicas`
    /// plus any hot-route expansion), clamped to the ring size.
    pub fn effective_replicas(&self, key: &str) -> usize {
        let n = self.ring().nodes().len();
        self.effective_replicas_for(key, n)
    }

    /// Candidate nodes for a key, in serving order, unroutable peers
    /// skipped. The first `effective_replicas` ring successors form
    /// the replica set: if this node is among them it serves locally
    /// (no hop beats any queue); otherwise the first candidate is
    /// picked by power-of-two-choices over the replicas whose load is
    /// known from gossip — two drawn deterministically from a splitmix
    /// ticket sequence, lower `(queue_depth, ewma_latency, ring
    /// order)` wins — falling back to the rotation cursor when fewer
    /// than two replicas have known load (mixed-version clusters, cold
    /// start) or `load_adaptive` is off. The remaining ring walk
    /// follows as the failover tail, so the list always ends in
    /// workable fallbacks (and always contains `Local` — this node is
    /// an alive ring member).
    pub fn candidates(&self, key: &str) -> Vec<Node> {
        let ring = self.ring();
        let walk = ring.successors(key);
        if walk.is_empty() {
            return vec![Node::Local];
        }
        let r = self.effective_replicas_for(key, walk.len());
        let peers = self.peers.lock().unwrap();
        let mut reps: Vec<Node> = walk[..r]
            .iter()
            .filter_map(|&n| self.routable(n, &peers))
            .collect();
        let tail: Vec<Node> = walk[r..]
            .iter()
            .filter_map(|&n| self.routable(n, &peers))
            .collect();
        drop(peers);
        if let Some(pos) = reps.iter().position(|n| *n == Node::Local) {
            reps.rotate_left(pos);
            self.stats.p2c_local_picks.fetch_add(1, Ordering::Relaxed);
        } else if reps.len() > 1 && !self.p2c_select(&mut reps) {
            let i = self.replica_cursor.fetch_add(1, Ordering::Relaxed)
                % reps.len();
            reps.rotate_left(i);
            self.stats.p2c_rotation_picks.fetch_add(1, Ordering::Relaxed);
        }
        reps.extend(tail);
        if reps.is_empty() {
            reps.push(Node::Local);
        }
        reps
    }

    /// Power-of-two-choices over the all-remote replica list: draw two
    /// distinct replicas from those with gossip-known load and move
    /// the less loaded one to the front. Returns `false` (caller
    /// rotates instead) when fewer than two loads are known — peers
    /// with unknown load are *excluded from the draw*, never guessed
    /// at. The draw runs off an atomic ticket through splitmix, so a
    /// single-threaded sim driver replays the exact choice sequence.
    fn p2c_select(&self, reps: &mut Vec<Node>) -> bool {
        if !self.cfg.load_adaptive {
            return false;
        }
        let loads = self.peer_loads.read().unwrap().clone();
        let known: Vec<(usize, LoadInfo)> = reps
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n {
                Node::Peer(p) => loads.get(p).map(|l| (i, *l)),
                Node::Local => None,
            })
            .collect();
        if known.len() < 2 {
            return false;
        }
        let ticket = self.p2c_ticket.fetch_add(1, Ordering::Relaxed);
        let mut draw = SplitMix64::new(ticket);
        let a = draw.below(known.len() as u64) as usize;
        let mut b = draw.below(known.len() as u64 - 1) as usize;
        if b >= a {
            b += 1;
        }
        let rank = |(i, l): &(usize, LoadInfo)| {
            (l.queue_depth, l.ewma_latency_us, *i)
        };
        let chosen = if rank(&known[a]) <= rank(&known[b]) { a } else { b };
        let (rep_idx, load) = known[chosen];
        self.stats.p2c_depth_hist.observe(load.queue_depth);
        self.stats.p2c_load_picks.fetch_add(1, Ordering::Relaxed);
        let node = reps.remove(rep_idx);
        reps.insert(0, node);
        true
    }

    /// The live replica set for a key (first `effective_replicas`
    /// ring successors, unroutable ones dropped, `Local` first when
    /// present). The `/v1/batch` fan-out splits across exactly this —
    /// a hot-route expansion widens the fan-out automatically.
    pub fn live_replicas(&self, key: &str) -> Vec<Node> {
        let ring = self.ring();
        let walk = ring.successors(key);
        let r = self.effective_replicas_for(key, walk.len());
        let peers = self.peers.lock().unwrap();
        let mut reps: Vec<Node> = walk[..r]
            .iter()
            .filter_map(|&n| self.routable(n, &peers))
            .collect();
        if let Some(pos) = reps.iter().position(|n| *n == Node::Local) {
            reps.rotate_left(pos);
        }
        reps
    }

    /// The key's primary replica set ignoring liveness (`/v1/models`
    /// display). Reflects hot-route expansions.
    pub fn replica_set(&self, key: &str) -> Vec<String> {
        let ring = self.ring();
        let walk = ring.successors(key);
        let r = self.effective_replicas_for(key, walk.len());
        walk[..r].iter().map(|n| n.to_string()).collect()
    }

    /// The node currently routed to first for `key` (liveness applied,
    /// no read rotation — stable for display).
    pub fn owner_name(&self, key: &str) -> Option<String> {
        let ring = self.ring();
        let walk = ring.successors(key);
        let peers = self.peers.lock().unwrap();
        walk.iter()
            .find_map(|&n| self.routable(n, &peers))
            .map(|node| match node {
                Node::Local => self.cfg.advertise.clone(),
                Node::Peer(p) => p,
            })
    }

    // -- hot-route controller -----------------------------------------

    /// Count one client-facing request for `route` toward the
    /// hot-route controller. Proxied-in requests are *not* counted:
    /// client arrivals at a front are a replica-layout-independent
    /// popularity signal (loadgen and real clients spread connections
    /// across fronts), whereas counting forwarded traffic would make
    /// the signal collapse as soon as an expansion spreads the load —
    /// a feedback loop that re-shrinks hot routes. Bounded by
    /// [`MAX_TRACKED_ROUTES`]; untracked names still route normally.
    pub fn note_route_request(&self, route: &str) {
        let mut traffic = self.route_traffic.lock().unwrap();
        match traffic.get_mut(route) {
            Some(rt) => rt.count += 1,
            None if traffic.len() < MAX_TRACKED_ROUTES => {
                traffic.insert(
                    route.to_string(),
                    RouteTraffic { count: 1, ..RouteTraffic::default() },
                );
            }
            None => {}
        }
    }

    /// One hot-route controller round: fold each tracked route's
    /// request count into its rate EWMA, then — only for routes this
    /// node currently owns (one steward per route; concurrent
    /// partition-side stewards still converge via the claim
    /// semilattice) — raise the effective replica count when the EWMA
    /// is at/above [`HOT_EXPAND_PER_ROUND`] and lower it back toward
    /// the base at/below [`HOT_SHRINK_PER_ROUND`], at most one
    /// transition per [`HOT_COOLDOWN_ROUNDS`] per route. Runs as part
    /// of [`Cluster::membership_round`] so new claims ride the very
    /// next gossip exchange.
    pub fn hot_route_round(&self) {
        let round = self.controller_rounds.fetch_add(1, Ordering::Relaxed) + 1;
        let ring_size = self.ring().nodes().len();
        let base = self.cfg.replicas;
        let mut transitions: Vec<(String, RouteClaim, bool)> = Vec::new();
        {
            let mut traffic = self.route_traffic.lock().unwrap();
            for (route, rt) in traffic.iter_mut() {
                let sample_x16 = rt.count << 4;
                rt.count = 0;
                rt.ewma_x16 = rt.ewma_x16 - (rt.ewma_x16 >> ROUTE_EWMA_SHIFT)
                    + (sample_x16 >> ROUTE_EWMA_SHIFT);
                if !self.cfg.load_adaptive {
                    continue;
                }
                if round.saturating_sub(rt.last_transition_round)
                    < HOT_COOLDOWN_ROUNDS
                {
                    continue;
                }
                if self.owner_name(route).as_deref()
                    != Some(self.cfg.advertise.as_str())
                {
                    continue;
                }
                let claim = self
                    .route_claims
                    .lock()
                    .unwrap()
                    .get(route)
                    .copied()
                    .unwrap_or_default();
                let cur =
                    (claim.replicas as usize).max(base).min(ring_size.max(1));
                let ewma = rt.ewma_x16 >> 4;
                let next = if ewma >= HOT_EXPAND_PER_ROUND && cur < ring_size
                {
                    Some((cur + 1, true))
                } else if ewma <= HOT_SHRINK_PER_ROUND && cur > base {
                    Some((cur - 1, false))
                } else {
                    None
                };
                if let Some((replicas, expand)) = next {
                    rt.last_transition_round = round;
                    transitions.push((
                        route.clone(),
                        RouteClaim {
                            epoch: claim
                                .epoch
                                .saturating_add(1)
                                .min(gossip::MAX_INCARNATION),
                            replicas: replicas as u64,
                        },
                        expand,
                    ));
                }
            }
        }
        for (route, claim, expand) in transitions {
            gossip::merge_route_claims(
                &mut self.route_claims.lock().unwrap(),
                &[RouteOverride { route: route.clone(), claim }],
            );
            let counter = if expand {
                &self.stats.route_expansions
            } else {
                &self.stats.route_shrinks
            };
            counter.fetch_add(1, Ordering::Relaxed);
            log::info(
                "cluster",
                if expand { "hot route expanded" } else { "hot route shrunk" },
                &[
                    ("route", route),
                    ("replicas", claim.replicas.to_string()),
                    ("epoch", claim.epoch.to_string()),
                    ("node", self.cfg.advertise.clone()),
                ],
            );
        }
    }

    // -- client legs (pooled) -----------------------------------------

    /// Forward a decoded request body to a peer and return its
    /// response. Transport failures are `Err` (the caller records them
    /// and fails over); HTTP-level errors pass through as responses.
    /// `extra_headers` ride along after the proxy loop-guard tag (the
    /// trace-propagation header travels here).
    pub fn forward(
        &self,
        addr: &str,
        path: &str,
        body: &[u8],
        extra_headers: &[(&str, &str)],
    ) -> Result<Response, String> {
        let mut headers: Vec<(&str, &str)> = vec![(PROXIED_HEADER, "1")];
        headers.extend_from_slice(extra_headers);
        self.request(
            addr,
            "POST",
            path,
            &headers,
            body,
            &Deadlines::uniform(self.cfg.proxy_timeout),
            MAX_PROXY_BODY,
        )
    }

    /// One pooled HTTP round trip with discard-and-redial: a failure
    /// on a *reused* connection (the peer may have closed it while
    /// idle) is retried exactly once on a fresh dial; a fresh dial's
    /// failure is a real transport error.
    #[allow(clippy::too_many_arguments)]
    fn request(
        &self,
        addr: &str,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
        deadlines: &Deadlines,
        max_body: usize,
    ) -> Result<Response, String> {
        let mut checked = self.pool.checkout(addr, deadlines)?;
        // Transport errors carry a retryable flag (see
        // [`super::transport::TransportError`]): a send failure or a
        // connection the peer closed/reset before answering is the
        // stale-keep-alive signature and safe to redial; a *timeout*
        // means the request may be executing on the peer right now —
        // re-sending it would double-execute (and double the latency
        // bound), so it is surfaced as the failure it is.
        let attempt = |c: &mut super::pool::Checked| {
            c.conn
                .send(method, path, headers, body)
                .map_err(|e| (e.retryable, format!("send to {addr}: {}", e.msg)))?;
            c.conn.recv(max_body).map_err(|e| {
                (e.retryable, format!("response from {addr}: {}", e.msg))
            })
        };
        let (status, resp_headers, resp_body) = match attempt(&mut checked) {
            Ok(r) => r,
            Err((retryable, _)) if checked.reused && retryable => {
                self.pool.note_discard();
                checked = self.pool.dial_fresh(addr, deadlines)?;
                attempt(&mut checked).map_err(|(_, msg)| msg)?
            }
            Err((_, msg)) => {
                // The connection is in an unknown state; it is dropped,
                // not pooled — keep the discard counter honest.
                self.pool.note_discard();
                return Err(msg);
            }
        };
        let keep = resp_headers
            .get("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true);
        if keep {
            self.pool.check_in(addr, checked.conn);
        } else {
            self.pool.note_discard();
        }
        let content_type = resp_headers
            .get("content-type")
            .cloned()
            .unwrap_or_else(|| "application/json".into());
        // Peer response headers (including its trace echo) are not
        // propagated: the receiving dispatch stamps its own trace
        // header on whatever it returns.
        Ok(Response {
            status,
            content_type,
            body: resp_body,
            headers: Vec::new(),
        })
    }

    /// One liveness probe: `GET /health` must answer 200 within the
    /// budget (shares the connection pool with the proxy path).
    fn probe_peer(&self, addr: &str) -> bool {
        matches!(
            self.request(
                addr,
                "GET",
                "/health",
                &[],
                b"",
                &Deadlines::uniform(self.cfg.probe_timeout),
                MAX_CONTROL_BODY,
            ),
            Ok(resp) if resp.status == 200
        )
    }

    /// Per-leg budgets for one gossip exchange: connect, write, and
    /// read each get a third of the whole-exchange budget, which is
    /// capped at one seed-backoff period (two probe intervals — the
    /// shortest retry delay [`Cluster::gossip_round`] hands a failing
    /// seed). A stalled/blackholed `--join` seed therefore costs the
    /// shared membership thread at most one backoff period per
    /// attempt, instead of up to three full probe timeouts.
    fn gossip_deadlines(&self) -> Deadlines {
        let budget =
            (self.cfg.probe_interval * 2).min(self.cfg.probe_timeout * 3);
        let leg = (budget / 3).min(self.cfg.probe_timeout);
        Deadlines::split(leg, leg, leg)
    }

    /// One gossip exchange with `addr`: send the local table (load
    /// stanzas and hot-route claims riding along), merge whatever
    /// comes back.
    pub fn gossip_with(&self, addr: &str) -> bool {
        let body = json::write(&gossip::encode(
            self.self_name(),
            &self.member_entries(),
            &self.route_overrides_wire(),
        ));
        let resp = self.request(
            addr,
            "POST",
            gossip::GOSSIP_PATH,
            &[],
            body.as_bytes(),
            &self.gossip_deadlines(),
            MAX_CONTROL_BODY,
        );
        let ok = match resp {
            Ok(resp) if resp.status == 200 => {
                let text = String::from_utf8_lossy(&resp.body).into_owned();
                match json::parse(&text).map_err(|e| e.to_string()).and_then(
                    |v| gossip::decode(&v),
                ) {
                    Ok(msg) => {
                        self.apply_remote_members(&msg.members);
                        self.apply_remote_routes(&msg.routes);
                        true
                    }
                    Err(_) => false,
                }
            }
            _ => false,
        };
        let counter =
            if ok { &self.stats.gossip_ok } else { &self.stats.gossip_fail };
        counter.fetch_add(1, Ordering::Relaxed);
        ok
    }

    /// One probe pass over every known peer — including evicted and
    /// tombstoned ones, which is the re-admission/resurrection path.
    /// Proxy traffic feeds the same accounting between rounds. Public
    /// so deterministic drivers (the sim harness, with
    /// [`ClusterConfig::manual_rounds`]) can step it without a thread.
    pub fn probe_round(&self) {
        let addrs: Vec<String> =
            self.peers.lock().unwrap().keys().cloned().collect();
        for addr in addrs {
            if self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if self.probe_peer(&addr) {
                self.record_success(&addr);
            } else {
                self.record_probe_failure(&addr);
            }
        }
    }

    /// One gossip pass: every `--join` seed that is not currently an
    /// alive member, plus one alive member round-robin. Tombstoned
    /// seeds stay targeted — ordinary gossip only reaches alive
    /// members, so a restarted seed (which initiates nothing itself)
    /// would otherwise be permanently unreachable and the cluster
    /// would split-brain; the retry cost is bounded by the configured
    /// join list.
    pub fn gossip_round(&self) {
        let started = Instant::now();
        self.gossip_round_inner();
        self.stats.gossip_round_hist.observe(started.elapsed());
    }

    fn gossip_round_inner(&self) {
        let round = self.gossip_rounds.fetch_add(1, Ordering::Relaxed);
        // One membership snapshot for both target lists, so they can't
        // disagree about a concurrently merged member.
        let (mut targets, live): (Vec<String>, Vec<String>) = {
            let st = self.membership.lock().unwrap();
            let targets = self
                .cfg
                .join
                .iter()
                .filter(|s| {
                    st.table.get(*s).map(|m| !m.alive).unwrap_or(true)
                })
                .cloned()
                .collect();
            let live = st
                .table
                .iter()
                .filter(|(a, m)| m.alive && a.as_str() != self.cfg.advertise)
                .map(|(a, _)| a.clone())
                .collect();
            (targets, live)
        };
        // Failing seeds are retried on an exponential schedule (2..32
        // rounds) rather than every round: each attempt can block the
        // shared membership thread for a full connect timeout.
        {
            let backoff = self.seed_backoff.lock().unwrap();
            targets.retain(|t| {
                backoff.get(t).map(|&(_, at)| round >= at).unwrap_or(true)
            });
        }
        if !live.is_empty() {
            let i = self.gossip_cursor.fetch_add(1, Ordering::Relaxed)
                % live.len();
            if !targets.contains(&live[i]) {
                targets.push(live[i].clone());
            }
        }
        for t in targets {
            if self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let ok = self.gossip_with(&t);
            if self.cfg.join.contains(&t) {
                let mut backoff = self.seed_backoff.lock().unwrap();
                if ok {
                    backoff.remove(&t);
                } else {
                    let fails = backoff
                        .get(&t)
                        .map(|&(f, _)| f)
                        .unwrap_or(0)
                        .saturating_add(1);
                    let delay = 1u64 << fails.min(5);
                    backoff.insert(t.clone(), (fails, round + delay));
                }
            }
        }
    }

    /// One full membership round: probe health, run the hot-route
    /// controller (so a fresh claim rides this round's gossip), then
    /// gossip. The membership thread calls this every
    /// `probe_interval`; with [`ClusterConfig::manual_rounds`] a
    /// deterministic driver calls it instead.
    pub fn membership_round(&self) {
        self.probe_round();
        if self.shutdown.load(Ordering::SeqCst) {
            return;
        }
        self.hot_route_round();
        self.gossip_round();
    }
}

/// Sync a member's death onto its probe slot (caller holds the peers
/// lock, nested under the membership lock). Static `--peers` entries
/// keep their slot with the tombstone mirrored onto it — they may not
/// speak gossip, so the prober stays their only resurrection path.
/// Gossip-learned members lose the slot entirely: they rejoin by
/// announcing a newer incarnation themselves, and probing every
/// departed node forever would let the probe round grow without bound
/// as departures accumulate.
fn sync_dead_slot(
    peers: &mut BTreeMap<String, PeerSlot>,
    static_peers: &[String],
    addr: &str,
) {
    if static_peers.iter().any(|p| p == addr) {
        if let Some(s) = peers.get_mut(addr) {
            s.dead = true;
        }
    } else {
        peers.remove(addr);
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.stop();
    }
}

/// An in-flight outbound-forward slot; dropping it frees the slot.
pub struct ForwardPermit<'a>(&'a Cluster);

impl Drop for ForwardPermit<'_> {
    fn drop(&mut self) {
        self.0.inflight_forwards.fetch_sub(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:8787")).collect()
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn ring_hash_decorrelates_sequential_labels() {
        // The finalizer must spread `addr#0..addr#n` labels evenly:
        // check the top byte of consecutive vnode labels is not
        // constant (raw FNV-1a fails this badly — its low-byte change
        // barely reaches the high bits for short strings).
        let mut top_bytes = std::collections::BTreeSet::new();
        for v in 0..64 {
            top_bytes.insert((hash64(format!("10.0.0.1:8787#{v}").as_bytes())
                >> 56) as u8);
        }
        assert!(
            top_bytes.len() > 32,
            "only {} distinct top bytes over 64 labels",
            top_bytes.len()
        );
    }

    #[test]
    fn ring_is_deterministic_and_covers_all_nodes() {
        let nodes = names(3);
        let a = HashRing::new(&nodes, 64);
        let b = HashRing::new(&nodes, 64);
        for key in ["s3_12", "s3_5", "s2_8", "model-x"] {
            assert_eq!(a.owner(key), b.owner(key));
            let succ = a.successors(key);
            assert_eq!(succ.len(), 3, "{key}: {succ:?}");
            let mut sorted: Vec<&str> = succ.clone();
            sorted.sort_unstable();
            let want: Vec<&str> =
                nodes.iter().map(String::as_str).collect();
            assert_eq!(sorted, want, "{key}");
        }
        // Node order in input must not matter.
        let mut shuffled = nodes.clone();
        shuffled.reverse();
        let c = HashRing::new(&shuffled, 64);
        assert_eq!(a.owner("s3_12"), c.owner("s3_12"));
    }

    #[test]
    fn ring_spreads_keys_roughly_evenly() {
        let ring = HashRing::new(&names(4), 64);
        let mut counts = BTreeMap::new();
        for i in 0..4000 {
            let k = format!("model-{i}");
            *counts.entry(ring.owner(&k).unwrap().to_string()).or_insert(0) +=
                1;
        }
        assert_eq!(counts.len(), 4);
        for (node, c) in &counts {
            // 1000 expected; virtual nodes keep the spread sane.
            assert!(
                (400..=1800).contains(c),
                "{node} owns {c} of 4000 keys: {counts:?}"
            );
        }
    }

    #[test]
    fn dead_node_moves_only_its_own_keys() {
        // The rebalance bound: with one node excluded, every key owned
        // by a surviving node keeps its owner; only the dead node's
        // keys move (to their ring successor).
        let nodes = names(3);
        let ring = HashRing::new(&nodes, 64);
        let dead = ring.owner("pick-a-victim").unwrap().to_string();
        let total = 3000usize;
        let mut moved = 0usize;
        for i in 0..total {
            let k = format!("model-{i}");
            let succ = ring.successors(&k);
            let before = succ[0];
            let after = *succ
                .iter()
                .find(|&&n| n != dead.as_str())
                .expect("two nodes survive");
            if before == dead {
                moved += 1;
                // Inherited by the immediate successor, nothing else.
                assert_eq!(after, succ[1], "{k}");
            } else {
                assert_eq!(before, after, "{k}: key moved off a live node");
            }
        }
        let frac = moved as f64 / total as f64;
        // Expected share 1/3; allow ring-slack for the hash spread.
        assert!(
            frac > 0.15 && frac < 1.0 / 3.0 + 0.15,
            "moved fraction {frac}"
        );
    }

    #[test]
    fn single_node_ring_owns_everything() {
        let ring = HashRing::new(&names(1), 8);
        assert_eq!(ring.owner("anything"), Some("10.0.0.0:8787"));
        assert!(HashRing::new(&[], 8).owner("x").is_none());
    }

    fn test_cluster(peers: usize) -> Arc<Cluster> {
        Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            // Unroutable peers; the prober is effectively a no-op
            // within the test runtime because probe_interval is long.
            peers: (0..peers).map(|i| format!("127.0.0.1:{}", 2 + i)).collect(),
            probe_interval: Duration::from_secs(3600),
            probe_timeout: Duration::from_millis(10),
            failure_threshold: 2,
            recovery_threshold: 2,
            incarnation: Some(100),
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn eviction_and_readmission_state_machine() {
        let c = test_cluster(2);
        let peer = "127.0.0.1:2";
        assert_eq!(c.peer_health()[peer], PeerHealth::Healthy);
        c.record_failure(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Suspect);
        // A success below the eviction threshold heals immediately.
        c.record_success(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Healthy);
        // Two consecutive failures evict.
        c.record_failure(peer);
        c.record_failure(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Down);
        assert_eq!(c.stats.evictions.load(Ordering::Relaxed), 1);
        assert_eq!(c.healthy_peers(), 1);
        // Re-admission needs recovery_threshold consecutive successes.
        c.record_success(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Down);
        c.record_success(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Healthy);
        assert_eq!(c.stats.readmissions.load(Ordering::Relaxed), 1);
        c.stop();
    }

    #[test]
    fn sustained_failure_tombstones_and_recovery_resurrects() {
        let c = test_cluster(2);
        let peer = "127.0.0.1:2";
        assert_eq!(c.alive_members(), 3);
        let v0 = c.membership_version();
        // Proxy-path failures alone must NEVER tombstone, no matter
        // how many arrive (they come at request rate).
        for _ in 0..(4 * gossip::DEATH_FACTOR) {
            c.record_failure(peer);
        }
        assert_eq!(c.alive_members(), 3, "proxy failures tombstoned");
        assert_eq!(c.peer_health()[peer], PeerHealth::Down, "but do evict");
        // failure_threshold (2) x DEATH_FACTOR consecutive failed
        // probe rounds: that is the death clock.
        for _ in 0..(2 * gossip::DEATH_FACTOR) {
            c.record_probe_failure(peer);
        }
        assert_eq!(c.alive_members(), 2, "member not tombstoned");
        assert!(!c.members()[peer].alive);
        assert_eq!(c.stats.members_died.load(Ordering::Relaxed), 1);
        assert!(c.membership_version() > v0);
        let inc_dead = c.members()[peer].incarnation;
        // The ring no longer contains the tombstone.
        assert!(!c.ring().nodes().contains(&peer.to_string()));
        // Direct probe recovery resurrects with a bumped incarnation.
        c.record_success(peer);
        c.record_success(peer);
        assert_eq!(c.alive_members(), 3, "member not resurrected");
        assert_eq!(c.members()[peer].incarnation, inc_dead + 1);
        assert_eq!(c.stats.members_resurrected.load(Ordering::Relaxed), 1);
        assert!(c.ring().nodes().contains(&peer.to_string()));
        c.stop();
    }

    #[test]
    fn gossip_merge_adds_members_and_rebuilds_ring() {
        let c = test_cluster(1);
        assert_eq!(c.ring().nodes().len(), 2);
        c.apply_remote_members(&[MemberEntry {
            addr: "127.0.0.1:77".into(),
            incarnation: 9,
            alive: true,
            load: None,
        }]);
        assert_eq!(c.alive_members(), 3);
        assert_eq!(c.ring().nodes().len(), 3);
        assert_eq!(c.stats.members_joined.load(Ordering::Relaxed), 1);
        // The new member gets a health slot (so the prober covers it).
        assert!(c.peer_health().contains_key("127.0.0.1:77"));
        // A death certificate tombstones it again — and, since it is
        // gossip-learned (not a static --peers entry), its probe slot
        // is dropped: departed dynamic members must not be probed
        // forever.
        c.apply_remote_members(&[MemberEntry {
            addr: "127.0.0.1:77".into(),
            incarnation: 9,
            alive: false,
            load: None,
        }]);
        assert_eq!(c.alive_members(), 2);
        assert!(!c.peer_health().contains_key("127.0.0.1:77"));
        // A restart (newer incarnation, alive) re-adds both the ring
        // entry and the probe slot, and counts as a resurrection.
        c.apply_remote_members(&[MemberEntry {
            addr: "127.0.0.1:77".into(),
            incarnation: 10,
            alive: true,
            load: None,
        }]);
        assert_eq!(c.alive_members(), 3);
        assert!(c.peer_health().contains_key("127.0.0.1:77"));
        assert_eq!(c.stats.members_resurrected.load(Ordering::Relaxed), 1);
        c.stop();
    }

    #[test]
    fn self_death_report_is_refuted() {
        let c = test_cluster(1);
        c.apply_remote_members(&[MemberEntry {
            addr: "127.0.0.1:1".into(),
            incarnation: 500,
            alive: false,
            load: None,
        }]);
        let m = c.members();
        assert!(m["127.0.0.1:1"].alive, "self must refute its own death");
        assert_eq!(m["127.0.0.1:1"].incarnation, 501);
        assert_eq!(c.alive_members(), 2);
        c.stop();
    }

    #[test]
    fn candidates_skip_evicted_peers() {
        let c = test_cluster(2);
        // Find a key owned by a peer.
        let key = (0..200)
            .map(|i| format!("m{i}"))
            .find(|k| {
                matches!(
                    c.candidates(k).first(),
                    Some(Node::Peer(_))
                )
            })
            .expect("some key lands on a peer");
        let Some(Node::Peer(owner)) = c.candidates(&key).first().cloned()
        else {
            unreachable!()
        };
        // Evict the owner: the key must remap to a surviving node and
        // the candidate list must shrink by exactly one.
        let before = c.candidates(&key);
        assert_eq!(before.len(), 3);
        c.record_failure(&owner);
        c.record_failure(&owner);
        let after = c.candidates(&key);
        assert_eq!(after.len(), 2);
        assert_ne!(after.first(), Some(&Node::Peer(owner.clone())));
        // And the new order is the old order with the owner removed —
        // only the dead node's keys moved.
        let filtered: Vec<Node> = before
            .into_iter()
            .filter(|n| *n != Node::Peer(owner.clone()))
            .collect();
        assert_eq!(after, filtered);
        c.stop();
    }

    #[test]
    fn replicas_rotate_reads_and_keep_local_first() {
        let c = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            peers: vec!["127.0.0.1:2".into(), "127.0.0.1:3".into()],
            replicas: 2,
            probe_interval: Duration::from_secs(3600),
            incarnation: Some(100),
            ..Default::default()
        })
        .unwrap();
        // Across many keys: every candidate list has all 3 nodes
        // (replica set + failover tail) and the replica set is the
        // first 2 ring successors.
        for i in 0..50 {
            let k = format!("m{i}");
            let cands = c.candidates(&k);
            assert_eq!(cands.len(), 3, "{k}: {cands:?}");
            let reps = c.replica_set(&k);
            assert_eq!(reps.len(), 2);
            // live_replicas is the liveness-filtered replica set with
            // Local first when this node is a replica.
            let live = c.live_replicas(&k);
            assert_eq!(live.len(), 2);
            if reps.contains(&"127.0.0.1:1".to_string()) {
                assert_eq!(live[0], Node::Local, "{k}");
                assert_eq!(cands[0], Node::Local, "{k}");
            }
        }
        // For a key whose replica set excludes Local, reads rotate
        // across the two replicas.
        let remote_key = (0..200)
            .map(|i| format!("r{i}"))
            .find(|k| !c.replica_set(k).contains(&"127.0.0.1:1".to_string()))
            .expect("some key has a fully remote replica set");
        let firsts: std::collections::BTreeSet<String> = (0..8)
            .filter_map(|_| match c.candidates(&remote_key).first() {
                Some(Node::Peer(p)) => Some(p.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(
            firsts.len(),
            2,
            "read rotation must alternate replicas: {firsts:?}"
        );
        c.stop();
    }

    #[test]
    fn rejects_self_in_peer_list_and_empty_advertise() {
        let err = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            peers: vec!["127.0.0.1:1".into()],
            ..Default::default()
        })
        .unwrap_err();
        assert!(err.contains("itself"), "{err}");
        let err = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            join: vec!["127.0.0.1:1".into()],
            ..Default::default()
        })
        .unwrap_err();
        assert!(err.contains("itself"), "{err}");
        assert!(Cluster::start(ClusterConfig::default()).is_err());
        let err = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            replicas: 0,
            ..Default::default()
        })
        .unwrap_err();
        assert!(err.contains("replicas"), "{err}");
    }

    #[test]
    fn forward_permits_bound_concurrency_and_release_on_drop() {
        let c = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            peers: vec!["127.0.0.1:2".into()],
            probe_interval: Duration::from_secs(3600),
            max_inflight_forwards: 2,
            ..Default::default()
        })
        .unwrap();
        let p1 = c.try_forward_permit().expect("first permit");
        let p2 = c.try_forward_permit().expect("second permit");
        assert!(
            c.try_forward_permit().is_none(),
            "bound of 2 must shed the third forward"
        );
        drop(p1);
        let p3 = c.try_forward_permit().expect("slot freed on drop");
        drop(p2);
        drop(p3);
        assert_eq!(c.inflight_forwards.load(Ordering::Relaxed), 0);
        c.stop();
    }

    #[test]
    fn default_permit_bound_is_unbounded_for_direct_users() {
        // max_inflight_forwards = 0 means "auto": direct Cluster users
        // get effectively unbounded permits (the HTTP server
        // substitutes workers/2 before starting).
        let c = test_cluster(1);
        let _a = c.try_forward_permit().expect("permit");
        let _b = c.try_forward_permit().expect("permit");
        c.stop();
    }

    #[test]
    fn unknown_peer_records_are_ignored() {
        let c = test_cluster(1);
        c.record_failure("127.0.0.1:999");
        c.record_success("127.0.0.1:999");
        assert_eq!(c.peer_health().len(), 1);
        c.stop();
    }

    fn loaded_entry(addr: &str, version: u64, queue: u64) -> MemberEntry {
        MemberEntry {
            addr: addr.into(),
            incarnation: 50,
            alive: true,
            load: Some(LoadInfo {
                version,
                queue_depth: queue,
                ewma_latency_us: queue,
                arena_bytes: 0,
            }),
        }
    }

    /// A 4-node view (self + 3 peers) where some keys have fully
    /// remote replica sets — the p2c arena.
    fn p2c_cluster() -> Arc<Cluster> {
        Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            peers: vec![
                "127.0.0.1:2".into(),
                "127.0.0.1:3".into(),
                "127.0.0.1:4".into(),
            ],
            replicas: 2,
            probe_interval: Duration::from_secs(3600),
            incarnation: Some(100),
            ..Default::default()
        })
        .unwrap()
    }

    fn remote_key(c: &Cluster) -> String {
        (0..500)
            .map(|i| format!("k{i}"))
            .find(|k| !c.replica_set(k).contains(&"127.0.0.1:1".to_string()))
            .expect("some key has a fully remote replica set")
    }

    #[test]
    fn p2c_prefers_the_less_loaded_replica() {
        let c = p2c_cluster();
        let key = remote_key(&c);
        let reps = c.replica_set(&key);
        // Load the first replica heavily, keep the second idle.
        c.apply_remote_members(&[
            loaded_entry(&reps[0], 1, 50),
            loaded_entry(&reps[1], 1, 0),
        ]);
        for _ in 0..32 {
            let first = c.candidates(&key)[0].clone();
            assert_eq!(
                first,
                Node::Peer(reps[1].clone()),
                "p2c must always land on the idle replica"
            );
        }
        assert!(c.stats.p2c_load_picks.load(Ordering::Relaxed) >= 32);
        assert_eq!(c.stats.p2c_rotation_picks.load(Ordering::Relaxed), 0);
        // Flip the load: the pick follows.
        c.apply_remote_members(&[
            loaded_entry(&reps[0], 2, 0),
            loaded_entry(&reps[1], 2, 50),
        ]);
        assert_eq!(c.candidates(&key)[0], Node::Peer(reps[0].clone()));
        c.stop();
    }

    #[test]
    fn p2c_excludes_unknown_load_and_falls_back_to_rotation() {
        let c = p2c_cluster();
        let key = remote_key(&c);
        let reps = c.replica_set(&key);
        // Only one replica has known load: below the two-candidate
        // minimum, so selection must fall back to rotation (the known
        // load must NOT dogpile the one reporting peer).
        c.apply_remote_members(&[loaded_entry(&reps[0], 1, 0)]);
        let firsts: std::collections::BTreeSet<String> = (0..8)
            .filter_map(|_| match c.candidates(&key).first() {
                Some(Node::Peer(p)) => Some(p.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(firsts.len(), 2, "rotation must still alternate");
        assert_eq!(c.stats.p2c_load_picks.load(Ordering::Relaxed), 0);
        assert!(c.stats.p2c_rotation_picks.load(Ordering::Relaxed) >= 8);
        c.stop();
    }

    #[test]
    fn load_adaptive_off_is_the_frozen_baseline() {
        let c = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            peers: vec![
                "127.0.0.1:2".into(),
                "127.0.0.1:3".into(),
                "127.0.0.1:4".into(),
            ],
            replicas: 2,
            probe_interval: Duration::from_secs(3600),
            incarnation: Some(100),
            load_adaptive: false,
            ..Default::default()
        })
        .unwrap();
        let key = remote_key(&c);
        let reps = c.replica_set(&key);
        c.apply_remote_members(&[
            loaded_entry(&reps[0], 1, 50),
            loaded_entry(&reps[1], 1, 0),
        ]);
        for _ in 0..8 {
            c.candidates(&key);
        }
        assert_eq!(c.stats.p2c_load_picks.load(Ordering::Relaxed), 0);
        // And the controller never moves replica counts.
        for _ in 0..10 {
            for _ in 0..100 {
                c.note_route_request(&key);
            }
            c.hot_route_round();
        }
        assert_eq!(c.effective_replicas(&key), 2);
        assert_eq!(c.stats.route_expansions.load(Ordering::Relaxed), 0);
        c.stop();
    }

    #[test]
    fn hot_route_controller_expands_and_shrinks_with_hysteresis() {
        let c = p2c_cluster();
        // Find a key this node owns (the controller only steers owned
        // routes).
        let key = (0..500)
            .map(|i| format!("own{i}"))
            .find(|k| c.owner_name(k).as_deref() == Some("127.0.0.1:1"))
            .expect("some key is owned locally");
        assert_eq!(c.effective_replicas(&key), 2);
        // Sustained heat: EWMA climbs past the expand threshold, then
        // one expansion per cooldown window.
        let mut rounds_to_first = None;
        for round in 1..=20u64 {
            for _ in 0..(2 * HOT_EXPAND_PER_ROUND) {
                c.note_route_request(&key);
            }
            c.hot_route_round();
            if rounds_to_first.is_none()
                && c.stats.route_expansions.load(Ordering::Relaxed) > 0
            {
                rounds_to_first = Some(round);
            }
        }
        // 4-node ring, base 2: expansion caps at 4.
        assert_eq!(c.effective_replicas(&key), 4);
        let expansions = c.stats.route_expansions.load(Ordering::Relaxed);
        assert_eq!(expansions, 2, "base 2 -> 4 is exactly two transitions");
        let claim = c.route_claims()[&key];
        assert_eq!(claim.replicas, 4);
        assert!(claim.epoch >= 2);
        // Cooldown: transitions must be spread at least
        // HOT_COOLDOWN_ROUNDS apart, so the first one alone can't have
        // finished the climb.
        assert!(rounds_to_first.unwrap() < 20);
        // Cold rounds: EWMA decays below the shrink threshold and the
        // route steps back down to base — and no further.
        for _ in 0..40 {
            c.hot_route_round();
        }
        assert_eq!(c.effective_replicas(&key), 2);
        assert_eq!(c.stats.route_shrinks.load(Ordering::Relaxed), 2);
        // The claim table remembers the base with a newer epoch (the
        // shrink must win merges against the old expansion claim).
        assert!(c.route_claims()[&key].epoch > claim.epoch);
        c.stop();
    }

    #[test]
    fn flapping_load_inside_the_band_never_transitions() {
        let c = p2c_cluster();
        let key = (0..500)
            .map(|i| format!("own{i}"))
            .find(|k| c.owner_name(k).as_deref() == Some("127.0.0.1:1"))
            .unwrap();
        // Alternate 24 and 8 requests per round: the EWMA settles
        // inside the (HOT_SHRINK, HOT_EXPAND) hysteresis band.
        for round in 0..40 {
            let n = if round % 2 == 0 { 24 } else { 8 };
            for _ in 0..n {
                c.note_route_request(&key);
            }
            c.hot_route_round();
        }
        assert_eq!(c.stats.route_expansions.load(Ordering::Relaxed), 0);
        assert_eq!(c.stats.route_shrinks.load(Ordering::Relaxed), 0);
        assert_eq!(c.effective_replicas(&key), 2);
        c.stop();
    }

    #[test]
    fn remote_route_claims_only_ever_raise_above_base() {
        let c = p2c_cluster();
        let key = remote_key(&c);
        c.apply_remote_routes(&[RouteOverride {
            route: key.clone(),
            claim: RouteClaim { epoch: 3, replicas: 3 },
        }]);
        assert_eq!(c.effective_replicas(&key), 3);
        assert_eq!(c.replica_set(&key).len(), 3);
        assert_eq!(c.live_replicas(&key).len(), 3);
        // A claim below the configured base is clamped to the base.
        c.apply_remote_routes(&[RouteOverride {
            route: key.clone(),
            claim: RouteClaim { epoch: 4, replicas: 1 },
        }]);
        assert_eq!(c.effective_replicas(&key), 2);
        // And a claim above the ring clamps to the ring.
        c.apply_remote_routes(&[RouteOverride {
            route: key.clone(),
            claim: RouteClaim { epoch: 5, replicas: 200 },
        }]);
        assert_eq!(c.effective_replicas(&key), 4);
        c.stop();
    }

    #[test]
    fn node_load_gauges_feed_the_stamped_stanza() {
        let c = test_cluster(1);
        c.load().begin_request();
        c.load().begin_request();
        c.load().end_request(800);
        let entries = c.member_entries();
        let me = entries
            .iter()
            .find(|e| e.addr == "127.0.0.1:1")
            .expect("self entry");
        let l = me.load.expect("self entry must carry a load stanza");
        assert_eq!(l.queue_depth, 1);
        assert_eq!(l.ewma_latency_us, 100, "EWMA alpha 1/8 of 800");
        assert!(l.version >= 1);
        // Peers we know nothing about carry no stanza.
        let peer = entries.iter().find(|e| e.addr != "127.0.0.1:1").unwrap();
        assert!(peer.load.is_none());
        // A second sample bumps the freshness version.
        let me2 = c.member_entries();
        let l2 = me2.iter().find(|e| e.addr == "127.0.0.1:1").unwrap();
        assert!(l2.load.unwrap().version > l.version);
        c.stop();
    }

    #[test]
    fn seed_node_with_no_peers_starts_alone() {
        let c = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            probe_interval: Duration::from_secs(3600),
            incarnation: Some(7),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(c.alive_members(), 1);
        assert_eq!(c.ring().nodes(), &["127.0.0.1:1".to_string()]);
        assert_eq!(c.candidates("anything"), vec![Node::Local]);
        c.stop();
    }
}
