//! Cluster tier: consistent-hash routing of model names across several
//! serving processes, with a health-checked peer table and an HTTP/1.1
//! proxy path.
//!
//! The paper frames one datapath generator serving *many* precision
//! design points; the router (L3) places those points side by side in
//! one process, and this module shards them across processes. Each
//! node runs the same HTTP front end ([`super::Server`]); a node
//! started in cluster mode additionally owns:
//!
//! * [`HashRing`] — consistent hashing with virtual nodes over the
//!   dependency-free [`hash64`] (FNV-1a + splitmix64 finalizer, the
//!   crate's `util::rng`-style mixing). Every node hashes the same
//!   identifier set (its own advertised address plus `--peers`), so
//!   all fronts agree on ownership. A key's candidate order is the
//!   ring walk from its hash point: the owner first, then the nodes
//!   that would inherit it — which is exactly the failover order, so
//!   a dead node's keys move *only* to their next-in-ring successor
//!   and every other key keeps its owner.
//! * A peer table with a background prober: `GET /health` every
//!   `probe_interval`, [`ClusterConfig::failure_threshold`] consecutive
//!   failures evict a peer from routing (it stays in the ring, so
//!   re-admission restores the exact original placement), and
//!   `recovery_threshold` consecutive successes re-admit it. Proxy
//!   traffic feeds the same accounting, so a dead peer is usually
//!   evicted by the first failed forward, not a probe tick later.
//! * The proxy path: `/v1/eval` and `/v1/batch` bodies whose model is
//!   owned elsewhere are forwarded verbatim (the incremental parser
//!   has already decoded chunked or `Content-Length` framing, so the
//!   hop is a plain `Content-Length` POST) tagged with
//!   [`PROXIED_HEADER`]; tagged requests are always answered locally,
//!   which bounds any transient ring disagreement to one hop.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

use super::http::{HttpConn, Response};

/// Header marking a request as already forwarded once: the receiving
/// node must answer locally, never re-proxy (loop guard).
pub const PROXIED_HEADER: &str = "x-tanhvf-proxied";

/// Response-size bound for the proxy leg (mirrors the loadgen client).
const MAX_PROXY_BODY: usize = 1 << 22;

/// FNV-1a 64-bit: the dependency-free byte hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Ring hash: FNV-1a with a splitmix64 finalizer (the same mixing
/// constants [`crate::util::rng`] seeds with). Raw FNV-1a is too
/// correlated on near-identical short strings — `addr#0`, `addr#1`, …
/// vnode labels land in clumps and the arc shares skew ~3x — and the
/// finalizer's avalanche restores an even spread.
pub fn hash64(bytes: &[u8]) -> u64 {
    let mut z = fnv1a64(bytes).wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------
// Hash ring
// ---------------------------------------------------------------------

/// Consistent-hash ring with virtual nodes.
///
/// Immutable once built: liveness is applied at lookup time by walking
/// past dead nodes, so membership changes (eviction, re-admission)
/// never rebuild the ring and the placement of keys on *live* nodes is
/// a pure function of the configured node set.
pub struct HashRing {
    /// (hash point, node index), sorted by hash point.
    points: Vec<(u64, u32)>,
    nodes: Vec<String>,
}

impl HashRing {
    /// Build over the deduplicated, name-sorted node set; each node
    /// contributes `virtual_nodes` points.
    pub fn new(nodes: &[String], virtual_nodes: usize) -> HashRing {
        let mut uniq: Vec<String> = nodes.to_vec();
        uniq.sort();
        uniq.dedup();
        let vnodes = virtual_nodes.max(1);
        let mut points = Vec::with_capacity(uniq.len() * vnodes);
        for (i, n) in uniq.iter().enumerate() {
            for v in 0..vnodes {
                points.push((hash64(format!("{n}#{v}").as_bytes()), i as u32));
            }
        }
        points.sort_unstable();
        HashRing { points, nodes: uniq }
    }

    /// The configured node set (sorted, deduplicated).
    pub fn nodes(&self) -> &[String] {
        &self.nodes
    }

    /// Every node in ring-walk order from `key`'s hash point: the
    /// owner first, then successive inheritors. Deterministic for a
    /// given (node set, virtual_nodes, key).
    pub fn successors(&self, key: &str) -> Vec<&str> {
        if self.points.is_empty() {
            return Vec::new();
        }
        let h = hash64(key.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut seen = vec![false; self.nodes.len()];
        let mut out = Vec::with_capacity(self.nodes.len());
        for off in 0..self.points.len() {
            let (_, ni) = self.points[(start + off) % self.points.len()];
            let ni = ni as usize;
            if !seen[ni] {
                seen[ni] = true;
                out.push(self.nodes[ni].as_str());
                if out.len() == self.nodes.len() {
                    break;
                }
            }
        }
        out
    }

    /// The key's owner ignoring liveness.
    pub fn owner(&self, key: &str) -> Option<&str> {
        self.successors(key).first().copied()
    }
}

// ---------------------------------------------------------------------
// Peer table
// ---------------------------------------------------------------------

/// Routing view of one peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerHealth {
    /// Answering probes/proxies; routable.
    Healthy,
    /// Recent failures below the eviction threshold; still routable.
    Suspect,
    /// Evicted from routing until `recovery_threshold` consecutive
    /// successful probes.
    Down,
}

impl PeerHealth {
    pub fn name(&self) -> &'static str {
        match self {
            PeerHealth::Healthy => "healthy",
            PeerHealth::Suspect => "suspect",
            PeerHealth::Down => "down",
        }
    }
}

#[derive(Clone, Debug)]
struct PeerSlot {
    health: PeerHealth,
    consecutive_failures: u32,
    consecutive_successes: u32,
}

impl PeerSlot {
    fn new() -> PeerSlot {
        PeerSlot {
            health: PeerHealth::Healthy,
            consecutive_failures: 0,
            consecutive_successes: 0,
        }
    }
}

/// Cluster-wide counters surfaced on `/metrics`.
#[derive(Default)]
pub struct ClusterStats {
    /// Eval/batch requests answered by the local router (owned here).
    pub local: AtomicU64,
    /// Requests forwarded to a peer (successful round trip).
    pub proxied: AtomicU64,
    /// Forwarded requests received from another front.
    pub proxied_in: AtomicU64,
    /// Transport failures on the proxy leg.
    pub proxy_errors: AtomicU64,
    /// Requests served by a non-first candidate after the owner failed.
    pub failovers: AtomicU64,
    /// Peer transitions into `Down`.
    pub evictions: AtomicU64,
    /// Peer transitions out of `Down`.
    pub readmissions: AtomicU64,
}

/// Where a key's next candidate lives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Node {
    /// This process owns the key: serve through the local router.
    Local,
    /// A peer owns it: proxy to this address.
    Peer(String),
}

/// Tuning for one cluster node. `advertise` is the identity this node
/// hashes itself under — it must match what the other fronts list in
/// their `--peers` for all rings to agree (an empty string is filled
/// with the bound address by [`super::Server::start_cluster`]).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub advertise: String,
    pub peers: Vec<String>,
    /// Ring points per node; more points = tighter load spread per key
    /// at O(nodes * virtual_nodes * log) build cost.
    pub virtual_nodes: usize,
    pub probe_interval: Duration,
    /// Connect/read budget for one probe.
    pub probe_timeout: Duration,
    /// Consecutive failures (probe or proxy) that evict a peer.
    pub failure_threshold: u32,
    /// Consecutive successful probes that re-admit an evicted peer.
    pub recovery_threshold: u32,
    /// End-to-end budget for one forwarded request.
    pub proxy_timeout: Duration,
    /// Bound on concurrent outbound forwards. A forward blocks the
    /// worker thread driving it, so an unbounded count lets two fronts
    /// proxying to each other fill both worker pools and deadlock
    /// until `proxy_timeout`; past the bound requests are shed with
    /// 503 instead. `0` means "derive from the server's worker count"
    /// ([`super::Server::start_cluster`] fills in `workers / 2`,
    /// minimum 1, so at least half the pool always stays available for
    /// local and proxied-in work).
    pub max_inflight_forwards: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            advertise: String::new(),
            peers: Vec::new(),
            virtual_nodes: 64,
            probe_interval: Duration::from_millis(500),
            probe_timeout: Duration::from_secs(1),
            failure_threshold: 3,
            recovery_threshold: 2,
            proxy_timeout: Duration::from_secs(10),
            max_inflight_forwards: 0,
        }
    }
}

/// A running cluster view: ring + peer table + prober thread.
pub struct Cluster {
    cfg: ClusterConfig,
    ring: HashRing,
    peers: Mutex<BTreeMap<String, PeerSlot>>,
    pub stats: ClusterStats,
    /// Concurrent outbound forwards (bounded by
    /// `cfg.max_inflight_forwards`).
    inflight_forwards: AtomicUsize,
    shutdown: Arc<AtomicBool>,
    prober: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Cluster {
    /// Validate, build the ring, and launch the prober.
    pub fn start(mut cfg: ClusterConfig) -> Result<Arc<Cluster>, String> {
        if cfg.advertise.is_empty() {
            return Err("cluster: advertise address must be set".into());
        }
        if cfg.peers.iter().any(|p| p == &cfg.advertise) {
            return Err(format!(
                "cluster: --peers must not include the node itself ({})",
                cfg.advertise
            ));
        }
        if cfg.failure_threshold == 0 || cfg.recovery_threshold == 0 {
            return Err("cluster: thresholds must be >= 1".into());
        }
        if cfg.max_inflight_forwards == 0 {
            // "Auto" without a known worker count: effectively
            // unbounded. The HTTP server substitutes workers/2 before
            // starting the cluster.
            cfg.max_inflight_forwards = usize::MAX;
        }
        let mut nodes = cfg.peers.clone();
        nodes.push(cfg.advertise.clone());
        let ring = HashRing::new(&nodes, cfg.virtual_nodes);
        let peers = cfg
            .peers
            .iter()
            .map(|p| (p.clone(), PeerSlot::new()))
            .collect::<BTreeMap<_, _>>();
        let cluster = Arc::new(Cluster {
            cfg,
            ring,
            peers: Mutex::new(peers),
            stats: ClusterStats::default(),
            inflight_forwards: AtomicUsize::new(0),
            shutdown: Arc::new(AtomicBool::new(false)),
            prober: Mutex::new(None),
        });
        if !cluster.cfg.peers.is_empty() {
            // The prober holds only a Weak reference: a Cluster whose
            // owners all drop without calling stop() still gets its
            // Drop (the upgrade fails and the thread exits) instead of
            // an Arc cycle keeping both alive forever.
            let weak: Weak<Cluster> = Arc::downgrade(&cluster);
            let shutdown = cluster.shutdown.clone();
            let interval = cluster.cfg.probe_interval;
            let t = std::thread::Builder::new()
                .name("tanhvf-cluster-prober".into())
                .spawn(move || loop {
                    // Sleep first (in short slices so stop() is
                    // prompt): freshly started peers keep the
                    // optimistic Healthy default for one interval, and
                    // deterministic tests see no startup probe race.
                    let mut left = interval;
                    while !left.is_zero() {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let step = left.min(Duration::from_millis(25));
                        std::thread::sleep(step);
                        left -= step;
                    }
                    let Some(c) = weak.upgrade() else { return };
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    c.probe_round();
                })
                .map_err(|e| format!("spawn prober: {e}"))?;
            *cluster.prober.lock().unwrap() = Some(t);
        }
        Ok(cluster)
    }

    /// Stop the prober and join it. Idempotent. Joining is skipped when
    /// called *from* the prober thread (possible when the prober's
    /// transient strong reference is the last one and its drop runs
    /// this via `Drop for Cluster`) — the thread exits on its own right
    /// after.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let handle = self.prober.lock().unwrap().take();
        if let Some(t) = handle {
            if t.thread().id() != std::thread::current().id() {
                let _ = t.join();
            }
        }
    }

    /// Reserve one slot of outbound-forward concurrency, or `None` when
    /// the bound is reached (the caller sheds load). The permit returns
    /// its slot on drop.
    pub fn try_forward_permit(&self) -> Option<ForwardPermit<'_>> {
        let limit = self.cfg.max_inflight_forwards;
        let mut cur = self.inflight_forwards.load(Ordering::Relaxed);
        loop {
            if cur >= limit {
                return None;
            }
            match self.inflight_forwards.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(ForwardPermit(self)),
                Err(seen) => cur = seen,
            }
        }
    }

    /// This node's ring identity.
    pub fn self_name(&self) -> &str {
        &self.cfg.advertise
    }

    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Health of every peer, name-sorted.
    pub fn peer_health(&self) -> BTreeMap<String, PeerHealth> {
        self.peers
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.health))
            .collect()
    }

    pub fn healthy_peers(&self) -> usize {
        self.peers
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.health != PeerHealth::Down)
            .count()
    }

    /// Candidate nodes for a key, in ring order, evicted peers
    /// skipped. The first entry is the routing decision; the rest are
    /// the failover order.
    pub fn candidates(&self, key: &str) -> Vec<Node> {
        let peers = self.peers.lock().unwrap();
        self.ring
            .successors(key)
            .into_iter()
            .filter_map(|n| {
                if n == self.cfg.advertise {
                    Some(Node::Local)
                } else {
                    match peers.get(n) {
                        Some(s) if s.health != PeerHealth::Down => {
                            Some(Node::Peer(n.to_string()))
                        }
                        _ => None,
                    }
                }
            })
            .collect()
    }

    /// The node currently routed to for `key` (liveness applied).
    pub fn owner_name(&self, key: &str) -> Option<String> {
        match self.candidates(key).into_iter().next() {
            Some(Node::Local) => Some(self.cfg.advertise.clone()),
            Some(Node::Peer(p)) => Some(p),
            None => None,
        }
    }

    /// One failed probe/proxy against `addr`.
    pub fn record_failure(&self, addr: &str) {
        let mut peers = self.peers.lock().unwrap();
        let Some(slot) = peers.get_mut(addr) else { return };
        slot.consecutive_successes = 0;
        slot.consecutive_failures = slot.consecutive_failures.saturating_add(1);
        if slot.health != PeerHealth::Down {
            slot.health = if slot.consecutive_failures
                >= self.cfg.failure_threshold
            {
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                PeerHealth::Down
            } else {
                PeerHealth::Suspect
            };
        }
    }

    /// One successful probe/proxy against `addr`.
    pub fn record_success(&self, addr: &str) {
        let mut peers = self.peers.lock().unwrap();
        let Some(slot) = peers.get_mut(addr) else { return };
        slot.consecutive_failures = 0;
        slot.consecutive_successes =
            slot.consecutive_successes.saturating_add(1);
        match slot.health {
            PeerHealth::Down => {
                if slot.consecutive_successes >= self.cfg.recovery_threshold {
                    slot.health = PeerHealth::Healthy;
                    self.stats.readmissions.fetch_add(1, Ordering::Relaxed);
                }
            }
            PeerHealth::Suspect => slot.health = PeerHealth::Healthy,
            PeerHealth::Healthy => {}
        }
    }

    /// Forward a decoded request body to a peer and return its
    /// response. Transport failures are `Err` (the caller records them
    /// and fails over); HTTP-level errors pass through as responses.
    pub fn forward(
        &self,
        addr: &str,
        path: &str,
        body: &[u8],
    ) -> Result<Response, String> {
        let sa = resolve(addr)?;
        let stream = TcpStream::connect_timeout(&sa, self.cfg.proxy_timeout)
            .map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(self.cfg.proxy_timeout));
        let _ = stream.set_write_timeout(Some(self.cfg.proxy_timeout));
        let mut conn = HttpConn::new(stream);
        conn.write_request_with_headers(
            "POST",
            path,
            &[(PROXIED_HEADER, "1")],
            body,
        )
        .map_err(|e| format!("forward to {addr}: {e}"))?;
        let (status, headers, body) = conn
            .read_response(MAX_PROXY_BODY)
            .map_err(|e| format!("response from {addr}: {e}"))?;
        let content_type = headers
            .get("content-type")
            .cloned()
            .unwrap_or_else(|| "application/json".into());
        Ok(Response { status, content_type, body })
    }

    /// One probe pass over every peer — including evicted ones, which
    /// is the re-admission path. Proxy traffic feeds the same
    /// accounting between rounds.
    fn probe_round(&self) {
        let addrs: Vec<String> =
            self.peers.lock().unwrap().keys().cloned().collect();
        for addr in addrs {
            if self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if probe(&addr, self.cfg.probe_timeout) {
                self.record_success(&addr);
            } else {
                self.record_failure(&addr);
            }
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.stop();
    }
}

/// An in-flight outbound-forward slot; dropping it frees the slot.
pub struct ForwardPermit<'a>(&'a Cluster);

impl Drop for ForwardPermit<'_> {
    fn drop(&mut self) {
        self.0.inflight_forwards.fetch_sub(1, Ordering::Release);
    }
}

fn resolve(addr: &str) -> Result<SocketAddr, String> {
    addr.to_socket_addrs()
        .map_err(|e| format!("resolve {addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("resolve {addr}: no address"))
}

/// One liveness probe: `GET /health` must answer 200 within the budget.
fn probe(addr: &str, timeout: Duration) -> bool {
    let Ok(sa) = resolve(addr) else { return false };
    let Ok(stream) = TcpStream::connect_timeout(&sa, timeout) else {
        return false;
    };
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    let mut conn = HttpConn::new(stream);
    if conn.write_request("GET", "/health", b"").is_err() {
        return false;
    }
    matches!(conn.read_response(1 << 20), Ok((200, _, _)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:8787")).collect()
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn ring_hash_decorrelates_sequential_labels() {
        // The finalizer must spread `addr#0..addr#n` labels evenly:
        // check the top byte of consecutive vnode labels is not
        // constant (raw FNV-1a fails this badly — its low-byte change
        // barely reaches the high bits for short strings).
        let mut top_bytes = std::collections::BTreeSet::new();
        for v in 0..64 {
            top_bytes.insert((hash64(format!("10.0.0.1:8787#{v}").as_bytes())
                >> 56) as u8);
        }
        assert!(
            top_bytes.len() > 32,
            "only {} distinct top bytes over 64 labels",
            top_bytes.len()
        );
    }

    #[test]
    fn ring_is_deterministic_and_covers_all_nodes() {
        let nodes = names(3);
        let a = HashRing::new(&nodes, 64);
        let b = HashRing::new(&nodes, 64);
        for key in ["s3_12", "s3_5", "s2_8", "model-x"] {
            assert_eq!(a.owner(key), b.owner(key));
            let succ = a.successors(key);
            assert_eq!(succ.len(), 3, "{key}: {succ:?}");
            let mut sorted: Vec<&str> = succ.clone();
            sorted.sort_unstable();
            let want: Vec<&str> =
                nodes.iter().map(String::as_str).collect();
            assert_eq!(sorted, want, "{key}");
        }
        // Node order in input must not matter.
        let mut shuffled = nodes.clone();
        shuffled.reverse();
        let c = HashRing::new(&shuffled, 64);
        assert_eq!(a.owner("s3_12"), c.owner("s3_12"));
    }

    #[test]
    fn ring_spreads_keys_roughly_evenly() {
        let ring = HashRing::new(&names(4), 64);
        let mut counts = BTreeMap::new();
        for i in 0..4000 {
            let k = format!("model-{i}");
            *counts.entry(ring.owner(&k).unwrap().to_string()).or_insert(0) +=
                1;
        }
        assert_eq!(counts.len(), 4);
        for (node, c) in &counts {
            // 1000 expected; virtual nodes keep the spread sane.
            assert!(
                (400..=1800).contains(c),
                "{node} owns {c} of 4000 keys: {counts:?}"
            );
        }
    }

    #[test]
    fn dead_node_moves_only_its_own_keys() {
        // The rebalance bound: with one node excluded, every key owned
        // by a surviving node keeps its owner; only the dead node's
        // keys move (to their ring successor).
        let nodes = names(3);
        let ring = HashRing::new(&nodes, 64);
        let dead = ring.owner("pick-a-victim").unwrap().to_string();
        let total = 3000usize;
        let mut moved = 0usize;
        for i in 0..total {
            let k = format!("model-{i}");
            let succ = ring.successors(&k);
            let before = succ[0];
            let after = *succ
                .iter()
                .find(|&&n| n != dead.as_str())
                .expect("two nodes survive");
            if before == dead {
                moved += 1;
                // Inherited by the immediate successor, nothing else.
                assert_eq!(after, succ[1], "{k}");
            } else {
                assert_eq!(before, after, "{k}: key moved off a live node");
            }
        }
        let frac = moved as f64 / total as f64;
        // Expected share 1/3; allow ring-slack for the hash spread.
        assert!(
            frac > 0.15 && frac < 1.0 / 3.0 + 0.15,
            "moved fraction {frac}"
        );
    }

    #[test]
    fn single_node_ring_owns_everything() {
        let ring = HashRing::new(&names(1), 8);
        assert_eq!(ring.owner("anything"), Some("10.0.0.0:8787"));
        assert!(HashRing::new(&[], 8).owner("x").is_none());
    }

    fn test_cluster(peers: usize) -> Arc<Cluster> {
        Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            // Unroutable peers; the prober is effectively a no-op
            // within the test runtime because probe_interval is long.
            peers: (0..peers).map(|i| format!("127.0.0.1:{}", 2 + i)).collect(),
            probe_interval: Duration::from_secs(3600),
            probe_timeout: Duration::from_millis(10),
            failure_threshold: 2,
            recovery_threshold: 2,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn eviction_and_readmission_state_machine() {
        let c = test_cluster(2);
        let peer = "127.0.0.1:2";
        assert_eq!(c.peer_health()[peer], PeerHealth::Healthy);
        c.record_failure(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Suspect);
        // A success below the eviction threshold heals immediately.
        c.record_success(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Healthy);
        // Two consecutive failures evict.
        c.record_failure(peer);
        c.record_failure(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Down);
        assert_eq!(c.stats.evictions.load(Ordering::Relaxed), 1);
        assert_eq!(c.healthy_peers(), 1);
        // Re-admission needs recovery_threshold consecutive successes.
        c.record_success(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Down);
        c.record_success(peer);
        assert_eq!(c.peer_health()[peer], PeerHealth::Healthy);
        assert_eq!(c.stats.readmissions.load(Ordering::Relaxed), 1);
        c.stop();
    }

    #[test]
    fn candidates_skip_evicted_peers() {
        let c = test_cluster(2);
        // Find a key owned by a peer.
        let key = (0..200)
            .map(|i| format!("m{i}"))
            .find(|k| {
                matches!(
                    c.candidates(k).first(),
                    Some(Node::Peer(_))
                )
            })
            .expect("some key lands on a peer");
        let Some(Node::Peer(owner)) = c.candidates(&key).first().cloned()
        else {
            unreachable!()
        };
        // Evict the owner: the key must remap to a surviving node and
        // the candidate list must shrink by exactly one.
        let before = c.candidates(&key);
        assert_eq!(before.len(), 3);
        c.record_failure(&owner);
        c.record_failure(&owner);
        let after = c.candidates(&key);
        assert_eq!(after.len(), 2);
        assert_ne!(after.first(), Some(&Node::Peer(owner.clone())));
        // And the new order is the old order with the owner removed —
        // only the dead node's keys moved.
        let filtered: Vec<Node> = before
            .into_iter()
            .filter(|n| *n != Node::Peer(owner.clone()))
            .collect();
        assert_eq!(after, filtered);
        c.stop();
    }

    #[test]
    fn rejects_self_in_peer_list_and_empty_advertise() {
        let err = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            peers: vec!["127.0.0.1:1".into()],
            ..Default::default()
        })
        .unwrap_err();
        assert!(err.contains("itself"), "{err}");
        assert!(Cluster::start(ClusterConfig::default()).is_err());
    }

    #[test]
    fn forward_permits_bound_concurrency_and_release_on_drop() {
        let c = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            peers: vec!["127.0.0.1:2".into()],
            probe_interval: Duration::from_secs(3600),
            max_inflight_forwards: 2,
            ..Default::default()
        })
        .unwrap();
        let p1 = c.try_forward_permit().expect("first permit");
        let p2 = c.try_forward_permit().expect("second permit");
        assert!(
            c.try_forward_permit().is_none(),
            "bound of 2 must shed the third forward"
        );
        drop(p1);
        let p3 = c.try_forward_permit().expect("slot freed on drop");
        drop(p2);
        drop(p3);
        assert_eq!(c.inflight_forwards.load(Ordering::Relaxed), 0);
        c.stop();
    }

    #[test]
    fn default_permit_bound_is_unbounded_for_direct_users() {
        // max_inflight_forwards = 0 means "auto": direct Cluster users
        // get effectively unbounded permits (the HTTP server
        // substitutes workers/2 before starting).
        let c = test_cluster(1);
        let _a = c.try_forward_permit().expect("permit");
        let _b = c.try_forward_permit().expect("permit");
        c.stop();
    }

    #[test]
    fn unknown_peer_records_are_ignored() {
        let c = test_cluster(1);
        c.record_failure("127.0.0.1:999");
        c.record_success("127.0.0.1:999");
        assert_eq!(c.peer_health().len(), 1);
        c.stop();
    }
}
