//! Nonblocking connection state machine for the reactor backend.
//!
//! One [`Conn`] per accepted socket, driven by readiness events:
//!
//! ```text
//!   Reading --(request parsed)--> Dispatching --(pool completes)-->
//!   Writing --(drained, keep-alive)--> Reading | --(close)--> gone
//! ```
//!
//! Each phase has its own deadline (checked by the reactor's sweep):
//! a partially received message must keep making progress within
//! `header_timeout` (slow-loris stall defence -> 408), an idle
//! keep-alive connection is bounded by `keep_alive`, and a stalled
//! response drain by `write_timeout`. Dispatch itself is bounded by the
//! router's `request_timeout` (-> 504), so no phase can hold the
//! connection forever.
//!
//! The state machine is transport-only — it never touches the router.
//! Parsed requests surface as [`Action::Dispatch`] and the reactor
//! hands them to the worker pool; internally generated protocol errors
//! (400/408/413/431/501) are serialized straight into the output
//! buffer, counted against the shared [`HttpCounters`], and the
//! connection closes once they drain.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::io::{AsRawFd, RawFd};
use std::time::{Duration, Instant};

use super::api::error_resp;
use super::http::{encode_response, HttpError, Parser, Request, Response};
use super::reactor::Interest;
use super::{HttpCounters, ServerConfig};

/// Which part of the request lifecycle the connection is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Phase {
    /// Waiting for (more of) a request.
    Reading,
    /// A request is in flight on the worker pool.
    Dispatching,
    /// Draining a serialized response.
    Writing,
}

/// What the reactor must do after driving the state machine.
#[derive(Debug)]
pub(crate) enum Action {
    /// Nothing beyond refreshing poll interest.
    Continue,
    /// A complete request is ready for the worker pool.
    Dispatch(Request),
    /// Tear the connection down (any queued bytes already flushed or
    /// unflushable).
    Close,
}

/// Per-connection state: socket, resumable parser, pending output.
pub(crate) struct Conn {
    stream: TcpStream,
    parser: Parser,
    phase: Phase,
    out: Vec<u8>,
    out_pos: usize,
    keep_after_write: bool,
    max_body: usize,
    /// Last progress on the in-progress message (None when idle between
    /// messages) — anchors the mid-message stall deadline.
    read_started: Option<Instant>,
    /// First byte of the in-progress message — never refreshed, so a
    /// byte-drip client (which always beats the stall deadline) is
    /// still bounded by the total budget.
    message_started: Option<Instant>,
    /// Entry into idle Reading — anchors the keep-alive budget.
    idle_since: Instant,
    /// Entry into Writing — anchors the drain deadline.
    write_since: Option<Instant>,
    /// Entry into Dispatching — anchors the lost-completion backstop.
    dispatch_since: Option<Instant>,
    registered: Interest,
}

/// Bound on bytes consumed per readiness event so one chatty peer
/// cannot starve the loop (level-triggered polling re-fires).
const MAX_READ_PER_EVENT: usize = 16 * 4096;

/// Total-receipt budget for one message, as a multiple of the stall
/// deadline (`header_timeout`): generous enough for a slow legitimate
/// upload, but a hard bound on a client dripping one byte per
/// almost-`header_timeout` to dodge the stall check.
const MESSAGE_BUDGET_FACTOR: u32 = 40;

/// Slack added to `2 * request_timeout` for the Dispatching backstop.
/// This is a lost-completion detector, not a latency bound: a dispatch
/// normally answers within `request_timeout` (504 path), but a cluster
/// front's proxy leg may legitimately take several `proxy_timeout`s
/// (connect + write + read are bounded separately, across failover
/// candidates), so the grace is deliberately far above any of those.
/// Only a worker that died without pushing its completion — which
/// would otherwise park the connection in Dispatching forever — should
/// ever hit it.
const DISPATCH_GRACE: Duration = Duration::from_secs(120);

impl Conn {
    pub fn new(
        stream: TcpStream,
        now: Instant,
        max_body: usize,
    ) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        let _ = stream.set_nodelay(true);
        Ok(Conn {
            stream,
            parser: Parser::new(),
            phase: Phase::Reading,
            out: Vec::new(),
            out_pos: 0,
            keep_after_write: false,
            max_body,
            read_started: None,
            message_started: None,
            idle_since: now,
            write_since: None,
            dispatch_since: None,
            registered: Interest::Read,
        })
    }

    pub fn fd(&self) -> RawFd {
        self.stream.as_raw_fd()
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// The readiness this phase needs from the poller.
    pub fn interest(&self) -> Interest {
        match self.phase {
            Phase::Reading => Interest::Read,
            Phase::Dispatching => Interest::None,
            Phase::Writing => Interest::Write,
        }
    }

    pub fn registered_interest(&self) -> Interest {
        self.registered
    }

    pub fn set_registered_interest(&mut self, i: Interest) {
        self.registered = i;
    }

    /// Socket is readable: pull bytes, resume the parser, maybe yield a
    /// request or a protocol-error response.
    pub fn on_readable(
        &mut self,
        now: Instant,
        http: &HttpCounters,
    ) -> Action {
        debug_assert_eq!(self.phase, Phase::Reading);
        let mut taken = 0usize;
        let mut eof = false;
        loop {
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                // EOF: stop reading, but parse what arrived first — a
                // client may write a full request and half-close in one
                // event. The fd stays readable at EOF (level-triggered),
                // so a later event closes the connection once the
                // parser is back at a clean point.
                Ok(0) => {
                    eof = true;
                    break;
                }
                Ok(n) => {
                    self.parser.feed(&chunk[..n]);
                    // Progress refreshes the stall deadline: a steady
                    // (if slow) upload is fine; only a silent stall
                    // mid-message draws the 408 — matching the threaded
                    // backend's stall-based timeout. The total budget
                    // (message_started) is anchored once and never
                    // refreshed.
                    self.read_started = Some(now);
                    if self.message_started.is_none() {
                        self.message_started = Some(now);
                    }
                    taken += n;
                    if taken >= MAX_READ_PER_EVENT {
                        break;
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break;
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    continue;
                }
                Err(_) => return Action::Close,
            }
        }
        let action = self.try_parse(now, http);
        // On EOF with nothing dispatchable (idle peer close, or
        // mid-message hangup with nobody left to answer), tear down now;
        // a queued error response (phase Writing) still gets to drain.
        if eof
            && self.phase == Phase::Reading
            && matches!(action, Action::Continue)
        {
            return Action::Close;
        }
        action
    }

    /// Socket is writable: keep draining the response.
    pub fn on_writable(
        &mut self,
        now: Instant,
        http: &HttpCounters,
    ) -> Action {
        debug_assert_eq!(self.phase, Phase::Writing);
        if self.flush().is_err() {
            return Action::Close;
        }
        self.after_flush(now, http)
    }

    /// A dispatched request finished: queue its response (the dispatch
    /// job already counted the status) and start draining.
    pub fn complete(
        &mut self,
        resp: &Response,
        keep: bool,
        now: Instant,
        http: &HttpCounters,
    ) -> Action {
        debug_assert_eq!(self.phase, Phase::Dispatching);
        self.dispatch_since = None;
        self.keep_after_write = keep;
        self.out = encode_response(resp, keep);
        self.out_pos = 0;
        self.phase = Phase::Writing;
        self.write_since = Some(now);
        if self.flush().is_err() {
            return Action::Close;
        }
        self.after_flush(now, http)
    }

    /// Enforce the current phase's deadline.
    pub fn check_deadline(
        &mut self,
        now: Instant,
        cfg: &ServerConfig,
        http: &HttpCounters,
    ) -> Action {
        match self.phase {
            Phase::Reading => {
                if let Some(t0) = self.read_started {
                    let total_spent = self
                        .message_started
                        .map(|m| now.duration_since(m))
                        .unwrap_or_default();
                    if now.duration_since(t0) >= cfg.header_timeout
                        || total_spent
                            >= cfg.header_timeout * MESSAGE_BUDGET_FACTOR
                    {
                        return self.protocol_error(
                            HttpError::Timeout(
                                "mid-message read stall".into(),
                            ),
                            now,
                            http,
                        );
                    }
                } else if now.duration_since(self.idle_since)
                    >= cfg.keep_alive
                {
                    return Action::Close;
                }
                Action::Continue
            }
            Phase::Writing => match self.write_since {
                Some(t0)
                    if now.duration_since(t0) >= cfg.write_timeout =>
                {
                    Action::Close
                }
                _ => Action::Continue,
            },
            // Normally bounded by the router's request_timeout -> 504;
            // the backstop only fires if a completion was lost (worker
            // death), at which point closing is the only safe move —
            // nobody is left to write a response.
            Phase::Dispatching => match self.dispatch_since {
                Some(t0)
                    if now.duration_since(t0)
                        >= cfg.request_timeout * 2 + DISPATCH_GRACE =>
                {
                    Action::Close
                }
                _ => Action::Continue,
            },
        }
    }

    // -- internals ----------------------------------------------------

    /// Try to produce the next request from buffered bytes.
    fn try_parse(&mut self, now: Instant, http: &HttpCounters) -> Action {
        match self.parser.next_request(self.max_body) {
            Ok(Some(req)) => {
                self.read_started = None;
                self.message_started = None;
                self.phase = Phase::Dispatching;
                self.dispatch_since = Some(now);
                Action::Dispatch(req)
            }
            Ok(None) => {
                if self.parser.is_clean() {
                    self.read_started = None;
                    self.message_started = None;
                } else {
                    if self.read_started.is_none() {
                        self.read_started = Some(now);
                    }
                    if self.message_started.is_none() {
                        self.message_started = Some(now);
                    }
                }
                Action::Continue
            }
            Err(e) => self.protocol_error(e, now, http),
        }
    }

    /// Serialize + count an internally generated error response; the
    /// connection always closes once it drains.
    fn protocol_error(
        &mut self,
        e: HttpError,
        now: Instant,
        http: &HttpCounters,
    ) -> Action {
        let status = e.status();
        if status == 0 {
            return Action::Close;
        }
        http.count_response(status);
        let resp = error_resp(status, "protocol_error", &e.to_string());
        self.keep_after_write = false;
        self.out = encode_response(&resp, false);
        self.out_pos = 0;
        self.phase = Phase::Writing;
        self.write_since = Some(now);
        self.read_started = None;
        self.message_started = None;
        if self.flush().is_err() {
            return Action::Close;
        }
        self.after_flush(now, http)
    }

    /// Write as much pending output as the socket accepts.
    fn flush(&mut self) -> Result<(), ()> {
        while self.out_pos < self.out.len() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => return Err(()),
                Ok(n) => self.out_pos += n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return Ok(());
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return Err(()),
            }
        }
        Ok(())
    }

    /// Post-flush transition: stay in Writing, close, or go back to
    /// Reading — where a pipelined request may already be buffered.
    fn after_flush(&mut self, now: Instant, http: &HttpCounters) -> Action {
        if self.out_pos < self.out.len() {
            return Action::Continue; // still draining; stay in Writing
        }
        self.out.clear();
        self.out_pos = 0;
        self.write_since = None;
        if !self.keep_after_write {
            return Action::Close;
        }
        self.phase = Phase::Reading;
        self.idle_since = now;
        self.read_started = None;
        self.try_parse(now, http)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    fn test_cfg() -> ServerConfig {
        ServerConfig {
            header_timeout: Duration::from_millis(200),
            keep_alive: Duration::from_millis(500),
            write_timeout: Duration::from_secs(2),
            ..Default::default()
        }
    }

    #[test]
    fn request_dispatch_response_cycle_with_pipelining() {
        let (mut client, server) = pair();
        let now = Instant::now();
        let http = HttpCounters::default();
        let mut conn = Conn::new(server, now, 1 << 20).unwrap();

        // Two pipelined requests land in one write.
        client
            .write_all(b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n")
            .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let req_a = match conn.on_readable(now, &http) {
            Action::Dispatch(r) => r,
            other => panic!("expected dispatch, got {other:?}"),
        };
        assert_eq!(req_a.path(), "/a");
        assert_eq!(conn.phase(), Phase::Dispatching);
        assert_eq!(conn.interest(), Interest::None);

        // Completing /a must immediately surface the pipelined /b.
        let resp = Response::text(200, "ok-a");
        let req_b = match conn.complete(&resp, true, now, &http) {
            Action::Dispatch(r) => r,
            other => panic!("expected pipelined dispatch, got {other:?}"),
        };
        assert_eq!(req_b.path(), "/b");

        // And /b's completion returns the connection to idle Reading.
        let resp = Response::text(200, "ok-b");
        match conn.complete(&resp, true, now, &http) {
            Action::Continue => {}
            other => panic!("expected continue, got {other:?}"),
        }
        assert_eq!(conn.phase(), Phase::Reading);

        // Client sees both responses, in order.
        client
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut cc = crate::server::http::HttpConn::new(client);
        let (s1, _, b1) = cc.read_response(1 << 20).unwrap();
        let (s2, _, b2) = cc.read_response(1 << 20).unwrap();
        assert_eq!((s1, s2), (200, 200));
        assert_eq!((b1.as_slice(), b2.as_slice()), (&b"ok-a"[..], &b"ok-b"[..]));
    }

    #[test]
    fn partial_writes_drain_across_writable_events() {
        let (client, server) = pair();
        let now = Instant::now();
        let http = HttpCounters::default();
        let mut conn = Conn::new(server, now, 1 << 20).unwrap();

        // Drive a request through so the state machine is in Dispatching.
        let mut c = client.try_clone().unwrap();
        c.write_all(b"GET /big HTTP/1.1\r\n\r\n").unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let req = match conn.on_readable(now, &http) {
            Action::Dispatch(r) => r,
            other => panic!("expected dispatch, got {other:?}"),
        };
        assert_eq!(req.path(), "/big");

        // A response far larger than any socket buffer forces a partial
        // write: the connection must park in Writing with bytes pending.
        let big = "x".repeat(8 << 20);
        let resp = Response::text(200, &big);
        match conn.complete(&resp, true, now, &http) {
            Action::Continue => {}
            other => panic!("big response finished instantly: {other:?}"),
        }
        assert_eq!(conn.phase(), Phase::Writing);
        assert_eq!(conn.interest(), Interest::Write);

        // Reader drains the client side concurrently.
        let reader = std::thread::spawn(move || {
            let mut cc = crate::server::http::HttpConn::new(client);
            cc.stream()
                .set_read_timeout(Some(Duration::from_secs(20)))
                .unwrap();
            cc.read_response(16 << 20).unwrap()
        });

        // Repeated writable events eventually drain the whole response.
        let t0 = Instant::now();
        while conn.phase() == Phase::Writing {
            assert!(
                t0.elapsed() < Duration::from_secs(20),
                "write never drained"
            );
            match conn.on_writable(Instant::now(), &http) {
                Action::Continue => {
                    std::thread::sleep(Duration::from_millis(1))
                }
                other => panic!("unexpected action {other:?}"),
            }
        }
        assert_eq!(conn.phase(), Phase::Reading);
        let (status, _, body) = reader.join().unwrap();
        assert_eq!(status, 200);
        assert_eq!(body.len(), 8 << 20);
        assert!(body.iter().all(|&b| b == b'x'));
    }

    #[test]
    fn slow_loris_partial_header_hits_408_deadline() {
        let (mut client, server) = pair();
        let cfg = test_cfg();
        let t0 = Instant::now();
        let http = HttpCounters::default();
        let mut conn = Conn::new(server, t0, 1 << 20).unwrap();

        // A partial request line, then silence.
        client.write_all(b"GET /health HT").unwrap();
        std::thread::sleep(Duration::from_millis(50));
        match conn.on_readable(t0, &http) {
            Action::Continue => {}
            other => panic!("partial bytes must keep reading: {other:?}"),
        }

        // Before the deadline: still fine.
        match conn.check_deadline(t0 + Duration::from_millis(100), &cfg, &http)
        {
            Action::Continue => {}
            other => panic!("deadline fired early: {other:?}"),
        }
        // Past the deadline: 408 is queued, flushed, and the connection
        // closes.
        match conn.check_deadline(t0 + Duration::from_millis(250), &cfg, &http)
        {
            Action::Close => {}
            other => panic!("expected close after 408, got {other:?}"),
        }
        assert_eq!(
            http.responses_4xx.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        client
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut buf = Vec::new();
        let _ = client.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 408"), "{text}");
    }

    #[test]
    fn lost_completion_hits_dispatch_backstop() {
        // A request is dispatched but its completion never arrives
        // (worker death). The connection must not park in Dispatching
        // forever: past 2 * request_timeout + grace it closes.
        let (mut client, server) = pair();
        let cfg = ServerConfig {
            request_timeout: Duration::from_millis(100),
            ..test_cfg()
        };
        let t0 = Instant::now();
        let http = HttpCounters::default();
        let mut conn = Conn::new(server, t0, 1 << 20).unwrap();
        client.write_all(b"GET /stuck HTTP/1.1\r\n\r\n").unwrap();
        std::thread::sleep(Duration::from_millis(50));
        match conn.on_readable(t0, &http) {
            Action::Dispatch(_) => {}
            other => panic!("expected dispatch, got {other:?}"),
        }
        assert_eq!(conn.phase(), Phase::Dispatching);
        // Within the in-flight budget (even a slow multi-candidate
        // proxy chain): still waiting on the worker.
        match conn.check_deadline(t0 + Duration::from_secs(60), &cfg, &http) {
            Action::Continue => {}
            other => panic!("backstop fired early: {other:?}"),
        }
        // Far past it: the connection is torn down.
        match conn.check_deadline(t0 + Duration::from_secs(300), &cfg, &http) {
            Action::Close => {}
            other => panic!("expected backstop close, got {other:?}"),
        }
    }

    #[test]
    fn idle_keep_alive_budget_expires_silently() {
        let (client, server) = pair();
        let cfg = test_cfg();
        let t0 = Instant::now();
        let http = HttpCounters::default();
        let mut conn = Conn::new(server, t0, 1 << 20).unwrap();

        match conn.check_deadline(t0 + Duration::from_millis(100), &cfg, &http)
        {
            Action::Continue => {}
            other => panic!("idle budget spent early: {other:?}"),
        }
        match conn.check_deadline(t0 + Duration::from_millis(600), &cfg, &http)
        {
            Action::Close => {}
            other => panic!("expected idle close, got {other:?}"),
        }
        assert_eq!(
            http.responses_4xx.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "idle expiry must not synthesize a response"
        );
        drop(client);
    }

    #[test]
    fn request_then_half_close_is_still_served() {
        // Data + EOF can land in one readiness event (client writes a
        // request and immediately shuts down its write side); the
        // buffered request must dispatch, not be dropped.
        let (mut client, server) = pair();
        let now = Instant::now();
        let http = HttpCounters::default();
        let mut conn = Conn::new(server, now, 1 << 20).unwrap();
        client.write_all(b"GET /last HTTP/1.1\r\n\r\n").unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let req = match conn.on_readable(now, &http) {
            Action::Dispatch(r) => r,
            other => panic!("half-closed request dropped: {other:?}"),
        };
        assert_eq!(req.path(), "/last");
        match conn.complete(&Response::text(200, "late"), true, now, &http) {
            Action::Continue => {}
            other => panic!("unexpected {other:?}"),
        }
        // The pending EOF now closes the connection on the next event.
        match conn.on_readable(now, &http) {
            Action::Close => {}
            other => panic!("expected close after EOF, got {other:?}"),
        }
        client
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut buf = Vec::new();
        let _ = client.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 200"), "{text}");
    }

    #[test]
    fn peer_eof_closes() {
        let (client, server) = pair();
        let now = Instant::now();
        let http = HttpCounters::default();
        let mut conn = Conn::new(server, now, 1 << 20).unwrap();
        drop(client);
        std::thread::sleep(Duration::from_millis(20));
        match conn.on_readable(now, &http) {
            Action::Close => {}
            other => panic!("expected close on EOF, got {other:?}"),
        }
    }

    #[test]
    fn protocol_garbage_gets_400_then_close() {
        let (mut client, server) = pair();
        let now = Instant::now();
        let http = HttpCounters::default();
        let mut conn = Conn::new(server, now, 1 << 20).unwrap();
        client.write_all(b"THIS IS NOT HTTP\r\n\r\n").unwrap();
        std::thread::sleep(Duration::from_millis(50));
        match conn.on_readable(now, &http) {
            Action::Close => {}
            other => panic!("expected close after 400, got {other:?}"),
        }
        client
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut buf = Vec::new();
        let _ = client.read_to_end(&mut buf);
        assert!(
            String::from_utf8_lossy(&buf).starts_with("HTTP/1.1 400"),
            "{}",
            String::from_utf8_lossy(&buf)
        );
    }
}
