//! Minimal concurrency runtime: thread pool + oneshot futures + wakers.
//!
//! tokio is unavailable in the offline crate set, and the needs of the
//! coordinator and the HTTP reactor are modest: a fixed worker pool
//! with a shared injector queue, oneshot completion handles, and a
//! cloneable [`Waker`] callback that worker threads fire to rouse a
//! blocked event loop (the HTTP reactor backs it with a self-pipe).
//! Everything is built on `std::thread` + `std::sync::Mutex`/`Condvar`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    executed: AtomicU64,
}

/// Fixed-size worker pool with a shared FIFO injector.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            executed: AtomicU64::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("tanhvf-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Number of jobs fully executed so far.
    pub fn executed(&self) -> u64 {
        self.shared.executed.load(Ordering::Relaxed)
    }

    /// Enqueue a fire-and-forget job.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(job));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Enqueue a job and get a [`Receiver`] for its result.
    pub fn submit<T: Send + 'static>(
        &self,
        job: impl FnOnce() -> T + Send + 'static,
    ) -> Receiver<T> {
        let (tx, rx) = oneshot();
        self.spawn(move || {
            tx.send(job());
        });
        rx
    }

    /// Run `jobs` to completion, returning results in order.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let handles: Vec<Receiver<T>> = jobs
            .into_iter()
            .map(|j| self.submit(move || j()))
            .collect();
        handles.into_iter().map(|h| h.recv().expect("worker died")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        job();
        shared.executed.fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Oneshot channel
// ---------------------------------------------------------------------------

struct OneshotShared<T> {
    slot: Mutex<(Option<T>, bool)>, // (value, closed)
    ready: Condvar,
}

/// Sending half of a oneshot channel.
pub struct Sender<T> {
    shared: Arc<OneshotShared<T>>,
}

/// Receiving half of a oneshot channel.
pub struct Receiver<T> {
    shared: Arc<OneshotShared<T>>,
}

/// Create a oneshot completion channel.
pub fn oneshot<T>() -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(OneshotShared {
        slot: Mutex::new((None, false)),
        ready: Condvar::new(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    pub fn send(self, value: T) {
        let mut s = self.shared.slot.lock().unwrap();
        s.0 = Some(value);
        drop(s);
        self.shared.ready.notify_all();
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut s = self.shared.slot.lock().unwrap();
        s.1 = true;
        drop(s);
        self.shared.ready.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Block until the value arrives; `None` if the sender was dropped.
    pub fn recv(self) -> Option<T> {
        let mut s = self.shared.slot.lock().unwrap();
        loop {
            if let Some(v) = s.0.take() {
                return Some(v);
            }
            if s.1 {
                return None;
            }
            s = self.shared.ready.wait(s).unwrap();
        }
    }

    /// Block with a deadline.
    pub fn recv_timeout(self, timeout: Duration) -> Option<T> {
        let deadline = Instant::now() + timeout;
        let mut s = self.shared.slot.lock().unwrap();
        loop {
            if let Some(v) = s.0.take() {
                return Some(v);
            }
            if s.1 {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .shared
                .ready
                .wait_timeout(s, deadline - now)
                .unwrap();
            s = guard;
        }
    }
}

// ---------------------------------------------------------------------------
// Waker
// ---------------------------------------------------------------------------

/// A cloneable callback that rouses a blocked event loop from another
/// thread.
///
/// Pool jobs hold a clone and call [`Waker::wake`] when their result is
/// ready; what "waking" means is the loop's business (the HTTP reactor
/// registers a self-pipe write). Calls must be cheap, non-blocking, and
/// safe to issue after the loop is gone.
#[derive(Clone)]
pub struct Waker(Arc<dyn Fn() + Send + Sync + 'static>);

impl Waker {
    pub fn new(f: impl Fn() + Send + Sync + 'static) -> Waker {
        Waker(Arc::new(f))
    }

    pub fn wake(&self) {
        (self.0)()
    }
}

impl std::fmt::Debug for Waker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Waker")
    }
}

/// Default worker count: cores - 1, at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..100)
            .map(|_| {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.executed(), 100);
    }

    #[test]
    fn submit_returns_value() {
        let pool = ThreadPool::new(2);
        let r = pool.submit(|| 6 * 7);
        assert_eq!(r.recv(), Some(42));
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..32usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn oneshot_timeout_expires() {
        let (_tx, rx) = oneshot::<u32>();
        // Sender kept alive; timeout must fire.
        let t0 = Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn oneshot_dropped_sender_yields_none() {
        let (tx, rx) = oneshot::<u32>();
        drop(tx);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| std::thread::sleep(Duration::from_millis(10)));
        drop(pool); // must not hang
    }

    #[test]
    fn waker_fires_from_pool_jobs() {
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let waker = Waker::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        let pool = ThreadPool::new(2);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let w = waker.clone();
                pool.submit(move || w.wake())
            })
            .collect();
        for h in handles {
            h.recv().unwrap();
        }
        assert_eq!(hits.load(Ordering::SeqCst), 8);
        assert_eq!(format!("{waker:?}"), "Waker");
    }
}
