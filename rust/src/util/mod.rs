//! Small shared utilities: JSON, errors, structured logging,
//! deterministic PRNG, order statistics, table formatting.

pub mod error;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod table;

/// Repository-relative path helper: resolves `rel` against the crate root
/// (so binaries work from any CWD under the repo).
pub fn repo_path(rel: &str) -> std::path::PathBuf {
    let mut base = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    base.push(rel);
    base
}
